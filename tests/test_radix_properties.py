"""Property suite for the radix longest-prefix index (DESIGN.md §4e).

A model-based machine drives random interleavings of insert / rearm /
remove / match / lookup / unpin against `RadixPrefixIndex`, checking
every step against (a) a flat ``key -> gid`` reference dict for point
lookups, (b) prefix-match laws for the tree walk, and (c) the index's
own `check()` structural oracle (parent/child coherence, directory ==
reachable set, gid-directory drift, pin consistency, capacity).

Chains come from the REAL key derivation — `page_keys` over random
token streams with random pad counts — so shared heads, divergent
tails, and pad-count splits arise exactly as they do in serving.

Two drivers share the machine, mirroring test_engine_fuzz.py:
a deterministic numpy driver (no hypothesis needed) and a
`RuleBasedStateMachine` (pinned seed in CI; tools/assert_no_skips.py
closes the importorskip silent-pass hole).
"""

import numpy as np
import pytest

from repro.core.agas import GlobalAddress
from repro.serving.kvcache import page_keys
from repro.serving.radix import RadixPrefixIndex

PAGE = 8
PADS = (0, 4, 8)                   # pad counts: part of the key
N_STREAMS = 4                      # token streams (heads shared below)
STREAM_LEN = 5 * PAGE


def _chain(stream: int, n_pages: int, pad: int):
    """Page-key chain over a deterministic token stream.  Streams 0/1
    share their first two pages of tokens (a real-token head), 2/3 are
    independent — so chains collide on prefixes exactly as mixed-length
    prompts sharing a system prompt do."""
    rng = np.random.default_rng(500 + (0 if stream < 2 else stream))
    head = rng.integers(0, 1000, size=2 * PAGE)
    tail = np.random.default_rng(900 + stream).integers(
        0, 1000, size=STREAM_LEN - 2 * PAGE)
    toks = np.concatenate([head, tail]).astype(np.int32)
    return page_keys(toks[:n_pages * PAGE], PAGE, pad=pad)


class RadixModel:
    """The machine body: a real index, a flat reference dict, and the
    laws every operation must preserve."""

    def __init__(self, pin_threshold=3, pin_capacity=4):
        self.idx = RadixPrefixIndex(pin_threshold=pin_threshold,
                                    pin_capacity=pin_capacity)
        self.live = {}               # key -> gid        (reference)
        self.gid_of = {}             # gid -> key
        self.chains = []             # every chain ever inserted
        self._gids = iter(range(1, 10_000))

    # -- operations ---------------------------------------------------
    def insert_chain(self, stream, n_pages, pad, upto=None):
        keys = _chain(stream, n_pages, pad)
        self.chains.append(keys)
        prev = None
        for key in keys[:upto]:
            gid = next(self._gids)
            self.idx.insert(key, GlobalAddress(gid), parent=prev)
            if key not in self.live:          # fresh or rearm
                self.live[key] = gid
                self.gid_of[gid] = key
            prev = key[0]
        self._invariants()

    def insert_duplicate_gid(self):
        """Registering an already-keyed gid must be a no-op."""
        if not self.gid_of:
            return
        gid = next(iter(self.gid_of))
        fresh = page_keys(np.arange(PAGE, dtype=np.int32) + gid, PAGE)
        self.idx.insert(fresh[0], GlobalAddress(gid))
        assert self.idx.lookup(fresh[0]) is None
        self._invariants()

    def remove(self, which):
        if not self.gid_of:
            return
        gid = sorted(self.gid_of)[which % len(self.gid_of)]
        self.idx.remove_gid(gid)
        del self.live[self.gid_of.pop(gid)]
        self._invariants()

    def match(self, chain_idx, upto=None):
        if not self.chains:
            return
        keys = self.chains[chain_idx % len(self.chains)][:upto]
        nodes = self.idx.match(keys)
        # a match is a leading run of live nodes with the right keys
        assert len(nodes) <= len(keys)
        for node, key in zip(nodes, keys):
            assert node.key == key and node.addr is not None
            assert self.live.get(key) == node.addr.gid
        # the walk never stops early at a live, correctly-parented key
        if len(nodes) < len(keys):
            nxt = keys[len(nodes)]
            if nxt in self.live:
                node = self.idx._nodes[nxt[0]]
                parent_ok = (node.parent is self.idx.root
                             if not nodes else
                             node.parent is nodes[-1])
                assert not parent_ok, (
                    "match stopped before a live, reachable key")
        self._invariants()

    def unpin(self, which, forced):
        pinned = sorted(self.idx.pinned_gids)
        if pinned:
            self.idx.unpin_gid(pinned[which % len(pinned)],
                               forced=forced)
        self._invariants()

    # -- the laws -----------------------------------------------------
    def _invariants(self):
        self.idx.check()
        assert len(self.idx) == len(self.live)
        for key, gid in self.live.items():
            addr = self.idx.lookup(key)
            assert addr is not None and addr.gid == gid
            assert self.idx.owns_gid(gid)
            assert self.idx.key_for_gid(gid) == key
        for gid in self.idx.pinned_gids:
            assert gid in self.gid_of           # pins are live pages
        m = self.idx.metrics()
        assert m["prefix.nodes"] == len(self.live)
        assert m["prefix.pinned"] <= self.idx.pin_capacity

    def lookup_dead(self):
        """Removed keys never resolve (unless re-armed since)."""
        for keys in self.chains:
            for key in keys:
                if key not in self.live:
                    assert self.idx.lookup(key) is None


# -- targeted unit laws ------------------------------------------------

def test_chain_insert_match_roundtrip():
    m = RadixModel()
    m.insert_chain(0, 4, 0)
    nodes = m.idx.match(m.chains[0])
    assert len(nodes) == 4               # full walk
    assert m.idx.metrics()["prefix.full_walks"] == 1


def test_shared_head_diverging_tails():
    """Streams 0 and 1 share two pages of tokens: their pad-0 chains
    share exactly the two head keys, and each tail extends its own
    branch of the tree."""
    m = RadixModel()
    m.insert_chain(0, 4, 0)
    m.insert_chain(1, 4, 0)
    a, b = m.chains
    assert a[:2] == b[:2] and a[2] != b[2]
    assert m.idx.node_count == 2 + 2 + 2     # shared head + two tails
    assert len(m.idx.match(a)) == 4
    assert len(m.idx.match(b)) == 4


def test_pad_count_splits_the_tree():
    """The same tokens under a different pad count are a DIFFERENT
    name: no key is shared, and both chains match independently."""
    m = RadixModel()
    m.insert_chain(0, 3, 0)
    m.insert_chain(0, 3, 4)
    a, b = m.chains
    assert not set(a) & set(b)
    assert len(m.idx.match(a)) == 3
    assert len(m.idx.match(b)) == 3


def test_interior_removal_truncates_match_but_keeps_lookup():
    """Dropping an interior page tombstones its node: the root walk
    stops at the hole, but descendants stay directory-reachable (chunk
    extensions can still hit them)."""
    m = RadixModel()
    m.insert_chain(0, 4, 0)
    keys = m.chains[0]
    m.remove(sorted(m.gid_of).index(m.live[keys[1]]))
    assert len(m.idx.match(keys)) == 1           # truncated at the hole
    assert m.idx.lookup(keys[2]) is not None     # directory still hits
    assert m.idx.lookup(keys[1]) is None
    m.lookup_dead()


def test_leaf_removal_trims_tombstone_chains():
    """Removing leaf-to-root leaves no tombstones behind."""
    m = RadixModel()
    m.insert_chain(2, 4, 0)
    for _ in range(4):
        m.remove(len(m.gid_of) - 1)              # always the newest
    assert m.idx.node_count == 0 and len(m.idx) == 0


def test_rearm_revives_tombstone_with_subtree():
    """A re-derived interior page adopts its old node: the subtree and
    hit history survive, and the full chain matches again."""
    m = RadixModel()
    m.insert_chain(0, 4, 0)
    keys = m.chains[0]
    m.idx.match(keys)
    hits_before = m.idx._nodes[keys[1][0]].hits
    m.remove(sorted(m.gid_of).index(m.live[keys[1]]))
    m.insert_chain(0, 4, 0)                      # re-prefill the chain
    assert m.idx.rearms >= 1
    assert len(m.idx.match(keys)) == 4
    assert m.idx._nodes[keys[1][0]].hits == hits_before + 1


def test_hot_nodes_pin_up_to_capacity_and_forced_unpin():
    m = RadixModel(pin_threshold=2, pin_capacity=3)
    m.insert_chain(0, 4, 0)
    for _ in range(3):
        m.match(0)
    assert 0 < len(m.idx.pinned_gids) <= 3       # capacity-bounded
    assert m.idx.metrics()["prefix.pins"] == 3
    m.unpin(0, forced=True)
    assert m.idx.metrics()["prefix.forced_unpins"] == 1
    # removal of a pinned page unpins it
    pinned = sorted(m.idx.pinned_gids)[0]
    m.remove(sorted(m.gid_of).index(pinned))
    assert pinned not in m.idx.pinned_gids


# -- driver 1: deterministic numpy traces ------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_radix_machine_deterministic(seed):
    rng = np.random.default_rng(200 + seed)
    m = RadixModel(pin_threshold=int(rng.integers(0, 4)),
                   pin_capacity=int(rng.integers(1, 5)))
    for _ in range(40):
        op = rng.choice(["insert", "insert", "match", "match",
                         "remove", "remove", "unpin", "dup"])
        if op == "insert":
            m.insert_chain(int(rng.integers(N_STREAMS)),
                           int(rng.integers(1, 6)),
                           int(rng.choice(PADS)),
                           upto=int(rng.integers(1, 6)))
        elif op == "match":
            m.match(int(rng.integers(0, 10)),
                    upto=int(rng.integers(1, 6)))
        elif op == "remove":
            m.remove(int(rng.integers(0, 50)))
        elif op == "unpin":
            m.unpin(int(rng.integers(0, 5)), bool(rng.integers(2)))
        else:
            m.insert_duplicate_gid()
    m.lookup_dead()


# -- driver 2: hypothesis stateful traces ------------------------------

try:
    from hypothesis import HealthCheck, settings
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     precondition, rule)
    HAVE_HYPOTHESIS = True
except ImportError:                  # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    class RadixFuzz(RuleBasedStateMachine):
        def __init__(self):
            super().__init__()
            self.m = None

        @initialize(threshold=st.integers(0, 3),
                    capacity=st.integers(1, 4))
        def setup(self, threshold, capacity):
            self.m = RadixModel(pin_threshold=threshold,
                                pin_capacity=capacity)

        @precondition(lambda self: self.m is not None)
        @rule(stream=st.integers(0, N_STREAMS - 1),
              n_pages=st.integers(1, 5),
              pad=st.sampled_from(PADS),
              upto=st.integers(1, 5))
        def insert(self, stream, n_pages, pad, upto):
            self.m.insert_chain(stream, n_pages, pad, upto=upto)

        @precondition(lambda self: self.m is not None)
        @rule(chain=st.integers(0, 9), upto=st.integers(1, 5))
        def match(self, chain, upto):
            self.m.match(chain, upto=upto)

        @precondition(lambda self: self.m is not None)
        @rule(which=st.integers(0, 49))
        def remove(self, which):
            self.m.remove(which)

        @precondition(lambda self: self.m is not None)
        @rule(which=st.integers(0, 4), forced=st.booleans())
        def unpin(self, which, forced):
            self.m.unpin(which, forced)

        @precondition(lambda self: self.m is not None)
        @rule()
        def duplicate_gid(self):
            self.m.insert_duplicate_gid()

        def teardown(self):
            if self.m is not None:
                self.m.lookup_dead()

    TestRadixFuzz = RadixFuzz.TestCase
    TestRadixFuzz.settings = settings(
        max_examples=50, stateful_step_count=30, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
else:                                # keep the skip visible locally;
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_radix_fuzz_stateful():  # CI asserts it did NOT skip
        ...
