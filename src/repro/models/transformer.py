"""Model assembly for all assigned families.

Families and their layer stacks (all scan-over-layers with stacked
params, remat-wrapped when cfg.remat):

  dense / audio   L x [norm, GQA-attn, norm, SwiGLU]
  moe             L x [norm, GQA-attn, norm, top-2 MoE]
  ssm             L x [norm, mamba1]
  hybrid (zamba2) G groups x [E mamba2 layers, shared attn block] + tail
                  — ONE shared attention block's weights reused by all
                  groups (an AGAS single-object/many-refs pattern), with
                  a per-group output adapter.
  vlm             G groups x [(k-1) self layers, 1 gated cross-attn
                  layer over stub patch embeddings]

Entry points:
  init_params(key, cfg, tp)                 -> params
  forward(params, batch, cfg, mode)         -> (hidden, aux)
  loss_fn(params, batch, cfg)               -> scalar (chunked-CE)
  init_cache(cfg, batch, cache_len)         -> cache pytree
  decode_step(params, cache, batch, cfg)    -> (logits, cache)
  init_paged_cache(cfg, n_rows, page_size)  -> {"k","v"} page arrays
  decode_step_paged(params, pages, batch, cfg) -> (logits, pages)
                                            (per-slot position clocks
                                            over AGAS block tables,
                                            DESIGN.md §4a)
  prefill_chunk(params, pages, batch, cfg)  -> (logits, pages)
                                            (resumable chunked prefill:
                                            one page-aligned chunk of a
                                            prompt attends the pages of
                                            earlier chunks and extends
                                            the paged cache, §4b;
                                            all_hidden=True returns the
                                            chunk's post-norm hidden
                                            states instead of logits —
                                            the activation checkpoints
                                            compute skip stores, §4e)
  resume_prefill(params, hidden)            -> logits
                                            (prefix-cache compute skip,
                                            §4e: first-token logits
                                            from a cached last-position
                                            activation checkpoint — a
                                            fully-covered prompt runs
                                            no transformer pass at all)

`batch` is a dict: tokens (B,S) int32; labels (B,S) for train;
patch_embeds (B,Nimg,Df) for vlm; frame_embeds (B,S,D) for audio;
cache_len () int32 for decode.  The modality frontends are STUBS per
the task statement: input_specs() (launch/dryrun.py) fabricates the
precomputed embeddings.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as att
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ArchConfig
from repro.models.layers import (Params, _init_dense, constrain_spec,
                                 cross_entropy_chunked, embed_init,
                                 embed_lookup, rmsnorm, rmsnorm_init,
                                 swiglu, swiglu_init)


import os

# Megatron-style sequence parallelism for the residual stream.
# MEASURED AND REFUTED on this partitioner (EXPERIMENTS.md §Perf, F4):
# instead of folding the TP psums into reduce-scatter/all-gather pairs,
# GSPMD reshards around every attention/MoE boundary — command-r train
# collective seconds went 30 -> 104.  Kept as an opt-in flag for
# documentation; default OFF.
_SEQ_SHARD_RESIDUAL = os.environ.get(
    "REPRO_SEQ_SHARD_RESIDUAL", "0") not in ("0", "false")


def _cres(x):
    """Pin the residual stream: batch on dp, seq optionally sharded
    over "model" (F4), D replicated.

    Stops the SPMD partitioner from speculatively resharding (B, S, D)
    activations onto "model" between blocks, which showed up as paired
    all-gather+all-reduce of activation tensors in every layer.  The
    batch dim must be pinned too — left unconstrained, the partitioner
    answered the D-replication constraint by all-gathering the batch
    (EXPERIMENTS.md §Perf, fix F1).
    """
    seq = "model" if _SEQ_SHARD_RESIDUAL else "U"
    return constrain_spec(x, "DP", seq, None)


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def _dense_layer_init(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "attn": att.attn_init(k1, cfg),
        "mlp_norm": rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
    }
    p["mlp"] = swiglu_init(k2, cfg.d_model, cfg.d_ff, jnp.dtype(cfg.dtype))
    return p


def _moe_layer_init(key, cfg: ArchConfig, tp: int) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "attn": att.attn_init(k1, cfg),
        "mlp_norm": rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "moe": moe_mod.moe_init(k2, cfg, tp),
    }


def _ssm_layer_init(key, cfg: ArchConfig) -> Params:
    init = ssm_mod.mamba1_init if cfg.mamba_version == 1 \
        else ssm_mod.mamba2_init
    return {
        "norm": rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "ssm": init(key, cfg),
    }


def _shared_attn_init(key, cfg: ArchConfig) -> Params:
    """zamba2 shared block: attends over concat(x, x0) (width 2d)."""
    wide = dataclasses.replace(
        cfg, head_dim=2 * cfg.d_model // cfg.n_heads)
    k1, k2 = jax.random.split(key)
    return {
        "norm": rmsnorm_init(2 * cfg.d_model, jnp.dtype(cfg.dtype)),
        "attn": att.attn_init(k1, wide, d_in=2 * cfg.d_model),
        "mlp_norm": rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "mlp": swiglu_init(k2, cfg.d_model, cfg.d_ff,
                           jnp.dtype(cfg.dtype)),
    }


def _cross_layer_init(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "attn": att.attn_init(k1, cfg),
        "gate": jnp.zeros((), jnp.float32),     # gated residual, init 0
        "mlp_norm": rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "mlp": swiglu_init(k2, cfg.d_model, cfg.d_ff,
                           jnp.dtype(cfg.dtype)),
    }


def _stack_init(key, n: int, fn) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def init_params(key, cfg: ArchConfig, tp: int = 1) -> Params:
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.dtype)
    params: Params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["out_embed"] = embed_init(ks[1], cfg.vocab_size,
                                         cfg.d_model, dt)
    fam = cfg.family
    if fam in ("dense", "audio"):
        params["layers"] = _stack_init(
            ks[2], cfg.n_layers, lambda k: _dense_layer_init(k, cfg))
    elif fam == "moe":
        params["layers"] = _stack_init(
            ks[2], cfg.n_layers, lambda k: _moe_layer_init(k, cfg, tp))
    elif fam == "ssm":
        params["layers"] = _stack_init(
            ks[2], cfg.n_layers, lambda k: _ssm_layer_init(k, cfg))
    elif fam == "hybrid":
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        tail = cfg.n_layers - n_groups * every
        params["groups"] = _stack_init(
            ks[2], n_groups,
            lambda k: _stack_init(k, every,
                                  lambda k2: _ssm_layer_init(k2, cfg)))
        params["shared_attn"] = _shared_attn_init(ks[3], cfg)
        params["adapters"] = _stack_init(
            ks[4], n_groups,
            lambda k: {"w": _init_dense(k, cfg.d_model, cfg.d_model, dt)
                       * 0.1})
        if tail:
            params["tail"] = _stack_init(
                ks[5], tail, lambda k: _ssm_layer_init(k, cfg))
    elif fam == "vlm":
        every = cfg.cross_attn_every
        n_groups = cfg.n_layers // every
        params["groups_self"] = _stack_init(
            ks[2], n_groups,
            lambda k: _stack_init(k, every - 1,
                                  lambda k2: _dense_layer_init(k2, cfg)))
        params["groups_cross"] = _stack_init(
            ks[3], n_groups, lambda k: _cross_layer_init(k, cfg))
        params["patch_proj"] = {
            "w": _init_dense(ks[4], _frontend_dim(cfg), cfg.d_model, dt)}
    else:
        raise ValueError(f"unknown family {fam}")
    return params


def _frontend_dim(cfg: ArchConfig) -> int:
    return 1280 if cfg.d_model >= 1024 else 32


# ---------------------------------------------------------------------------
# Layer bodies (shared by forward and decode)
# ---------------------------------------------------------------------------

def _attn_block(lp: Params, x, cfg: ArchConfig, cos, sin, *,
                use_pallas=False, kv_override=None, causal=True):
    h = rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
    q, k, v = att.qkv(lp["attn"], h, cfg)
    if kv_override is None:
        q = att.apply_rope(q, cos, sin, cfg.rope_fraction)
        k = att.apply_rope(k, cos, sin, cfg.rope_fraction)
        o = att.attention(q, k, v, cfg, causal=causal,
                          use_pallas=use_pallas)
    else:
        # cross-attention: keys/values from the frontend embeddings
        kx, vx = kv_override
        o = att.attention(q, kx, vx, cfg, causal=False,
                          use_pallas=use_pallas)
    b, s, _, _ = o.shape
    return o.reshape(b, s, -1) @ lp["attn"]["wo"], (k, v)


def _cross_kv(lp: Params, embeds, cfg: ArchConfig):
    b, n, _ = embeds.shape
    k = (embeds @ lp["attn"]["wk"]).reshape(b, n, cfg.n_kv_heads,
                                            cfg.head_dim)
    v = (embeds @ lp["attn"]["wv"]).reshape(b, n, cfg.n_kv_heads,
                                            cfg.head_dim)
    return k, v


def _mlp_block(lp: Params, x, cfg: ArchConfig):
    return swiglu(lp["mlp"], rmsnorm(lp["mlp_norm"], x, cfg.norm_eps))


def _shared_attn_apply(sp: Params, adapter: Params, x, x0,
                       cfg: ArchConfig, positions, use_pallas=False):
    wide = dataclasses.replace(
        cfg, head_dim=2 * cfg.d_model // cfg.n_heads)
    rot = max(int(wide.head_dim * cfg.rope_fraction), 2)
    cos, sin = att.rope_angles(positions, rot, cfg.rope_theta)
    xx = jnp.concatenate([x, x0], axis=-1)
    h = rmsnorm(sp["norm"], xx, cfg.norm_eps)
    q, k, v = att.qkv(sp["attn"], h, wide)
    q = att.apply_rope(q, cos, sin, cfg.rope_fraction)
    k = att.apply_rope(k, cos, sin, cfg.rope_fraction)
    o = att.attention(q, k, v, wide, causal=True, use_pallas=use_pallas)
    b, s, _, _ = o.shape
    o = o.reshape(b, s, -1) @ sp["attn"]["wo"]
    x = x + o @ adapter["w"]
    x = x + swiglu(sp["mlp"], rmsnorm(sp["mlp_norm"], x, cfg.norm_eps))
    return x, (k, v)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ArchConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def forward(params: Params, batch: Dict[str, Any], cfg: ArchConfig,
            mode: str = "train", use_pallas: bool = False,
            tp: int = 1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  Returns (hidden (B,S,D), aux_loss)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens)
    if cfg.family == "audio" and "frame_embeds" in batch:
        x = x + batch["frame_embeds"].astype(x.dtype)
    pos = jnp.arange(s)
    rot = int(cfg.head_dim * cfg.rope_fraction) if cfg.n_heads else 0
    cos, sin = att.rope_angles(pos, max(rot, 2), cfg.rope_theta)
    aux = jnp.float32(0.0)
    fam = cfg.family

    if fam in ("dense", "audio"):
        def layer(x, lp):
            o, _ = _attn_block(lp, x, cfg, cos, sin,
                               use_pallas=use_pallas)
            x = _cres(x + o)
            x = _cres(x + _mlp_block(lp, x, cfg))
            return x, None

        G = cfg.remat_group_size
        if G > 1 and cfg.n_layers % G == 0:
            # F5: checkpoint k-layer groups — the backward saves one
            # residual per GROUP (stack memory / k) and re-runs the
            # inner k-layer scan during the group's backward.
            grouped = jax.tree.map(
                lambda p: p.reshape((cfg.n_layers // G, G)
                                    + p.shape[1:]), params["layers"])

            def group(x, gp):
                # nested remat: the group's backward replays layer by
                # layer with only one inner residual live at a time
                x, _ = jax.lax.scan(_maybe_remat(layer, cfg), x, gp)
                return x, None

            x, _ = jax.lax.scan(_maybe_remat(group, cfg), x, grouped)
        else:
            x, _ = jax.lax.scan(_maybe_remat(layer, cfg), x,
                                params["layers"])
    elif fam == "moe":
        def layer(carry, lp):
            x, aux = carry
            o, _ = _attn_block(lp, x, cfg, cos, sin,
                               use_pallas=use_pallas)
            x = _cres(x + o)
            h = rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
            mo, a = moe_mod.moe_apply(lp["moe"], h, cfg, tp)
            return (_cres(x + mo), aux + a), None
        (x, aux), _ = jax.lax.scan(_maybe_remat(layer, cfg), (x, aux),
                                   params["layers"])
    elif fam == "ssm":
        ssm_mode = "chunked" if mode != "ref" else "ref"
        def layer(x, lp):
            h = rmsnorm(lp["norm"], x, cfg.norm_eps)
            y, _ = ssm_mod.ssm_block_apply(lp["ssm"], h, cfg,
                                           mode=ssm_mode)
            return _cres(x + y), None
        x, _ = jax.lax.scan(_maybe_remat(layer, cfg), x,
                            params["layers"])
    elif fam == "hybrid":
        x0 = x
        ssm_mode = "chunked" if mode != "ref" else "ref"

        def mamba_layer(x, lp):
            h = rmsnorm(lp["norm"], x, cfg.norm_eps)
            y, _ = ssm_mod.ssm_block_apply(lp["ssm"], h, cfg,
                                           mode=ssm_mode)
            return x + y, None

        sp = params["shared_attn"]

        def group(x, g):
            gp, ad = g
            x, _ = jax.lax.scan(mamba_layer, x, gp)
            x, _ = _shared_attn_apply(sp, ad, x, x0, cfg, pos,
                                      use_pallas=use_pallas)
            return x, None

        x, _ = jax.lax.scan(_maybe_remat(group, cfg), x,
                            (params["groups"], params["adapters"]))
        if "tail" in params:
            x, _ = jax.lax.scan(_maybe_remat(mamba_layer, cfg), x,
                                params["tail"])
    elif fam == "vlm":
        pe = batch["patch_embeds"].astype(x.dtype)
        pe = pe @ params["patch_proj"]["w"]

        def self_layer(x, lp):
            o, _ = _attn_block(lp, x, cfg, cos, sin,
                               use_pallas=use_pallas)
            x = _cres(x + o)
            return _cres(x + _mlp_block(lp, x, cfg)), None

        def group(x, g):
            sl, cl = g
            # nested remat (F5): without it the group backward holds
            # every inner self-layer's internals live at once
            x, _ = jax.lax.scan(_maybe_remat(self_layer, cfg), x, sl)
            kx, vx = _cross_kv(cl, pe, cfg)
            h = rmsnorm(cl["attn_norm"], x, cfg.norm_eps)
            q = (h @ cl["attn"]["wq"]).reshape(
                x.shape[0], x.shape[1], cfg.n_heads, cfg.head_dim)
            o = att.attention(q, kx, vx, cfg, causal=False,
                              use_pallas=use_pallas)
            o = o.reshape(x.shape[0], x.shape[1], -1) @ cl["attn"]["wo"]
            x = _cres(x + jnp.tanh(cl["gate"]).astype(x.dtype) * o)
            return _cres(x + _mlp_block(cl, x, cfg)), None

        x, _ = jax.lax.scan(
            _maybe_remat(group, cfg), x,
            (params["groups_self"], params["groups_cross"]))
    else:
        raise ValueError(fam)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def loss_fn(params: Params, batch: Dict[str, Any], cfg: ArchConfig,
            use_pallas: bool = False, tp: int = 1) -> jnp.ndarray:
    x, aux = forward(params, batch, cfg, "train", use_pallas, tp)
    out_w = params.get("out_embed", params["embed"])["embedding"]
    ce = cross_entropy_chunked(x, out_w, batch["labels"],
                               cfg.loss_chunk)
    return ce + 0.01 * aux


def logits_fn(params: Params, hidden: jnp.ndarray) -> jnp.ndarray:
    out_w = params.get("out_embed", params["embed"])["embedding"]
    return (hidden @ out_w.T.astype(hidden.dtype)).astype(jnp.float32)


def prefill(params: Params, batch: Dict[str, Any], cfg: ArchConfig,
            use_pallas: bool = False, tp: int = 1,
            full_kv: bool = False, last_index=None,
            all_hidden: bool = False
            ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Full-sequence forward that also builds the decode cache.

    Returns (last-position hidden (B, D), cache).  SWA archs keep only
    the trailing `window` keys (ring reset so the cursor wraps onto the
    oldest slot) unless `full_kv` — the paged cache keeps every
    position and enforces the window as an absolute-position mask
    instead of by trimming.  `last_index` (a traced int32) selects
    which position's hidden state is returned instead of the final
    one — used by right-padded prefills, where the real sequence ends
    before the padded buffer does, without recompiling per length.
    `all_hidden=True` returns the full post-norm hidden (B, S, D)
    instead (`last_index` ignored) — callers index it themselves and
    checkpoint page-boundary positions for compute skip (§4e).

    Layout contract: the paged/chunked engines run PAD-FREE — real
    tokens occupy positions 0..R-1 and any padding is RIGHT-padding
    in the compute buffer only (junk positions are causally masked
    from the real ones and never attached to the KV cache), so the
    same prompt produces the same per-position KV regardless of which
    bucket it compiled into.  That position normalization is what the
    §4e prefix keys hash over; only the dense engine left-pads (its
    single shared clock needs aligned ends), which is why its caches
    never interoperate with the paged prefix index.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens)
    if cfg.family == "audio" and "frame_embeds" in batch:
        x = x + batch["frame_embeds"].astype(x.dtype)
    pos = jnp.arange(s)
    rot = int(cfg.head_dim * cfg.rope_fraction) if cfg.n_heads else 0
    cos, sin = att.rope_angles(pos, max(rot, 2), cfg.rope_theta)
    fam = cfg.family
    win = cfg.sliding_window
    eff = min(s, win) if win else s

    def trim(k):   # keep trailing window for SWA ring buffers
        return k[..., -eff:, :, :] if (win and not full_kv) else k

    # len = valid cache slots; cursor = next ring write slot (slot 0 is
    # the oldest after a trim); abs = absolute next position (RoPE
    # phase continuity for ring-buffer SWA caches where len < abs).
    cache: Dict[str, Any] = {
        "len": jnp.asarray(eff, jnp.int32),
        "cursor": jnp.asarray(0 if win else s, jnp.int32),
        "abs": jnp.asarray(s, jnp.int32),
    }

    if fam in ("dense", "audio", "moe"):
        def layer(x, lp):
            o, (k, v) = _attn_block(lp, x, cfg, cos, sin,
                                    use_pallas=use_pallas)
            x = x + o
            if fam == "moe":
                h = rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
                mo, _ = moe_mod.moe_apply(lp["moe"], h, cfg, tp)
                x = x + mo
            else:
                x = x + _mlp_block(lp, x, cfg)
            return x, (trim(k), trim(v))
        x, (ks, vs) = jax.lax.scan(_maybe_remat(layer, cfg), x,
                                   params["layers"])
        cache["k"], cache["v"] = ks, vs
    elif fam == "ssm":
        def layer(x, lp):
            h = rmsnorm(lp["norm"], x, cfg.norm_eps)
            y, st = ssm_mod.ssm_block_apply(lp["ssm"], h, cfg,
                                            mode="chunked")
            return x + y, (st["ssm"], st["conv"])
        x, (hs, cs) = jax.lax.scan(_maybe_remat(layer, cfg), x,
                                   params["layers"])
        cache["ssm"], cache["conv"] = hs, cs
    elif fam == "hybrid":
        x0 = x
        sp = params["shared_attn"]

        def mamba_layer(x, lp):
            h = rmsnorm(lp["norm"], x, cfg.norm_eps)
            y, st = ssm_mod.ssm_block_apply(lp["ssm"], h, cfg,
                                            mode="chunked")
            return x + y, (st["ssm"], st["conv"])

        def group(x, g):
            gp, ad = g
            x, (hs, cs) = jax.lax.scan(mamba_layer, x, gp)
            x, (k, v) = _shared_attn_apply(sp, ad, x, x0, cfg, pos,
                                           use_pallas=use_pallas)
            return x, (hs, cs, trim(k), trim(v))

        x, (hs, cs, ks, vs) = jax.lax.scan(
            _maybe_remat(group, cfg), x,
            (params["groups"], params["adapters"]))
        cache.update(ssm=hs, conv=cs, k=ks, v=vs)
        if "tail" in params:
            x, (th, tc) = jax.lax.scan(_maybe_remat(mamba_layer, cfg),
                                       x, params["tail"])
            cache["tail_ssm"], cache["tail_conv"] = th, tc
    elif fam == "vlm":
        pe = batch["patch_embeds"].astype(x.dtype)
        pe = pe @ params["patch_proj"]["w"]

        def self_layer(x, lp):
            o, (k, v) = _attn_block(lp, x, cfg, cos, sin,
                                    use_pallas=use_pallas)
            x = x + o
            return x + _mlp_block(lp, x, cfg), (trim(k), trim(v))

        def group(x, g):
            sl, cl = g
            x, (k, v) = jax.lax.scan(self_layer, x, sl)
            kx, vx = _cross_kv(cl, pe, cfg)
            h = rmsnorm(cl["attn_norm"], x, cfg.norm_eps)
            q = (h @ cl["attn"]["wq"]).reshape(
                x.shape[0], x.shape[1], cfg.n_heads, cfg.head_dim)
            o = att.attention(q, kx, vx, cfg, causal=False,
                              use_pallas=use_pallas)
            o = o.reshape(x.shape[0], x.shape[1], -1) @ cl["attn"]["wo"]
            x = x + jnp.tanh(cl["gate"]).astype(x.dtype) * o
            return x + _mlp_block(cl, x, cfg), (k, v)

        x, (ks, vs) = jax.lax.scan(
            _maybe_remat(group, cfg), x,
            (params["groups_self"], params["groups_cross"]))
        cache["k"], cache["v"] = ks, vs
    else:
        raise ValueError(fam)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if all_hidden:
        return x, cache
    if last_index is None:
        return x[:, -1], cache
    out = jax.lax.dynamic_index_in_dim(x, last_index, axis=1,
                                       keepdims=False)
    return out, cache


# ---------------------------------------------------------------------------
# KV / state caches and decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch_size: int, cache_len: int,
               dtype=None) -> Dict[str, Any]:
    """Allocate the decode cache.  Sliding-window archs cap the cache
    at their window (the sub-quadratic property)."""
    dt = dtype or jnp.dtype(cfg.dtype)
    eff = min(cache_len, cfg.sliding_window) if cfg.sliding_window \
        else cache_len
    cache: Dict[str, Any] = {"len": jnp.zeros((), jnp.int32),
                             "cursor": jnp.zeros((), jnp.int32),
                             "abs": jnp.zeros((), jnp.int32)}
    kvshape = (cfg.n_layers, batch_size, eff, cfg.n_kv_heads,
               cfg.head_dim)
    fam = cfg.family
    if fam in ("dense", "audio", "moe", "vlm"):
        n_attn = cfg.n_layers
        if fam == "vlm":
            n_attn = cfg.n_layers - cfg.n_layers // cfg.cross_attn_every
            kvshape = (cfg.n_layers // cfg.cross_attn_every,
                       cfg.cross_attn_every - 1, batch_size, eff,
                       cfg.n_kv_heads, cfg.head_dim)
        else:
            kvshape = (n_attn, batch_size, eff, cfg.n_kv_heads,
                       cfg.head_dim)
        cache["k"] = jnp.zeros(kvshape, dt)
        cache["v"] = jnp.zeros(kvshape, dt)
    if fam == "ssm":
        cache["ssm"] = jnp.zeros(
            (cfg.n_layers, batch_size, cfg.d_inner, cfg.ssm_state),
            jnp.float32)
        cache["conv"] = jnp.zeros(
            (cfg.n_layers, batch_size, cfg.ssm_conv - 1, cfg.d_inner),
            dt)
    if fam == "hybrid":
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        tail = cfg.n_layers - n_groups * every
        nh = cfg.d_inner // cfg.ssm_head_dim
        cache["ssm"] = jnp.zeros(
            (n_groups, every, batch_size, nh, cfg.ssm_head_dim,
             cfg.ssm_state), jnp.float32)
        cache["conv"] = jnp.zeros(
            (n_groups, every, batch_size, cfg.ssm_conv - 1,
             cfg.d_inner + 2 * cfg.ssm_state), dt)
        if tail:
            cache["tail_ssm"] = jnp.zeros(
                (tail, batch_size, nh, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32)
            cache["tail_conv"] = jnp.zeros(
                (tail, batch_size, cfg.ssm_conv - 1,
                 cfg.d_inner + 2 * cfg.ssm_state), dt)
        wide_hd = 2 * cfg.d_model // cfg.n_heads
        cache["k"] = jnp.zeros(
            (n_groups, batch_size, eff, cfg.n_kv_heads, wide_hd), dt)
        cache["v"] = jnp.zeros(
            (n_groups, batch_size, eff, cfg.n_kv_heads, wide_hd), dt)
    return cache


def _decode_attn(lp, x, cfg, cos, sin, k_c, v_c, cache_len, pos):
    """One-token attention against (and update of) one layer's cache."""
    h = rmsnorm(lp["attn_norm"] if "attn_norm" in lp else lp["norm"],
                x, cfg.norm_eps)
    q, k, v = att.qkv(lp["attn"], h, cfg)
    q = att.apply_rope(q, cos, sin, cfg.rope_fraction)
    k = att.apply_rope(k, cos, sin, cfg.rope_fraction)
    k_c = jax.lax.dynamic_update_slice_in_dim(k_c, k, pos, axis=1)
    v_c = jax.lax.dynamic_update_slice_in_dim(v_c, v, pos, axis=1)
    o = att.decode_attention(q, k_c, v_c, cache_len + 1, cfg)
    b = x.shape[0]
    return o.reshape(b, 1, -1) @ lp["attn"]["wo"], k_c, v_c


def decode_step(params: Params, cache: Dict[str, Any],
                batch: Dict[str, Any], cfg: ArchConfig,
                tp: int = 1) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step for the whole batch.

    batch: tokens (B, 1).  Returns (logits (B, V) f32, new cache).
    For sliding-window caches the write position wraps (ring buffer).
    """
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = embed_lookup(params["embed"], tokens)
    cache_len = cache["len"]
    pos_abs = cache["abs"]                    # absolute position (RoPE)
    eff = cache["k"].shape[-3] if "k" in cache else 0
    # SWA caches are ring buffers of size `window`: the write cursor
    # wraps; masking is by valid-slot count (order-free softmax).
    pos_write = (cache["cursor"] % jnp.int32(eff)) \
        if (eff and cfg.sliding_window > 0) else cache["cursor"]
    rot = int(cfg.head_dim * cfg.rope_fraction) if cfg.n_heads else 2
    cos, sin = att.rope_angles(pos_abs[None], max(rot, 2),
                               cfg.rope_theta)
    aux_len = jnp.minimum(cache_len, eff - 1) if eff else cache_len
    fam = cfg.family

    if fam in ("dense", "audio", "moe"):
        def layer(x, lkv):
            lp, k_c, v_c = lkv
            o, k_c, v_c = _decode_attn(lp, x, cfg, cos, sin, k_c, v_c,
                                       aux_len, pos_write)
            x = x + o
            if fam == "moe":
                h = rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
                mo, _ = moe_mod.moe_apply(lp["moe"], h, cfg, tp)
                x = x + mo
            else:
                x = x + _mlp_block(lp, x, cfg)
            return x, (k_c, v_c)
        x, (k_new, v_new) = jax.lax.scan(
            layer, x, (params["layers"], cache["k"], cache["v"]))
        cache = dict(cache, k=k_new, v=v_new)
    elif fam == "ssm":
        def layer(x, lst):
            lp, h0, c0 = lst
            h = rmsnorm(lp["norm"], x, cfg.norm_eps)
            y, st = ssm_mod.ssm_block_apply(
                lp["ssm"], h, cfg, mode="decode",
                state={"ssm": h0, "conv": c0})
            return x + y, (st["ssm"], st["conv"])
        x, (ssm_new, conv_new) = jax.lax.scan(
            layer, x, (params["layers"], cache["ssm"], cache["conv"]))
        cache = dict(cache, ssm=ssm_new, conv=conv_new)
    elif fam == "hybrid":
        x0 = x
        sp = params["shared_attn"]
        wide = dataclasses.replace(
            cfg, head_dim=2 * cfg.d_model // cfg.n_heads)
        rot_w = max(int(wide.head_dim * cfg.rope_fraction), 2)
        cos_w, sin_w = att.rope_angles(pos_abs[None], rot_w,
                                       cfg.rope_theta)

        def mamba_layer(x, lst):
            lp, h0, c0 = lst
            h = rmsnorm(lp["norm"], x, cfg.norm_eps)
            y, st = ssm_mod.ssm_block_apply(
                lp["ssm"], h, cfg, mode="decode",
                state={"ssm": h0, "conv": c0})
            return x + y, (st["ssm"], st["conv"])

        def group(x, g):
            gp, ad, h0, c0, k_c, v_c = g
            x, (h_new, c_new) = jax.lax.scan(mamba_layer, x,
                                             (gp, h0, c0))
            xx = jnp.concatenate([x, x0], axis=-1)
            hh = rmsnorm(sp["norm"], xx, cfg.norm_eps)
            q, k, v = att.qkv(sp["attn"], hh, wide)
            q = att.apply_rope(q, cos_w, sin_w, cfg.rope_fraction)
            k = att.apply_rope(k, cos_w, sin_w, cfg.rope_fraction)
            k_c = jax.lax.dynamic_update_slice_in_dim(
                k_c, k, pos_write, axis=1)
            v_c = jax.lax.dynamic_update_slice_in_dim(
                v_c, v, pos_write, axis=1)
            o = att.decode_attention(q, k_c, v_c, aux_len + 1, wide)
            o = o.reshape(b, 1, -1) @ sp["attn"]["wo"]
            x = x + o @ ad["w"]
            x = x + swiglu(sp["mlp"],
                           rmsnorm(sp["mlp_norm"], x, cfg.norm_eps))
            return x, (h_new, c_new, k_c, v_c)

        x, (ssm_new, conv_new, k_new, v_new) = jax.lax.scan(
            group, x,
            (params["groups"], params["adapters"], cache["ssm"],
             cache["conv"], cache["k"], cache["v"]))
        cache = dict(cache, ssm=ssm_new, conv=conv_new, k=k_new,
                     v=v_new)
        if "tail" in params:
            x, (th, tc) = jax.lax.scan(
                mamba_layer, x,
                (params["tail"], cache["tail_ssm"], cache["tail_conv"]))
            cache = dict(cache, tail_ssm=th, tail_conv=tc)
    elif fam == "vlm":
        pe = batch["patch_embeds"].astype(x.dtype)
        pe = pe @ params["patch_proj"]["w"]

        def self_layer(x, lkv):
            lp, k_c, v_c = lkv
            o, k_c, v_c = _decode_attn(lp, x, cfg, cos, sin, k_c, v_c,
                                       aux_len, pos_write)
            x = x + o
            return x + _mlp_block(lp, x, cfg), (k_c, v_c)

        def group(x, g):
            sl, cl, k_c, v_c = g
            x, (k_n, v_n) = jax.lax.scan(self_layer, x,
                                         (sl, k_c, v_c))
            kx, vx = _cross_kv(cl, pe, cfg)
            h = rmsnorm(cl["attn_norm"], x, cfg.norm_eps)
            q = (h @ cl["attn"]["wq"]).reshape(b, 1, cfg.n_heads,
                                               cfg.head_dim)
            o = att.attention(q, kx, vx, cfg, causal=False)
            o = o.reshape(b, 1, -1) @ cl["attn"]["wo"]
            x = x + jnp.tanh(cl["gate"]).astype(x.dtype) * o
            return x + _mlp_block(cl, x, cfg), (k_n, v_n)

        x, (k_new, v_new) = jax.lax.scan(
            group, x,
            (params["groups_self"], params["groups_cross"],
             cache["k"], cache["v"]))
        cache = dict(cache, k=k_new, v=v_new)
    else:
        raise ValueError(fam)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(params, x[:, 0])
    cache = dict(cache, len=cache["len"] + 1,
                 cursor=cache["cursor"] + 1, abs=cache["abs"] + 1)
    return logits, cache


# ---------------------------------------------------------------------------
# Paged KV cache decode (serving/kvcache.py block tables)
# ---------------------------------------------------------------------------

PAGED_FAMILIES = ("dense", "audio", "moe")


def init_paged_cache(cfg: ArchConfig, n_rows: int, page_size: int,
                     dtype=None, n_shards: int = 1) -> Dict[str, Any]:
    """Allocate the page-pool KV arrays.

    Single locality (``n_shards == 1``): (L, n_rows, ps, KV, D), where
    `n_rows` counts physical rows (the pool passes capacity + 1 so the
    last row can serve as the null page idle slots write into).

    Sharded pool (``n_shards > 1``, DESIGN.md §4c): one AGAS locality
    per KV shard — (L, n_shards, n_rows, ps, KV, D) with `n_rows` rows
    PER SHARD (pages_per_shard + 1; each shard carries its own local
    null page so an idle write never crosses localities).  Axis 1 is
    the locality axis the serving mesh shards over "kv".
    """
    if cfg.family not in PAGED_FAMILIES:
        raise ValueError(
            f"paged decode supports {PAGED_FAMILIES}, not {cfg.family!r}")
    dt = dtype or jnp.dtype(cfg.dtype)
    if n_shards > 1:
        shape = (cfg.n_layers, n_shards, n_rows, page_size,
                 cfg.n_kv_heads, cfg.head_dim)
    else:
        shape = (cfg.n_layers, n_rows, page_size, cfg.n_kv_heads,
                 cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def decode_step_paged(params: Params, pages: Dict[str, Any],
                      batch: Dict[str, Any], cfg: ArchConfig,
                      tp: int = 1, use_pallas: bool = False
                      ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step over block tables with per-slot position clocks.

    batch: tokens (B, 1); block_tables (B, P) int32 physical page
    rows; positions (B,) int32 per-slot absolute position of the new
    token (replaces the dense cache's shared len/cursor/abs clock);
    write_rows/write_offs (B,) int32 page slot the new K/V lands in
    (idle slots point at the pool's null row, which no mask ever
    reads).  Sliding windows are enforced as absolute-position masks —
    pages are never trimmed, so RoPE phases baked at write time stay
    valid.  Returns (logits (B, V) f32, new pages).

    Pages may be sharded across AGAS localities (DESIGN.md §4c):
    6-d ``pages["k"]`` of (L, n_shards, R, ps, KV, D) with block-table
    rows encoded ``locality * R + slot`` — the scatter and the gather
    both decode (locality, slot) so every page resolves on the shard
    that owns it.
    """
    if cfg.family not in PAGED_FAMILIES:
        raise ValueError(
            f"paged decode supports {PAGED_FAMILIES}, not {cfg.family!r}")
    if use_pallas:
        from repro.kernels.attention.ops import paged_attention
    else:
        from repro.kernels.attention.ref import \
            paged_attention_ref as paged_attention
    tokens = batch["tokens"]
    tables = batch["block_tables"]
    positions = batch["positions"]
    write_rows = batch["write_rows"]
    write_offs = batch["write_offs"]
    b = tokens.shape[0]
    x = embed_lookup(params["embed"], tokens)
    rot = int(cfg.head_dim * cfg.rope_fraction) if cfg.n_heads else 2
    # per-slot RoPE phases: (B, 1, rot/2) broadcasting over heads
    cos, sin = att.rope_angles(positions[:, None], max(rot, 2),
                               cfg.rope_theta)
    fam = cfg.family

    def layer(x, lkv):
        lp, kp, vp = lkv
        h = rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
        q, k, v = att.qkv(lp["attn"], h, cfg)
        q = att.apply_rope(q, cos, sin, cfg.rope_fraction)
        k = att.apply_rope(k, cos, sin, cfg.rope_fraction)
        # scatter the new token's K/V into each slot's write page
        if kp.ndim == 5:                 # sharded: (S, R, ps, KV, D)
            rps = kp.shape[1]
            wloc, wslot = write_rows // rps, write_rows % rps
            kp = kp.at[wloc, wslot, write_offs].set(k[:, 0])
            vp = vp.at[wloc, wslot, write_offs].set(v[:, 0])
        else:
            kp = kp.at[write_rows, write_offs].set(k[:, 0])
            vp = vp.at[write_rows, write_offs].set(v[:, 0])
        o = paged_attention(q, kp, vp, tables, positions,
                            window=cfg.sliding_window)
        x = x + o.reshape(b, 1, -1) @ lp["attn"]["wo"]
        if fam == "moe":
            hh = rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
            mo, _ = moe_mod.moe_apply(lp["moe"], hh, cfg, tp)
            x = x + mo
        else:
            x = x + _mlp_block(lp, x, cfg)
        return x, (kp, vp)

    x, (k_new, v_new) = jax.lax.scan(
        layer, x, (params["layers"], pages["k"], pages["v"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(params, x[:, 0])
    return logits, dict(pages, k=k_new, v=v_new)


def prefill_chunk(params: Params, pages: Dict[str, Any],
                  batch: Dict[str, Any], cfg: ArchConfig,
                  tp: int = 1, use_pallas: bool = False,
                  all_hidden: bool = False
                  ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Resumable chunked prefill: one page-aligned chunk of a prompt
    consumes and extends the paged KV cache (DESIGN.md §4b).

    batch: tokens (B, C) chunk tokens, right-padded to the fixed chunk
    width; block_tables (B, P) int32 physical page rows (pages of
    earlier chunks plus this chunk's freshly acquired pages); start
    (B,) int32 absolute position of tokens[:, 0] — page-aligned, equal
    to the tokens already resident for the slot; chunk_rows (B, C/ps)
    int32 physical rows this chunk's K/V pages are scattered into, with
    the pool's null row substituted for prefix-shared pages (their
    content already exists and must not be rewritten) and for pages
    past a partial final chunk; last_index () int32 chunk-local index
    whose hidden state feeds the returned logits (only meaningful on a
    prompt's final chunk; earlier chunks ignore it).

    Query t attends key positions <= start + t: causal over every
    earlier chunk's pages and within the chunk itself — the chunk's
    K/V is scattered into its pages *before* the gather, so one paged
    attention covers both.  Junk K/V from right-padding lands inside
    the final partial page beyond the slot's clock; masks never read
    it, and the first decode write overwrites it (same invariant as
    the whole-prompt attach path).  Returns (logits (B, V) f32, new
    pages); with ``all_hidden=True`` the post-norm hidden (B, C, D)
    replaces the logits (`last_index` ignored) — callers index the
    last position themselves and checkpoint the chunk's page-boundary
    activations for compute skip (§4e).
    """
    if cfg.family not in PAGED_FAMILIES:
        raise ValueError(
            f"paged prefill supports {PAGED_FAMILIES}, not {cfg.family!r}")
    if use_pallas:
        from repro.kernels.attention.ops import paged_prefill_attention
    else:
        from repro.kernels.attention.ref import \
            paged_prefill_attention_ref as paged_prefill_attention
    tokens = batch["tokens"]
    tables = batch["block_tables"]
    start = batch["start"]
    chunk_rows = batch["chunk_rows"]
    last_index = batch["last_index"]
    b, c = tokens.shape
    sharded = pages["k"].ndim == 6       # (L, S, R, ps, KV, D)
    ps = pages["k"].shape[3 if sharded else 2]
    assert c % ps == 0, f"chunk width {c} not page-aligned (ps={ps})"
    cp = c // ps
    x = embed_lookup(params["embed"], tokens)
    positions = start[:, None] + jnp.arange(c)[None, :]    # (B, C)
    rot = int(cfg.head_dim * cfg.rope_fraction) if cfg.n_heads else 2
    cos, sin = att.rope_angles(positions, max(rot, 2), cfg.rope_theta)
    fam = cfg.family

    def layer(x, lkv):
        lp, kp, vp = lkv
        h = rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
        q, k, v = att.qkv(lp["attn"], h, cfg)
        q = att.apply_rope(q, cos, sin, cfg.rope_fraction)
        k = att.apply_rope(k, cos, sin, cfg.rope_fraction)
        # scatter the chunk's K/V as whole pages (shared pages and the
        # tail of a partial chunk point at the null row)
        kw = k.reshape(b, cp, ps, *k.shape[2:]).astype(kp.dtype)
        vw = v.reshape(b, cp, ps, *v.shape[2:]).astype(vp.dtype)
        if sharded:
            rps = kp.shape[1]
            cloc, cslot = chunk_rows // rps, chunk_rows % rps
            kp = kp.at[cloc, cslot].set(kw)
            vp = vp.at[cloc, cslot].set(vw)
        else:
            kp = kp.at[chunk_rows].set(kw)
            vp = vp.at[chunk_rows].set(vw)
        o = paged_prefill_attention(q, kp, vp, tables, start,
                                    window=cfg.sliding_window)
        x = x + o.reshape(b, c, -1) @ lp["attn"]["wo"]
        if fam == "moe":
            hh = rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
            mo, _ = moe_mod.moe_apply(lp["moe"], hh, cfg, tp)
            x = x + mo
        else:
            x = x + _mlp_block(lp, x, cfg)
        return x, (kp, vp)

    x, (k_new, v_new) = jax.lax.scan(
        layer, x, (params["layers"], pages["k"], pages["v"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if all_hidden:
        return x, dict(pages, k=k_new, v=v_new)
    out = jax.lax.dynamic_index_in_dim(x, last_index, axis=1,
                                       keepdims=False)
    logits = logits_fn(params, out)
    return logits, dict(pages, k=k_new, v=v_new)


def resume_prefill(params: Params, hidden: jnp.ndarray) -> jnp.ndarray:
    """First-token logits from a cached last-position activation
    checkpoint (prefix-cache compute skip, DESIGN.md §4e).

    ``hidden`` (B, D) is the post-final-norm hidden state of a
    prompt's last position, checkpointed by an earlier prefill of the
    identical pad-free token sequence and stored in the page pool's
    prefix index
    alongside the KV pages.  A fully-covered prompt needs no
    transformer pass at all: its KV is resident in shared pages, and
    this one vocab projection reproduces the logits its own prefill
    would have computed.  Partial covers need no checkpoint —
    `prefill_chunk` is resumable from any page-aligned position given
    only the prefix KV pages.
    """
    return logits_fn(params, hidden)
