"""The compiled ParalleX engine: block pools + parcel halo exchange in
one XLA program (shard_map over the production mesh).

This is the TPU-native rendering of DESIGN.md §2: the dataflow LCO
graph of a window is erased into a static program where

  * AGAS placement  -> the (locality, slot) layout of the block pool
                       array (n_localities, slots, 3, grain);
  * parcels         -> `lax.ppermute` legs moving halo payloads between
                       localities (2 legs for contiguous placement);
  * LCO/dataflow    -> HLO data dependence between rounds;
  * HPX threads     -> vmap'd fused-RK3 block tasks (one batched kernel
                       launch per round — per-task overhead is zero).

The per-device pool axis is the "work queue": every round each locality
executes its `slots` resident tasks as one vectorized kernel.  With the
default contiguous AGAS placement only the pool-edge blocks exchange
inter-locality parcels, so the collective term is 2 * H * 3 * 4 bytes
per round per locality — the number the roofline analysis reports.

The uniform (single-level) configuration compiles for any mesh size and
is the AMR entry in the multi-pod dry-run; multi-level compiled
execution is represented by the measured-schedule engines (see
DESIGN.md §9 note 1).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.amr.wave import H, NFIELDS, WaveProblem, fused_rk3_block


@dataclasses.dataclass(frozen=True)
class CompiledAMRConfig:
    """Static layout: n_localities x slots blocks of `grain` points."""

    grain: int = 256
    slots: int = 8              # blocks resident per locality
    n_steps: int = 8            # steps fused into one program
    use_pallas: bool = False    # stencil kernel backend (kernels/stencil)
    # Communication-avoiding fusion (§Perf hillclimb, AMR cell): carry
    # a 3k-cell halo and take k RK3 steps per parcel exchange.  Parcels
    # per step drop k-fold and the block stays VMEM-resident across the
    # k steps (HBM term ~ 1/k); extra compute is the shrinking-halo
    # overlap, fraction ~ 3k(k+1)/grain.
    steps_per_exchange: int = 1

    def n_blocks(self, n_loc: int) -> int:
        return n_loc * self.slots

    def n_points(self, n_loc: int) -> int:
        return self.n_blocks(n_loc) * self.grain


def _block_step_vmapped(pool_ext: jnp.ndarray, r_ext: jnp.ndarray,
                        left_phys: jnp.ndarray, right_phys: jnp.ndarray,
                        dr: float, dt: float, p: int,
                        use_pallas: bool) -> jnp.ndarray:
    """(slots, 3, g+2H) -> (slots, 3, g), one fused RK3 per resident block."""
    if use_pallas:
        from repro.kernels.stencil.ops import stencil_rk3_step
        return stencil_rk3_step(pool_ext, r_ext, left_phys, right_phys,
                                dr=dr, dt=dt, p=p)
    fn = lambda u, r, lp, rp: fused_rk3_block(u, r, dr, dt, p, lp, rp)
    return jax.vmap(fn)(pool_ext, r_ext, left_phys, right_phys)


def make_uniform_step(prob: WaveProblem, cfg: CompiledAMRConfig,
                      mesh: Mesh, axis_names: Tuple[str, ...]):
    """Build the shard_map'd n-step evolution for a uniform grid.

    Returns (step_fn, make_inputs, sharding) where step_fn(pool) -> pool
    advances cfg.n_steps steps.  pool has shape
    (n_localities, slots, NFIELDS, grain) sharded over axis 0.
    """
    n_loc = int(np.prod([mesh.shape[a] for a in axis_names]))
    g = cfg.grain
    S = cfg.slots
    n_pts = cfg.n_points(n_loc)
    dr = prob.rmax / (n_pts - 1)
    dt = prob.cfl * dr
    dtype = prob.jnp_dtype()

    spec = P(axis_names)  # leading dim sharded over all given axes
    sharding = NamedSharding(mesh, spec)

    K = cfg.steps_per_exchange
    HK = H * K
    if cfg.n_steps % K:
        raise ValueError("n_steps must be a multiple of "
                         "steps_per_exchange")
    if HK > g:
        raise ValueError("halo exceeds grain: lower steps_per_exchange")

    def local_step(pool: jnp.ndarray) -> jnp.ndarray:
        """Per-locality body: one exchange + K fused RK3 steps.

        pool: (1, S, 3, g) (sharded block).
        """
        pool = pool[0]                       # (S, 3, g)
        loc = lax.axis_index(axis_names)     # flattened locality id

        # --- parcels: pool-edge halo exchange (2 ppermute legs) -------
        # Right-moving leg: my last block's right edge -> next locality.
        right_edge = pool[-1, :, -HK:]       # (3, HK)
        left_edge = pool[0, :, :HK]
        fwd = [(i, (i + 1) % n_loc) for i in range(n_loc)]
        bwd = [((i + 1) % n_loc, i) for i in range(n_loc)]
        from_left = lax.ppermute(right_edge, axis_names, fwd)
        from_right = lax.ppermute(left_edge, axis_names, bwd)

        # --- assemble extended blocks (S, 3, g+2HK) --------------------
        # Intra-locality halos come from pool neighbours (an AGAS-local
        # lookup); the pool boundary slots splice in the parcels.
        lefts = jnp.concatenate(
            [from_left[None], pool[:-1, :, -HK:]], axis=0)
        rights = jnp.concatenate(
            [pool[1:, :, :HK], from_right[None]], axis=0)
        u = jnp.concatenate([lefts, pool, rights], axis=-1)

        # --- physical-boundary masks ----------------------------------
        slot_ids = jnp.arange(S)
        left_phys = (loc == 0) & (slot_ids == 0)
        right_phys = (loc == n_loc - 1) & (slot_ids == S - 1)

        # --- radial coordinates per block -----------------------------
        blk0 = (loc * S + slot_ids) * g       # (S,) global start index
        r_full = (blk0[:, None] +
                  jnp.arange(-HK, g + HK, dtype=dtype)[None, :]) * dr

        # --- K fused steps, validity shrinking by H per side ----------
        for i in range(K):
            r_ext = r_full[:, H * i: r_full.shape[1] - H * i]
            u = _block_step_vmapped(
                u, r_ext, left_phys[:, None, None],
                right_phys[:, None, None], dr, dt, prob.p,
                cfg.use_pallas)
        return u[None]                        # (1, S, 3, g)

    from repro.distributed.compat import shard_map
    inner = shard_map(local_step, mesh=mesh, in_specs=(spec,),
                      out_specs=spec, check=False)

    def step_fn(pool: jnp.ndarray) -> jnp.ndarray:
        def body(p_, _):
            return inner(p_), None
        out, _ = lax.scan(body, pool, None, length=cfg.n_steps // K)
        return out

    def make_inputs() -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct((n_loc, S, NFIELDS, g), dtype,
                                    sharding=sharding)

    def initial_pool() -> jnp.ndarray:
        """Concrete initial data laid out into the pool (host-side)."""
        from repro.amr.wave import initial_data
        u = initial_data(prob, level_dr=dr, n=n_pts)     # (3, n_pts)
        blocks = u.reshape(NFIELDS, n_loc, S, g)
        return jnp.transpose(blocks, (1, 2, 0, 3))

    def to_global(pool: jnp.ndarray) -> jnp.ndarray:
        return jnp.transpose(pool, (2, 0, 1, 3)).reshape(NFIELDS, n_pts)

    return step_fn, make_inputs, initial_pool, to_global, sharding, dict(
        n_loc=n_loc, grain=g, slots=S, n_points=n_pts, dr=dr, dt=dt)


def reference_uniform(prob: WaveProblem, n_pts: int, n_steps: int,
                      dr: float, dt: float) -> jnp.ndarray:
    """Global jnp oracle for the compiled engine (tests)."""
    from repro.amr.wave import global_step, initial_data

    u = initial_data(prob, level_dr=dr, n=n_pts)
    r = jnp.arange(n_pts, dtype=u.dtype) * dr
    for _ in range(n_steps):
        u = global_step(u, r, dr, dt, prob.p)
    return u
