"""Low-overhead ring-buffer tracer with causal ids and Chrome export.

The runtime has five interacting subsystems (engine scheduler, paged
pool, sharded AGAS, percolation tiering, prefix-cache skip); this module
gives them one shared event stream.  Two record shapes:

- **span**: a timed interval (``dur`` seconds) opened/closed via the
  ``span(...)`` context manager.  Spans carry a ``kind`` used by
  overhead attribution ("compute", "sched", "pages", "parcel", "copy").
- **instant**: a point event (``dur is None``) — page allocs, LCO sets,
  parcel sends, slot binds.

Causal ids ride in ``args``: engine events carry ``rid`` (request),
``slot``; kvcache events carry ``slot`` and ``gid``/``gids`` (AGAS page
names); parcel/percolation events carry the ``gids`` they move.  Because
AGAS gids are never recycled (itertools counter), a gid is a globally
unique causal id and "dangling" is decidable from the event stream alone
(see ``obs.attribution.check_causal``).

Parent links come from a per-thread span stack: a record's ``parent`` is
the sid of the innermost open span *of the same tracer* on this thread
at the time the record was opened.  Records land in a preallocated ring
(oldest evicted first, ``dropped`` counts evictions) so memory stays
O(capacity) over arbitrarily long runs.

Disabled tracing is the ``NULL_TRACER`` singleton: every call is a
constant-time no-op (no clock read, no allocation beyond the call
itself).  Free-standing subsystems that have no constructor path for a
tracer (``core.lco``, ``core.parcels``, ``core.agas``) emit through the
module-global ``GLOBAL``, rebindable via ``set_global`` — attribute
lookup at call time, so rebinding takes effect immediately.
"""

import json
import threading
import time

__all__ = [
    "Span",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "GLOBAL",
    "set_global",
    "get_global",
]


class Span:
    """One trace record.  ``dur is None`` marks an instant event."""

    __slots__ = ("sid", "parent", "subsystem", "name", "kind", "lane",
                 "t0", "dur", "args")

    def __init__(self, sid, parent, subsystem, name, kind, lane, t0,
                 dur, args):
        self.sid = sid
        self.parent = parent
        self.subsystem = subsystem
        self.name = name
        self.kind = kind
        self.lane = lane
        self.t0 = t0
        self.dur = dur
        self.args = args

    def __repr__(self):
        shape = "instant" if self.dur is None else f"dur={self.dur:.6f}"
        return (f"Span(sid={self.sid}, {self.subsystem}/{self.name}, "
                f"t0={self.t0:.6f}, {shape}, parent={self.parent})")


class _SpanCtx:
    """Context manager opening/closing one span on a live tracer."""

    __slots__ = ("_tr", "_rec")

    def __init__(self, tr, rec):
        self._tr = tr
        self._rec = rec

    def __enter__(self):
        tr = self._tr
        rec = self._rec
        stack = tr._stack()
        rec.parent = stack[-1].sid if stack else None
        rec.t0 = tr.clock()
        stack.append(rec)
        return rec

    def __exit__(self, exc_type, exc, tb):
        tr = self._tr
        rec = self._rec
        rec.dur = tr.clock() - rec.t0
        stack = tr._stack()
        if stack and stack[-1] is rec:
            stack.pop()
        tr._append(rec)
        return False


class _NullSpan:
    """Returned by NullTracer.span().__enter__; absorbs arg mutation."""

    __slots__ = ("args",)

    def __init__(self):
        self.args = {}


class _NullCtx:
    __slots__ = ("_span",)

    def __init__(self):
        self._span = _NullSpan()

    def __enter__(self):
        return self._span

    def __exit__(self, exc_type, exc, tb):
        return False


class NullTracer:
    """Disabled tracer: every call is a constant-time no-op."""

    enabled = False
    dropped = 0
    clock = staticmethod(time.perf_counter)

    def __init__(self):
        self._ctx = _NullCtx()

    def span(self, subsystem, name, kind=None, lane=None, **args):
        return self._ctx

    def instant(self, subsystem, name, kind=None, lane=None, **args):
        return None

    def records(self):
        return []

    def clear(self):
        return None


NULL_TRACER = NullTracer()


class Tracer:
    """Ring-buffer tracer.  ``capacity`` bounds retained records."""

    enabled = True

    def __init__(self, capacity=65536, clock=None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.clock = clock if clock is not None else time.perf_counter
        self._buf = [None] * capacity
        self._n = 0          # total records ever appended
        self._sid = 0
        self._lock = threading.Lock()
        self._tls = threading.local()

    # -- internals -------------------------------------------------------

    def _stack(self):
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = []
            self._tls.stack = st
        return st

    def _next_sid(self):
        with self._lock:
            self._sid += 1
            return self._sid

    def _append(self, rec):
        with self._lock:
            self._buf[self._n % self.capacity] = rec
            self._n += 1

    # -- recording API ---------------------------------------------------

    def span(self, subsystem, name, kind=None, lane=None, **args):
        rec = Span(self._next_sid(), None, subsystem, name, kind, lane,
                   0.0, None, args)
        return _SpanCtx(self, rec)

    def instant(self, subsystem, name, kind=None, lane=None, **args):
        stack = self._stack()
        parent = stack[-1].sid if stack else None
        rec = Span(self._next_sid(), parent, subsystem, name, kind,
                   lane, self.clock(), None, args)
        self._append(rec)
        return rec

    # -- inspection ------------------------------------------------------

    @property
    def dropped(self):
        return max(0, self._n - self.capacity)

    def records(self):
        """Retained records, oldest first (append order)."""
        with self._lock:
            n, cap = self._n, self.capacity
            if n <= cap:
                return [r for r in self._buf[:n]]
            i = n % cap
            return self._buf[i:] + self._buf[:i]

    def clear(self):
        with self._lock:
            self._buf = [None] * self.capacity
            self._n = 0

    # -- Chrome trace-event export ---------------------------------------

    def to_chrome(self):
        """Records as a Chrome trace-event dict (perfetto-viewable).

        One process (pid) per subsystem, one thread (tid) per lane within
        it (lane None -> "main").  Spans become "X" complete events with
        microsecond ts/dur relative to the earliest record; instants
        become thread-scoped "i" events.  Causal args (rid/slot/gid/...)
        and the span sid/parent ride in each event's ``args`` so links
        survive the export.
        """
        recs = self.records()
        events = []
        pids = {}
        tids = {}
        tbase = min((r.t0 for r in recs), default=0.0)
        for r in recs:
            pid = pids.get(r.subsystem)
            if pid is None:
                pid = pids[r.subsystem] = len(pids) + 1
                events.append({"ph": "M", "name": "process_name",
                               "pid": pid, "tid": 0,
                               "args": {"name": r.subsystem}})
            lane = "main" if r.lane is None else str(r.lane)
            tid = tids.get((pid, lane))
            if tid is None:
                tid = tids[(pid, lane)] = \
                    len([k for k in tids if k[0] == pid]) + 1
                events.append({"ph": "M", "name": "thread_name",
                               "pid": pid, "tid": tid,
                               "args": {"name": lane}})
            args = dict(r.args)
            args["sid"] = r.sid
            if r.parent is not None:
                args["parent"] = r.parent
            if r.kind is not None:
                args["kind"] = r.kind
            ev = {"name": r.name, "cat": r.subsystem, "pid": pid,
                  "tid": tid, "ts": (r.t0 - tbase) * 1e6, "args": args}
            if r.dur is None:
                ev["ph"] = "i"
                ev["s"] = "t"
            else:
                ev["ph"] = "X"
                ev["dur"] = r.dur * 1e6
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome(self, path):
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


# Free-standing subsystems (lco, parcels, agas) trace through this
# global; call sites read it by attribute so set_global takes effect
# immediately.  Default is the null tracer: zero overhead when off.
GLOBAL = NULL_TRACER


def set_global(tracer):
    global GLOBAL
    GLOBAL = tracer if tracer is not None else NULL_TRACER
    return GLOBAL


def get_global():
    return GLOBAL
