"""Explicit collective schedules: hierarchical pod-aware reduction and
int8 error-feedback gradient compression.

GSPMD already fuses gradient reductions into the backward pass; these
utilities exist for the cases where the AUTOMATIC schedule is the
bottleneck (the §Perf hillclimb lever):

* `hierarchical_psum` — reduce-scatter inside the pod (fast ICI),
  all-reduce the shards across pods (thin inter-pod links carry 1/16th
  of the bytes), all-gather inside the pod.  This is the classic
  two-level schedule for multi-pod DP; wire bytes across pods drop by
  the in-pod shard factor.

* `compressed_cross_pod_psum` — int8-quantized cross-pod all-reduce
  with error feedback (the residual of quantization is added to the
  next step's gradient), cutting inter-pod bytes 4x vs f32 at bounded
  bias.  Paper tie-in: gradient parcels are payload-compressed.

Both are shard_map building blocks; tests/test_collectives.py runs them
on an 8-device host mesh in a subprocess and checks exactness /
error-feedback convergence.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def hierarchical_psum(x: jnp.ndarray, pod_axis: str, data_axis: str
                      ) -> jnp.ndarray:
    """psum over (pod, data) as RS(data) -> AR(pod) -> AG(data).

    Must be called inside shard_map with both axes bound.  x is
    replicated-per-device input (e.g. a gradient shard); returns the
    full sum on every device.  The first dim must divide the data-axis
    size.
    """
    xs = lax.psum_scatter(x, data_axis, scatter_dimension=0,
                          tiled=True)
    xs = lax.psum(xs, pod_axis)
    return lax.all_gather(xs, data_axis, axis=0, tiled=True)


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_cross_pod_psum(
        x: jnp.ndarray, err: jnp.ndarray, pod_axis: str,
        data_axis: Optional[str] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8 + error-feedback psum over the pod axis.

    x:   this pod's (already data-reduced) gradient shard, f32.
    err: carried quantization residual (same shape), f32.

    Returns (summed gradient, new residual).  The residual guarantees
    the LONG-RUN sum is unbiased (error-feedback SGD analysis).
    """
    if data_axis is not None:
        x = lax.psum_scatter(x, data_axis, scatter_dimension=0,
                             tiled=True)
    comp_in = x + err
    q, scale = quantize_int8(comp_in)
    deq = dequantize_int8(q, scale)
    new_err = comp_in - deq
    # int8 payload summed across pods: sum of dequantized values (each
    # pod contributes its own scale, so exchange dequantized int8 —
    # the wire format is int8 + one f32 scale).
    summed = lax.psum(deq, pod_axis)
    if data_axis is not None:
        summed = lax.all_gather(summed, data_axis, axis=0, tiled=True)
    return summed, new_err


def ring_halo_exchange(edge_left: jnp.ndarray, edge_right: jnp.ndarray,
                       axis: str, n: int
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The AMR parcel pattern as a reusable primitive: send my right
    edge to the next locality, my left edge to the previous."""
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [((i + 1) % n, i) for i in range(n)]
    from_left = lax.ppermute(edge_right, axis, fwd)
    from_right = lax.ppermute(edge_left, axis, bwd)
    return from_left, from_right


def make_hierarchical_grad_reducer(mesh: Mesh):
    """shard_map-wrapped tree reducer for multi-pod gradient sync.

    Maps `hierarchical_psum` over every leaf of a gradient pytree whose
    leaves are replicated within (pod, data) — the manual alternative
    schedule benchmarked in the §Perf log.
    """
    if "pod" not in mesh.axis_names:
        raise ValueError("hierarchical reduction needs a pod axis")

    def reduce_tree(grads):
        def one(g):
            flat = g.reshape(-1)
            pad = (-flat.shape[0]) % mesh.shape["data"]
            flat = jnp.pad(flat, (0, pad))
            out = hierarchical_psum(flat, "pod", "data")
            return out[:g.size].reshape(g.shape)
        from repro.distributed.compat import shard_map
        fn = shard_map(
            lambda t: jax.tree.map(one, t), mesh=mesh,
            in_specs=P(), out_specs=P(), check=False)
        return fn(grads)

    return reduce_tree
