"""Locality loss and recovery in the serving stack (DESIGN.md §4g).

The resilience contract under test: killing a KV shard with requests
in flight loses NO request and changes NO token.  Pages with a
host-tier percolation copy are rebuilt on a surviving shard; pages
without one are lost and their requests drained — re-admitted at the
queue front with generated tokens retained, futures left pending —
and re-prefilled (position-normalized layouts make the replay exact).
Elastic membership rides the same machinery: a planned retire
evacuates instead of losing, a join re-admits the shard and
rebalances toward it.

Also here: the failure-path regression tests this PR's chaos audit
produced — a kill racing the covered-prefix window between
`covered_prefix` and `attach_covered` (the purged-index walk must
raise, never hand back freed pages), and a kill landing on a staged
prefill->decode handoff snapshot (drain must skip the dead pages'
refcounts, not double-return them).

Hypothesis-free by design: `tools/assert_no_skips.py` lists this
module, so every test here must run everywhere.
"""

from functools import lru_cache

import numpy as np
import pytest
import jax

import repro.configs as configs
from repro.core.agas import AGAS, AGASError
from repro.core.localities import LocalityDomain
from repro.ft.failures import FailurePlan, InjectedFailure
from repro.ft.supervisor import RecoveryBudget
from repro.models import transformer as T
from repro.serving.engine import Request, make_engine
from repro.serving.kvcache import PageExhausted

SLOTS = 3
MAX_LEN = 96
PAGE = 16
CHUNK = 32
MAX_NEW = 6


@lru_cache(maxsize=1)
def _setup():
    cfg = configs.get_reduced("yi-6b")
    return cfg, T.init_params(jax.random.PRNGKey(0), cfg)


def _engine(**kw):
    cfg, params = _setup()
    base = dict(engine="chunked", slots=SLOTS, max_len=MAX_LEN,
                prefill_buckets=(32,), page_size=PAGE,
                chunk_size=CHUNK)
    base.update(kw)
    return make_engine(params, cfg, **base)


@lru_cache(maxsize=1)
def _prompts():
    """Four mixed-length prompts behind one shared 16-token head (a
    shared page, so prefix sharing is part of the chaos surface)."""
    cfg, _ = _setup()
    rng = np.random.default_rng(5)
    head = rng.integers(0, cfg.vocab_size, size=16)
    out = []
    for i in range(4):
        tail = rng.integers(0, cfg.vocab_size, size=8 + 4 * i)
        out.append(np.concatenate([head, tail]).astype(np.int32))
    return tuple(out)


@lru_cache(maxsize=1)
def _reference():
    """Failure-free ample-pool single-shard greedy tokens per prompt
    index — the ground truth every chaos schedule must reproduce."""
    eng = _engine(n_pages=24)
    futs = [eng.submit(Request(100 + i, p, max_new_tokens=MAX_NEW))
            for i, p in enumerate(_prompts())]
    eng.run_to_completion()
    return {i: f.get().tokens for i, f in enumerate(futs)}


# -- the trigger and the budget ----------------------------------------

def test_failure_plan_kill_trigger_fires_once():
    plan = FailurePlan.kill_locality(1, at_step=3)
    killed = set()
    assert plan.shard_to_kill(2, killed) is None
    assert plan.shard_to_kill(3, killed) == 1
    assert plan.shard_to_kill(3, killed) is None     # once per pair
    # serving kills never raise: check() is the training-side trigger
    plan.check(3, set())


def test_recovery_budget_exhaustion():
    budget = RecoveryBudget(max_restarts=2)
    budget.spend("locality 1 loss")
    budget.spend("locality 0 loss")
    with pytest.raises(InjectedFailure, match="budget exhausted"):
        budget.spend("locality 1 loss")


# -- AGAS locality lifecycle -------------------------------------------

def test_agas_locality_lifecycle():
    agas = AGAS(LocalityDomain.simulated(2), 4)
    held = agas.allocate(1)
    agas.deactivate(1)
    assert not agas.is_active(1)
    with pytest.raises(AGASError, match="retired"):
        agas.allocate(1)
    assert agas.least_loaded() == 0          # placement skips retired
    other = agas.allocate(0)
    with pytest.raises(AGASError, match="retired"):
        agas.migrate(other, 1)
    # a kill sweep can still return slots to a retired pool, and a
    # later join finds the free list intact — no directory rebuild
    agas.free(held)
    assert agas.resident_on(other.gid, 0)
    assert not agas.resident_on(held.gid, 1)        # freed -> dangling
    agas.activate(1)
    assert agas.allocate(1).gid != held.gid


def test_agas_least_loaded_raises_when_tier_is_dead():
    agas = AGAS(LocalityDomain.simulated(2), 4)
    agas.deactivate(0)
    agas.deactivate(1)
    with pytest.raises(AGASError, match="no active locality"):
        agas.least_loaded(tier=0)


# -- kill mid-wave: drain + re-prefill (no host tier) ------------------

def test_kill_mid_wave_untiered_token_identity():
    ref = _reference()
    eng = _engine(kv_shards=2, n_pages=12)
    futs = [eng.submit(Request(200 + i, p, max_new_tokens=MAX_NEW))
            for i, p in enumerate(_prompts())]
    for _ in range(3):
        eng.step()
    assert eng.active                    # the kill lands mid-wave
    eng.kill_locality(1)
    eng.run_to_completion()
    for i, fut in enumerate(futs):
        assert fut.get().tokens == ref[i]
    rec = eng.stats()["recovery"]
    assert rec["localities_killed"] == 1
    assert rec["pages_lost"] > 0         # untiered: nothing to rebuild
    assert rec["pages_rebuilt"] == 0
    assert rec["drained_slots"] > 0
    assert rec["re_prefills"] >= rec["drained_slots"]
    assert rec["recovery_restarts"] == 1
    assert eng.kvc.pool.used_pages == 0


def test_failure_plan_fires_through_step_disagg_tiered():
    """The full §4g stack: disagg + tiering + 2 shards, the kill
    scheduled through the engine's failure plan instead of called by
    hand — the serve_bench --chaos composition in miniature."""
    ref = _reference()
    eng = _engine(kv_shards=2, n_pages=12, tiering=True, host_pages=48,
                  disagg=True,
                  failure_plan=FailurePlan.kill_locality(1, at_step=2))
    futs = [eng.submit(Request(250 + i, p, max_new_tokens=MAX_NEW))
            for i, p in enumerate(_prompts())]
    eng.run_to_completion()
    for i, fut in enumerate(futs):
        assert fut.get().tokens == ref[i]
    rec = eng.stats()["recovery"]
    assert rec["localities_killed"] == 1
    assert rec["recovery_restarts"] == 1
    assert eng.kvc.pool.used_pages == 0


# -- host-tier rebuild: the percolation copy pays off -------------------

def test_tiered_kill_rebuilds_from_host_shadow():
    """A page that percolated through the host tier leaves a shadow
    copy; killing its shard rebuilds it on a survivor byte-identically
    instead of re-prefilling its request."""
    ref = _reference()
    eng = _engine(kv_shards=2, n_pages=12, tiering=True, host_pages=48)
    fut = eng.submit(Request(300, _prompts()[0],
                             max_new_tokens=MAX_NEW))
    for _ in range(2):
        eng.step()
    assert eng.active
    slot = next(iter(eng.active))
    eng._preempt(slot)                   # KV written back to host
    assert eng.offloads == 1
    for _ in range(10):                  # restore promotes the pages
        eng.step()                       # back (capturing shadows)
        if eng.restores:
            break
    assert eng.restores == 1
    slot = next(iter(eng.active))
    addrs = eng.kvc._state[slot].addrs
    victim = eng.kvc.pool.agas.locality_of(addrs[0])
    eng.kill_locality(victim)
    eng.run_to_completion()
    assert fut.get().tokens == ref[0]
    rec = eng.stats()["recovery"]
    assert rec["pages_rebuilt"] > 0      # the shadow was used
    assert eng.kvc.pool.used_pages == 0


# -- staged-handoff drop path (this PR's chaos-audit repro) ------------

def test_kill_during_staged_handoff_drains_cleanly():
    """A locality dies while prefill->decode handoff snapshots are
    staged on the percolation queue.  The drained snapshot's refcounts
    on LOST pages died with the pages — returning them again would
    corrupt the pool — while surviving pages must still be decref'd
    exactly once.  Requests finish token-identically; the pool drains
    to zero."""
    ref = _reference()
    # 16-token chunks: every prompt spans several chunks, so slots
    # are reliably mid-prefill when the drill stages handoffs
    eng = _engine(kv_shards=2, n_pages=12, disagg=True, chunk_size=16)
    futs = [eng.submit(Request(400 + i, p, max_new_tokens=MAX_NEW))
            for i, p in enumerate(_prompts()[:3])]
    staged = None
    for _ in range(20):
        eng.step()
        if eng.force_handoff():          # stage mid-prefill handoffs
            staged = next(s for s, st in eng.active.items()
                          if st.get("phase") == "handoff")
            break
    assert staged is not None, "no prefilling slot to stage"
    snap = eng.active[staged]["snap"]
    victim = eng.kvc.pool.agas.locality_of(snap.addrs[0])
    eng.kill_locality(victim)
    assert "snap" not in eng.active.get(staged, {})
    eng.run_to_completion()
    for i, fut in enumerate(futs):
        assert fut.get().tokens == ref[i]
    assert eng.kvc.pool.used_pages == 0


# -- covered-prefix vs a dying owner (this PR's chaos-audit repro) -----

def test_kill_between_cover_lookup_and_attach():
    """`covered_prefix` computes a cover, the owner shard dies, and
    only then does `attach_covered` run.  The kill purges every swept
    page through `_purge_index`, so the attach's re-probe must miss
    and raise `PageExhausted` — handing back a freed page would serve
    another request's (or garbage) KV."""
    eng = _engine(kv_shards=2, n_pages=12, tiering=True, host_pages=48,
                  prefix_cache_compute=True)
    prompt = _prompts()[0]
    fut = eng.submit(Request(500, prompt, max_new_tokens=MAX_NEW))
    eng.run_to_completion()
    want = fut.get().tokens
    kvc = eng.kvc
    layout = np.asarray(prompt, np.int32)
    cov = kvc.covered_prefix(layout)
    assert cov.covered > 0               # retained-cold prefix pages
    owner = kvc.pool.agas.locality_of(
        kvc.pool.lookup_prefix(cov.keys[0]))
    used = kvc.pool.used_pages
    kvc.pool.kill_locality(owner)        # the race window closes here
    slot = eng.free_slots[0]
    with pytest.raises(PageExhausted):
        kvc.attach_covered(slot, layout, cov.keys)
    assert not kvc._state[slot].addrs    # rollback left nothing bound
    assert kvc.pool.used_pages == used   # and leaked no refcount
    # the engine still serves the same prompt identically afterwards
    eng.join_locality(owner)
    fut2 = eng.submit(Request(501, prompt, max_new_tokens=MAX_NEW))
    eng.run_to_completion()
    assert fut2.get().tokens == want
    assert eng.kvc.pool.used_pages == 0


# -- elastic membership: planned retire / join --------------------------

def test_elastic_retire_and_join_token_identity():
    ref = _reference()
    eng = _engine(kv_shards=2, n_pages=24)
    futs = [eng.submit(Request(600 + i, p, max_new_tokens=MAX_NEW))
            for i, p in enumerate(_prompts())]
    eng.step()
    eng.step()
    assert eng.active
    eng.retire_locality(1)               # planned: evacuate, lose none
    assert not eng.kvc.pool.agas.is_active(1)
    assert eng.kvc.pool.shard_used()[1] == 0
    eng.step()
    moved_back = eng.join_locality(1)    # rebalance toward the joiner
    assert eng.kvc.pool.agas.is_active(1)
    assert moved_back > 0
    eng.run_to_completion()
    for i, fut in enumerate(futs):
        assert fut.get().tokens == ref[i]
    rec = eng.stats()["recovery"]
    assert rec["pages_lost"] == 0        # elastic, not lossy
    assert rec["drained_slots"] == 0
    assert eng.kvc.pool.used_pages == 0


def test_retire_sole_survivor_refuses():
    eng = _engine(kv_shards=2, n_pages=12)
    eng.retire_locality(1)
    with pytest.raises(PageExhausted, match="no surviving"):
        eng.retire_locality(0)
    assert eng.kvc.pool.agas.is_active(0)    # nothing committed
