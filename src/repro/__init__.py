"""repro: A ParalleX/HPX-style execution-model framework in JAX.

Reproduction (and TPU-native extension) of
"An Application Driven Analysis of the ParalleX Execution Model"
(Anderson, Brodowicz, Kaiser, Sterling; 2011).

Layers
------
core/         ParalleX model: LCOs (futures, dataflow), parcels, AGAS,
              localities, the dataflow scheduler (DAG -> compiled rounds +
              work-queue simulator), task-granularity control.
amr/          The paper's application: 1+1D Berger-Oliger AMR for the
              semilinear wave equation (p=7), with barrier (CSP/MPI-style)
              and barrier-free (dataflow) engines.
models/       Assigned LM-architecture pool (dense/GQA/SWA, MoE, SSM, hybrid,
              audio/VLM backbones).
kernels/      Pallas TPU kernels (stencil RK3 update, flash attention,
              selective scan) with jnp oracles.
distributed/  Sharding rules, hierarchical collectives, gradient compression.
optim/ data/ checkpoint/ ft/ serving/   Substrate.
configs/      Assigned architecture configs + the paper's AMR config.
launch/       Mesh construction, multi-pod dry-run, train/serve drivers.
"""

__version__ = "1.0.0"
