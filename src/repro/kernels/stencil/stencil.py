"""Pallas TPU kernel: fused RK3 semilinear-wave block step.

The paper's task body (one AMR block update) as a TPU kernel: all three
RK stages execute on a block resident in VMEM, so HBM traffic per task
is exactly one read of (3, g+2H) and one write of (3, g) — the
communication-avoiding property that motivated fusing the stages in the
first place (amr/wave.py).

Tiling: grid = (n_blocks,); each program owns one block.
  in  : u_ext (1, 3, g+2H) VMEM   r_ext (1, g+2H) VMEM
        flags (1, 2) VMEM (left_phys, right_phys as 0/1)
  out : (1, 3, g) VMEM

The physics matches amr/wave.fused_rk3_block bit-for-bit in interpret
mode (tests/test_kernels.py sweeps shapes and dtypes against ref.py).
TPU target notes: g should be a multiple of 128 (lane width); the three
stages are elementwise + shifts, so the kernel is VPU-bound — the win
is HBM avoidance, not MXU utilization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

H = 3  # halo width (1 stencil radius x 3 RK stages)


def _rhs(u, r, dr, p):
    """RHS on a (3, W) VMEM block via rolls.

    The two wrap-around edge cells per stage are garbage; they reach at
    most `stage` cells inward, all discarded by the final [H:-H] slice
    (or overwritten by the physical-ghost refresh) — the same validity
    argument as the fused jnp version.  Roll keeps the kernel free of
    captured array constants (a pallas_call restriction).
    """
    chi, phi, pi = u[0], u[1], u[2]

    def ctr(v):
        return (jnp.roll(v, -1) - jnp.roll(v, 1)) / (2.0 * dr)

    near = jnp.abs(r) < 0.5 * dr
    safe = jnp.where(near, 1.0, r * r)
    mono = jnp.where(near, 3.0 * ctr(phi), ctr(r * r * phi) / safe)
    return jnp.stack([pi, ctr(pi), mono + chi ** p])


def _refresh(u, left, right):
    w = u.shape[-1]
    # mirror about index H (r=0): ghost columns [0:H] <- columns
    # [H+1 : 2H+1] reversed, with (+, -, +) parity.
    mir = u[:, H + 1:2 * H + 1][:, ::-1]
    lvals = jnp.stack([mir[0], -mir[1], mir[2]])
    u = jnp.where(left, jnp.concatenate([lvals, u[:, H:]], axis=-1), u)
    last = u[:, w - H - 1]
    slope = last - u[:, w - H - 2]
    rvals = jnp.stack([last + (k + 1.0) * slope for k in range(H)],
                      axis=-1)
    u = jnp.where(right,
                  jnp.concatenate([u[:, :w - H], rvals], axis=-1), u)
    return u


def _kernel(u_ref, r_ref, flags_ref, o_ref, *, dr, dt, p):
    u = u_ref[0]                       # (3, W)
    r = r_ref[0]                       # (W,)
    left = flags_ref[0, 0] > 0
    right = flags_ref[0, 1] > 0
    u0 = _refresh(u, left, right)
    u1 = u0 + dt * _rhs(u0, r, dr, p)
    u1 = _refresh(u1, left, right)
    u2 = 0.75 * u0 + 0.25 * (u1 + dt * _rhs(u1, r, dr, p))
    u2 = _refresh(u2, left, right)
    u3 = u0 / 3.0 + (2.0 / 3.0) * (u2 + dt * _rhs(u2, r, dr, p))
    u3 = _refresh(u3, left, right)
    o_ref[0] = u3[:, H:-H]


def stencil_rk3(u_ext: jnp.ndarray, r_ext: jnp.ndarray,
                flags: jnp.ndarray, *, dr: float, dt: float, p: int,
                interpret: bool = True) -> jnp.ndarray:
    """u_ext: (nb, 3, g+2H); r_ext: (nb, g+2H); flags: (nb, 2) int32.

    Returns (nb, 3, g).
    """
    nb, _, w = u_ext.shape
    g = w - 2 * H
    kern = functools.partial(_kernel, dr=u_ext.dtype.type(dr),
                             dt=u_ext.dtype.type(dt), p=p)
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 3, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, w), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3, g), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 3, g), u_ext.dtype),
        interpret=interpret,
    )(u_ext, r_ext, flags)
