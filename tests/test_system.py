"""End-to-end behaviour tests for the paper's system.

1. The flagship claim chain: barrier-free dataflow AMR computes the
   SAME physics as the lockstep/MPI-style engine, faster under the
   work-queue execution model, with the cone signature of Fig 5.
2. The LM framework trains end-to-end (loss decreases) and recovers
   from injected failures with an identical loss trace.
"""

import numpy as np
import pytest
import jax

from repro import amr
from repro.amr import taskgraph as tg


def test_paper_claim_chain():
    prob = amr.WaveProblem(n_points=128, rmax=20.0, amplitude=0.005)
    specs = amr.default_specs(prob, 3)
    cfg = amr.EngineConfig(grain=8, n_workers=8)
    df, ba = amr.compare_engines(prob, specs, 3, cfg)   # checks values
    # Fig 7/8: dataflow outperforms barrier at levels>1, P>1
    assert ba.makespan / df.makespan > 1.5
    # Fig 5: cone — finest region reached fewer steps at mid-budget
    wg = df.windows[0].window_graph
    sched = df.windows[0].schedule
    front = tg.timestep_front(wg, sched.finish, sched.makespan * 0.5,
                              prob.n_points)
    assert front.max() > front.min()


def test_overhead_crossover():
    """Fig 8: at 1 level (uniform), barrier wins or ties (dataflow
    overhead not amortized); at 3 levels dataflow wins."""
    prob = amr.WaveProblem(n_points=128, rmax=20.0, amplitude=0.005)
    cfg = amr.EngineConfig(grain=16, n_workers=4,
                           comm_latency=2e-6)
    one = amr.compare_engines(prob, amr.default_specs(prob, 1), 3, cfg)
    three = amr.compare_engines(prob, amr.default_specs(prob, 3), 3,
                                cfg)
    gain1 = one[1].makespan / one[0].makespan
    gain3 = three[1].makespan / three[0].makespan
    assert gain3 > gain1   # deeper hierarchies favour dataflow


def test_lm_training_end_to_end(tmp_path):
    import repro.configs as configs
    from repro.ft.failures import FailurePlan
    from repro.launch.train import train

    arch = configs.get_reduced("yi-6b")
    _, _, losses = train(arch, steps=12, batch=4, seq=64,
                         ckpt_dir=str(tmp_path / "c1"), ckpt_every=4,
                         log_every=100)
    l0 = np.mean([l for _, l in losses[:3]])
    l1 = np.mean([l for _, l in losses[-3:]])
    assert l1 < l0, (l0, l1)

    # failure at step 9 -> restart from ckpt 8 -> identical trace
    _, _, losses_f = train(arch, steps=12, batch=4, seq=64,
                           ckpt_dir=str(tmp_path / "c2"),
                           ckpt_every=4, log_every=100,
                           fail_plan=FailurePlan.at(9), resume=False)
    trace = dict(losses)
    trace_f = dict(losses_f)
    for k in trace:
        assert trace_f[k] == pytest.approx(trace[k], rel=1e-5)
