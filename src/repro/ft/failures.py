"""Failure injection for fault-tolerance tests and drills.

`FailurePlan` deterministically raises `InjectedFailure` at configured
steps — the supervisor (ft/supervisor.py) must recover from every one
of them by restarting from the last checkpoint (tests/test_ft.py).
"""

from __future__ import annotations

import dataclasses
from typing import FrozenSet, Iterable


class InjectedFailure(RuntimeError):
    """Stands in for a lost node / preemption / hardware fault."""


@dataclasses.dataclass(frozen=True)
class FailurePlan:
    fail_at_steps: FrozenSet[int] = frozenset()
    kind: str = "node_loss"

    @staticmethod
    def at(*steps: int) -> "FailurePlan":
        return FailurePlan(frozenset(steps))

    def check(self, step: int, already_failed: set) -> None:
        if step in self.fail_at_steps and step not in already_failed:
            already_failed.add(step)
            raise InjectedFailure(
                f"injected {self.kind} at step {step}")
