"""Prefill/decode worker roles and the role-agnostic step scheduler.

DESIGN.md §4f: the serving engine is a COMPOSITION — a token-budget
step scheduler that knows nothing about where work runs, plus two
roles it drives each step:

* the **prefill role** turns pending prompt chunks into executed
  chunks.  `PrefillWorker` runs them where the engine runs (the
  single-locality composition `ChunkedPagedServingEngine` uses);
  `ParcelPrefillWorker` lowers each chunk into a `PrefillParcel`
  dispatched through a `ParcelPort` to the AGAS locality that owns
  the prompt's prefix pages — the paper's "move the work to the
  data", at serving granularity.

* the **decode role** owns the decode batch.  `HandoffDecodeWorker`
  additionally commits staged prefill->decode KV handoffs at the top
  of its step, so the handoff copy staged under the PREVIOUS step's
  decode batch lands before this step's batch assembles (the §4d
  double-buffer pattern applied to the §4f role boundary).

The scheduler's budget policy is byte-for-byte the one the chunked
engine always had: every decoding slot reserves its token first,
pending prefill chunks fill the remainder FCFS by admission order,
budget-trimmed to page-aligned pieces, no overtaking.  Roles only
change WHERE a chunk executes, never WHETHER — which is why the
disaggregated engine stays greedy token-identical to the
single-locality one (the differential fuzzer asserts it).
"""

from __future__ import annotations

import time
from typing import Any, List, Optional, Tuple

from repro.core.agas import GlobalAddress
from repro.core.parcels import (ActionRegistry, Parcel, PrefillParcel,
                                lower_prefill_parcels)

#: Actions a prefill worker executes.  One registry shared by every
#: engine instance — actions close over nothing; the engine arrives
#: as the parcel's `state`.
PREFILL_ACTIONS = ActionRegistry()


@PREFILL_ACTIONS.register("prefill_chunk")
def _prefill_chunk_action(engine: Any, target: Optional[GlobalAddress],
                          slot: int, take: int) -> bool:
    """Run one prefill chunk at the destination locality.  The slot
    may have been preempted by an earlier chunk's page pressure while
    this parcel sat in the inbound queue — then the parcel is a no-op
    (its request re-prefills after re-admission)."""
    st = engine.active.get(slot)
    ok = False
    if st is not None and st.get("phase") == "prefill":
        ok = engine._run_chunk(slot, take)
    engine._last_chunk_ok = ok
    return ok


class PrefillWorker:
    """Single-locality prefill role: chunks execute in place."""

    def pending(self, eng) -> List[int]:
        """Prefilling slots in admission order (FCFS by seq)."""
        return sorted((s for s in eng.active
                       if eng.active[s]["phase"] == "prefill"),
                      key=lambda s: eng.active[s]["seq"])

    def run_chunk(self, eng, slot: int, take: int) -> bool:
        return eng._run_chunk(slot, take)

    def flush(self, eng) -> None:
        """End-of-budget-loop hook (parcel batching); no-op locally."""


class ParcelPrefillWorker(PrefillWorker):
    """Parcel-dispatched prefill role (DESIGN.md §4f).

    Every chunk becomes a `PrefillParcel` whose destination is the
    engine's dispatch policy (`_dispatch_target`): the locality
    owning the prompt's radix-matched prefix pages when the prompt is
    warm, least-loaded among the prefill workers when cold.  The
    parcel is posted through the port (local apply or send + drain)
    and the step's parcels are batch-lowered per destination at
    canonical power-of-two sizes — the same size-class program cache
    the migration lowering uses, so dispatch compiles one program per
    (locality, size class), not one per step.
    """

    def __init__(self, n_workers: int):
        self.n_workers = int(n_workers)
        self.parcels = 0            # prefill parcels dispatched
        self.owner_parcels = 0      # ... to the prefix-owner locality
        self.cold_parcels = 0       # ... placed least-loaded (no owner)
        self.dispatch_sizes: set = set()   # canonical batch sizes seen
        self.inter_locality = 0     # parcels that crossed localities
        self._step_parcels: List[PrefillParcel] = []

    def run_chunk(self, eng, slot: int, take: int) -> bool:
        st = eng.active[slot]
        anchor, dst, warm = eng._dispatch_target(slot, st)
        self._step_parcels.append(PrefillParcel(
            rid=st["req"].rid, slot=slot, start=st["pos"], take=take,
            anchor=anchor, locality=dst))
        if eng.recorder.enabled:
            # the flight timeline keeps the dispatch decision next to
            # the chunk it placed: which locality, owner-affine or not
            eng.recorder.event(st["req"].rid, "dispatch", slot=slot,
                               loc=dst, warm=warm)
        self.parcels += 1
        if warm:
            self.owner_parcels += 1
        else:
            self.cold_parcels += 1
        home = eng._home_locality(slot)
        if dst != home:
            self.inter_locality += 1
        port = eng._port
        port.post(Parcel(target=anchor, action="prefill_chunk",
                         args=(slot, take)), dst, home, eng)
        if dst != home:
            port.drain(dst, eng)
        return bool(eng._last_chunk_ok)

    def flush(self, eng) -> None:
        """Lower the step's dispatched parcels into per-destination
        batches at canonical sizes (the compiled-dispatch accounting a
        multi-host port would execute as one program per locality)."""
        if not self._step_parcels:
            return
        lowering = lower_prefill_parcels(self._step_parcels)
        self.dispatch_sizes.update(lowering.sizes)
        self._step_parcels = []


class DecodeWorker:
    """Decode role: owns the decode batch."""

    def commit_handoffs(self, eng) -> None:
        """Step-top hook; only the disaggregated role commits."""

    def run_batch(self, eng, slots: List[int]) -> List[int]:
        return eng._decode_batch(slots)


class HandoffDecodeWorker(DecodeWorker):
    """Decode role that adopts prefill workers' finished KV: staged
    handoff snapshots are committed (restored into their slot) before
    the step schedules, so a prompt whose prefill finished in step N
    decodes from step N+1 — the same cadence the single-locality
    engine has, with the copy double-buffered under step N's decode
    batch instead of serialized before it."""

    def commit_handoffs(self, eng) -> None:
        for slot in [s for s, st in list(eng.active.items())
                     if st.get("phase") == "handoff"]:
            eng._commit_handoff(slot)


class StepScheduler:
    """Role-agnostic token-budget step (DESIGN.md §4b policy, §4f
    composition): decode reservation first, FCFS prefill chunks in
    the remainder, page-aligned budget trim, no overtaking.  A chunk
    that fails (page exhaustion preempted its slot) returns its
    budget to the chunks behind it — exactly the legacy loop."""

    def __init__(self, step_tokens: int, chunk_size: int,
                 page_size: int):
        self.step_tokens = int(step_tokens)
        self.chunk_size = int(chunk_size)
        self.page_size = int(page_size)

    def run_step(self, eng, prefill: PrefillWorker,
                 decode: DecodeWorker
                 ) -> Tuple[List[int], List[int], int, int, float]:
        """Returns (done, decoding, n_chunks, prefill_tok, t0)."""
        # the decode reservation is taken at step start; a slot whose
        # prefill completes THIS step joins the decode batch NEXT
        # step, so prefill chunks + decode tokens never exceed the
        # step's token budget
        decoding = eng._decode_slots()
        budget = self.step_tokens - len(decoding)
        prefill_tok = 0
        n_chunks = 0
        ps = self.page_size
        for slot in prefill.pending(eng):
            if slot not in eng.active:   # preempted by an earlier
                continue                 # chunk's page pressure
            st = eng.active[slot]
            take = min(self.chunk_size, st["real"] - st["pos"])
            if take > budget:
                # trim to the page-aligned piece the budget covers
                take = (budget // ps) * ps
            if take <= 0:
                break                    # FCFS: no overtaking
            if prefill.run_chunk(eng, slot, take):
                budget -= take
                prefill_tok += take
                n_chunks += 1
        prefill.flush(eng)
        # the decode batch: prefilling slots ride along masked (their
        # write row is the null page; their logits are discarded)
        done: List[int] = []
        decoding = [s for s in decoding if s in eng.active]
        if decoding:
            with eng.trace.span("engine", "prepare_writes",
                                 kind="pages"):
                eng._prepare_writes(decoding)
            decoding = [s for s in decoding if s in eng.active]
        # timer starts after write preparation, matching the
        # whole-prompt engine so mean_decode_ms stays comparable
        t0 = time.perf_counter()
        if decoding:
            done = decode.run_batch(eng, decoding)
        return done, decoding, n_chunks, prefill_tok, t0
