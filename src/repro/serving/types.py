"""Request/completion records and small stats helpers.

Shared by every engine role (serving/engine.py, serving/workers.py)
and by the benchmarks/tests, so the prefill/decode worker split does
not churn imports: `Request` is the unit a parcel carries to the
engine, `Completion` the value its LCO resolves to.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # optional SLO deadlines (obs/slo.py): a request carrying either
    # is goodput-tracked; TTFT is checked against ttft_s, ITL against
    # the p95 of itl_s.  None = untracked.
    ttft_deadline_ms: Optional[float] = None
    itl_deadline_ms: Optional[float] = None


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]
    prefill_s: float
    decode_s: float
    preemptions: int = 0
    # submit -> first sampled token (survives preemption: the first
    # token is only ever sampled once)
    ttft_s: float = 0.0
    # gaps between consecutive sampled tokens (inter-token latencies)
    itl_s: List[float] = dataclasses.field(default_factory=list)


def _mean(xs) -> float:
    return float(np.mean(xs)) if len(xs) else 0.0


def _pct(xs, q: float) -> float:
    return float(np.percentile(xs, q)) if len(xs) else 0.0
