"""Observability subsystem (DESIGN.md §10): ring-buffer tracer,
streaming-histogram metrics registry, Chrome trace export, overhead
attribution, and the causal/nesting validators — units plus a small
traced-engine integration run."""

import json
import threading

import numpy as np
import pytest
import jax

import repro.configs as configs
from repro.models import transformer as T
from repro.obs.attribution import (attribute, check_causal,
                                   check_nesting, subsystems)
from repro.obs.metrics import (Counter, Gauge, MetricsRegistry,
                               StreamingHistogram)
from repro.obs.trace import (NULL_TRACER, Tracer, get_global,
                             set_global)
from repro.serving.engine import Request, make_engine


class ManualClock:
    """Deterministic tracer clock: returns ``t``; the test advances it."""

    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


# -- tracer: ring buffer, parenting, null no-op ------------------------

def test_ring_buffer_wraparound_keeps_newest_in_order():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant("t", "e", i=i)
    recs = tr.records()
    assert [r.args["i"] for r in recs] == [6, 7, 8, 9]
    assert tr.dropped == 6
    tr.clear()
    assert tr.records() == [] and tr.dropped == 0


def test_records_before_wrap_are_chronological():
    tr = Tracer(capacity=8)
    for i in range(3):
        tr.instant("t", "e", i=i)
    assert [r.args["i"] for r in tr.records()] == [0, 1, 2]


def test_span_parenting_and_instant_adoption():
    clk = ManualClock()
    tr = Tracer(capacity=16, clock=clk)
    with tr.span("engine", "step") as outer:
        clk.t = 1.0
        with tr.span("engine", "admit", kind="sched") as inner:
            clk.t = 2.0
            tr.instant("kvcache", "page_alloc", gid=0)
            clk.t = 3.0
        clk.t = 4.0
    recs = {r.name: r for r in tr.records()}
    assert recs["admit"].parent == outer.sid
    assert recs["page_alloc"].parent == inner.sid
    assert recs["step"].parent is None
    assert recs["step"].dur == pytest.approx(4.0)
    assert recs["admit"].dur == pytest.approx(2.0)
    assert recs["page_alloc"].dur is None
    assert check_nesting(tr.records()) == []


def test_per_thread_span_stacks_do_not_cross():
    tr = Tracer(capacity=64)
    parents = {}

    def worker(name):
        with tr.span("t", name) as sp:
            ev = tr.instant("t", f"{name}_ev")
            parents[name] = (sp.sid, ev.parent)

    ts = [threading.Thread(target=worker, args=(f"w{i}",))
          for i in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    for sid, parent in parents.values():
        assert parent == sid


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    with NULL_TRACER.span("x", "y", kind="compute", rid=1) as sp:
        sp.args["k"] = 1           # arg mutation must be absorbed
        NULL_TRACER.instant("x", "z", gid=2)
    assert NULL_TRACER.records() == []
    assert NULL_TRACER.dropped == 0


def test_set_global_rebinds_and_restores():
    assert get_global() is NULL_TRACER
    tr = Tracer(capacity=4)
    try:
        assert set_global(tr) is tr
        assert get_global() is tr
    finally:
        set_global(None)
    assert get_global() is NULL_TRACER


# -- metrics registry --------------------------------------------------

def test_counter_and_gauge():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.value == 5
    c.reset()
    assert c.value == 0
    g = Gauge()
    g.set(2.0)
    g.set_max(1.0)
    assert g.value == 2.0
    g.set_max(7.0)
    assert g.value == 7.0


def test_streaming_histogram_quantiles_match_numpy():
    rng = np.random.default_rng(0)
    samples = np.concatenate([rng.lognormal(0.0, 1.0, 4000),
                              rng.uniform(0.0, 50.0, 1000)])
    h = StreamingHistogram()
    for s in samples:
        h.record(float(s))
    assert h.count == len(samples)
    assert h.min == pytest.approx(samples.min())
    assert h.max == pytest.approx(samples.max())
    assert h.mean == pytest.approx(samples.mean(), rel=1e-9)
    for q in (50, 90, 95, 99):
        exact = np.percentile(samples, q)
        # log-bucketed with GROWTH=1.03: ~3% relative bucket error
        assert h.quantile(q) == pytest.approx(exact, rel=0.04)


def test_streaming_histogram_empty_and_edge_quantiles():
    h = StreamingHistogram()
    assert h.count == 0 and h.mean == 0.0 and h.quantile(50) == 0.0
    h.record(3.0)
    assert h.quantile(0) == pytest.approx(3.0, rel=0.04)
    assert h.quantile(100) == pytest.approx(3.0, rel=0.04)
    h.record(0.0)      # underflow bucket
    assert h.count == 2 and h.min == 0.0


def test_registry_get_or_create_and_type_guard():
    m = MetricsRegistry()
    c = m.counter("a.b")
    assert m.counter("a.b") is c
    with pytest.raises(TypeError):
        m.gauge("a.b")
    m.histogram("a.h").record(2.0)
    snap = m.snapshot()
    assert snap["a.b"] == 0
    assert snap["a.h.count"] == 1
    assert snap["a.h.p50"] == pytest.approx(2.0, rel=0.04)
    m.reset()
    assert m.snapshot()["a.h.count"] == 0
    assert sorted(m.names()) == ["a.b", "a.h"]


# -- Chrome export (golden, deterministic clock) -----------------------

def test_chrome_export_golden():
    clk = ManualClock(1.0)
    tr = Tracer(capacity=8, clock=clk)
    with tr.span("engine", "step", kind="sched", ran=1):
        clk.t = 1.5
        tr.instant("kvcache", "page_alloc", lane=2, gid=7)
        clk.t = 2.0
    trace = tr.to_chrome()
    assert trace["displayTimeUnit"] == "ms"
    evs = trace["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    procs = {e["args"]["name"]: e["pid"] for e in meta
             if e["name"] == "process_name"}
    assert set(procs) == {"engine", "kvcache"}
    threads = {(e["pid"], e["args"]["name"]): e["tid"] for e in meta
               if e["name"] == "thread_name"}
    assert (procs["engine"], "main") in threads
    assert (procs["kvcache"], "2") in threads
    inst = next(e for e in evs if e["ph"] == "i")
    span = next(e for e in evs if e["ph"] == "X")
    assert inst == {"name": "page_alloc", "cat": "kvcache",
                    "pid": procs["kvcache"],
                    "tid": threads[(procs["kvcache"], "2")],
                    "ts": pytest.approx(0.5e6), "ph": "i", "s": "t",
                    "args": {"gid": 7, "sid": inst["args"]["sid"],
                             "parent": span["args"]["sid"]}}
    assert span["name"] == "step" and span["cat"] == "engine"
    assert span["ts"] == pytest.approx(0.0)
    assert span["dur"] == pytest.approx(1.0e6)
    assert span["args"]["kind"] == "sched"
    assert span["args"]["ran"] == 1


def test_export_chrome_writes_valid_json(tmp_path):
    tr = Tracer(capacity=8)
    with tr.span("engine", "step"):
        tr.instant("engine", "submit", rid=0)
    path = tr.export_chrome(str(tmp_path / "t.json"))
    with open(path) as f:
        loaded = json.load(f)
    assert {e["name"] for e in loaded["traceEvents"]} >= {
        "step", "submit", "process_name", "thread_name"}


# -- attribution -------------------------------------------------------

def _synthetic_step(tr, clk, t0):
    clk.t = t0
    with tr.span("engine", "step"):
        clk.t = t0 + 0.01
        with tr.span("engine", "admit", kind="sched"):
            clk.t = t0 + 0.03
        with tr.span("engine", "decode_batch", kind="compute"):
            clk.t = t0 + 0.13
        with tr.span("kvcache", "attach", kind="pages"):
            clk.t = t0 + 0.16
        clk.t = t0 + 0.20


def test_attribute_self_time_decomposition():
    clk = ManualClock()
    tr = Tracer(capacity=64, clock=clk)
    _synthetic_step(tr, clk, 0.0)
    _synthetic_step(tr, clk, 1.0)
    rep = attribute(tr.records())
    assert rep["steps"] == 2
    assert rep["wall_ms"] == pytest.approx(400.0)
    assert rep["compute_ms"] == pytest.approx(200.0)
    cats = rep["categories_ms"]
    assert cats["sched"] == pytest.approx(40.0)
    assert cats["pages"] == pytest.approx(60.0)
    # step self time (gaps between children) lands in "other"
    assert cats["other"] == pytest.approx(100.0)
    assert rep["sum_residual"] == pytest.approx(0.0, abs=1e-9)
    assert rep["compute_fraction"] + rep["overhead_fraction"] == \
        pytest.approx(1.0)


def test_attribute_empty_trace():
    rep = attribute([])
    assert rep["steps"] == 0 and rep["wall_ms"] == 0.0
    assert rep["sum_residual"] == 0.0


# -- validators --------------------------------------------------------

def test_check_nesting_flags_escaping_child():
    clk = ManualClock()
    tr = Tracer(capacity=16, clock=clk)
    with tr.span("engine", "step") as parent:
        clk.t = 1.0
    # forge a child that overruns its parent's interval
    with tr.span("engine", "rogue") as rogue:
        clk.t = 5.0
    recs = tr.records()
    next(r for r in recs if r.sid == rogue.sid).parent = parent.sid
    problems = check_nesting(recs)
    assert len(problems) == 1 and "rogue" in problems[0]


def test_check_causal_accepts_well_formed_trace():
    clk = ManualClock()
    tr = Tracer(capacity=32, clock=clk)
    tr.instant("engine", "submit", rid=0)
    clk.t = 1.0
    tr.instant("engine", "slot_bind", rid=0, slot=3)
    clk.t = 2.0
    tr.instant("kvcache", "page_alloc", gid=10, slot=3)
    clk.t = 3.0
    tr.instant("parcels", "local_apply", gids=[10])
    clk.t = 4.0
    tr.instant("kvcache", "page_free", gid=10, slot=3)
    assert check_causal(tr.records()) == []


def test_check_causal_flags_dangles():
    clk = ManualClock()
    tr = Tracer(capacity=32, clock=clk)
    tr.instant("engine", "finish", rid=9)           # never submitted
    clk.t = 1.0
    tr.instant("kvcache", "attach", slot=2)         # slot never bound
    clk.t = 2.0
    tr.instant("parcels", "send", gids=[42])        # gid never alloc'd
    problems = check_causal(tr.records())
    assert len(problems) == 3
    assert any("never submitted" in p for p in problems)
    assert any("before any bind" in p for p in problems)
    assert any("never allocated" in p for p in problems)


def test_check_causal_flags_use_after_free():
    clk = ManualClock()
    tr = Tracer(capacity=32, clock=clk)
    tr.instant("kvcache", "page_alloc", gid=5)
    clk.t = 1.0
    tr.instant("kvcache", "page_free", gid=5)
    clk.t = 2.0
    tr.instant("percolation", "stage", gids=[5])
    problems = check_causal(tr.records())
    assert len(problems) == 1 and "after free" in problems[0]


# -- traced engine integration -----------------------------------------

def test_traced_engine_run_produces_causally_linked_spans():
    cfg = configs.get_reduced("yi-6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tr = Tracer(capacity=1 << 14)
    eng = make_engine(params, cfg, engine="chunked", slots=2,
                      max_len=64, prefill_buckets=(32,), page_size=8,
                      n_pages=16, tiering=True, host_pages=32,
                      tracer=tr)
    set_global(tr)
    try:
        for rid in range(3):
            eng.submit(Request(
                rid, np.arange(12 + rid, dtype=np.int32),
                max_new_tokens=4))
        eng.run_to_completion()
    finally:
        set_global(None)
    recs = tr.records()
    assert tr.dropped == 0
    assert {"engine", "kvcache", "lco"} <= subsystems(recs)
    assert check_nesting(recs) == []
    assert check_causal(recs) == []
    rep = attribute(recs)
    assert rep["steps"] == len(eng.counters) > 0
    assert rep["compute_ms"] > 0.0
    assert rep["sum_residual"] <= 0.05
    # registry-backed stats agree with the trace
    s = eng.stats()
    assert s["steps"] == rep["steps"]
    assert eng.metrics.snapshot()["engine.decode_ms.count"] > 0


def test_traced_attribution_reconciles_with_compute_skip_active():
    """§4e compute skip removes work from the step; the §10 ledger
    must still balance — skipped prefill is compute the tracer never
    saw AND wall-clock the step never contained, so the per-step self
    times keep summing to the step wall (residual <= 5%) while the
    engine reports both full and partial covers."""
    cfg = configs.get_reduced("yi-6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tr = Tracer(capacity=1 << 14)
    eng = make_engine(params, cfg, engine="chunked", slots=2,
                      max_len=96, prefill_buckets=(32,), page_size=16,
                      n_pages=16, chunk_size=32, tiering=True,
                      host_pages=32, prefix_cache_compute=True,
                      tracer=tr)
    rng = np.random.default_rng(11)
    head = rng.integers(0, cfg.vocab_size, size=48).astype(np.int32)
    seed_prompt = np.concatenate(
        [head, rng.integers(0, cfg.vocab_size, size=16)]
    ).astype(np.int32)
    set_global(tr)
    try:
        # cold seed, then a warm wave: one exact repeat (full cover)
        # and one longer prompt sharing only the head (partial cover)
        eng.submit(Request(0, seed_prompt, max_new_tokens=3))
        eng.run_to_completion()
        eng.submit(Request(1, seed_prompt, max_new_tokens=3))
        eng.submit(Request(2, np.concatenate(
            [head, rng.integers(0, cfg.vocab_size, size=32)]
        ).astype(np.int32), max_new_tokens=3))
        eng.run_to_completion()
    finally:
        set_global(None)
    assert eng.prefix_skips >= 1
    assert eng.prefix_partial_hits >= 1
    assert eng.prefill_tokens_skipped >= 64 + 48
    recs = tr.records()
    assert tr.dropped == 0
    assert check_nesting(recs) == []
    assert check_causal(recs) == []
    rep = attribute(recs)
    assert rep["steps"] == len(eng.counters) > 0
    assert rep["sum_residual"] <= 0.05
    # the registry mirrors both skip counters next to the trace stats
    s = eng.stats()
    assert s["prefix_skips"] == eng.prefix_skips
    assert s["prefix_partial_hits"] == eng.prefix_partial_hits
    snap = eng.metrics.snapshot()
    assert snap["engine.prefix_partial_hits"] == eng.prefix_partial_hits
    assert snap["engine.prefill_tokens_skipped"] == \
        eng.prefill_tokens_skipped


def test_untraced_engine_has_null_tracer_and_empty_trace():
    cfg = configs.get_reduced("yi-6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = make_engine(params, cfg, engine="paged", slots=2, max_len=64,
                      prefill_buckets=(32,))
    assert eng.trace is NULL_TRACER
    eng.submit(Request(0, np.arange(10, dtype=np.int32),
                       max_new_tokens=2))
    eng.run_to_completion()
    assert eng.trace.records() == []
    assert eng.stats()["steps"] == len(eng.counters)
