"""Runtime observability: causal tracing, metrics, overhead attribution.

Three layers (ISSUE 6):

- ``trace``       ring-buffer tracer emitting typed spans/instants with
                  monotonic timestamps and causal ids (request -> slot ->
                  page chain -> parcel); Chrome trace-event JSON export.
- ``metrics``     unified registry of counters / gauges / streaming
                  histograms under a ``subsystem.metric`` namespace.
- ``attribution`` per-step wall-clock decomposition into kernel compute
                  vs runtime overhead (the paper's Fig. 9 analysis applied
                  online to serving).
"""

from repro.obs.trace import (  # noqa: F401
    NULL_TRACER,
    Tracer,
    get_global,
    set_global,
)
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    MetricsRegistry,
    StreamingHistogram,
)
