"""Request-level SLO/goodput observability (DESIGN.md §10).

Span-granularity attribution (obs/attribution.py) answers "where does
a STEP's wall-clock go"; this module answers the request-level
question the serving tier needs: *which requests missed their
deadline, and which lifecycle phase ate the budget?*

Two pieces:

- **FlightRecorder** — a per-request event timeline.  Every request
  carries a compact list of lifecycle events (submit → bind →
  prefill chunks → handoff stage/commit → first token → each
  preempt/offload/restore → finish), appended by engine hooks at the
  same boundaries the tracer spans open and close, so the recorder's
  exec durations reconcile with the §10 attribution buckets.
  Execution events carry a ``dur`` measured by the hook; everything
  else is a point event.  Disabled recording is the ``NULL_RECORDER``
  singleton — every call a constant-time no-op.  Finished requests
  are retained up to ``retain`` timelines (oldest finished evicted
  first) so memory stays bounded over arbitrarily long runs.

- **Deadline classification** — requests optionally carry
  ``ttft_deadline_ms`` / ``itl_deadline_ms`` (serving/types.py).  At
  completion the engine calls ``classify``: a request is *met* iff
  its TTFT is within the TTFT deadline and its p95 inter-token gap is
  within the ITL deadline.  A miss is blamed on the largest timeline
  contributor in the relevant window (``BLAME_PHASES``: queue /
  prefill / handoff / preempt / decode), derived from the flight
  timeline by ``derive_phases``.  Verdicts stream into the metrics
  registry under ``slo.*`` (``record_verdict``) so ``stats()`` and
  the exporters see goodput without scanning completions.

Phase semantics (``derive_phases``): the TTFT window is
[submit, first_token] and splits into ``queue`` (submit → first
bind), ``preempted`` (preempt → re-bind gaps), ``prefill_exec``
(summed durs of prefill / prefill_chunk / resume / restore exec
events), ``handoff`` (summed handoff op durs) and ``prefill_wait``
(the remainder: admitted but waiting for step budget).  The decode
window is [first_token, finish]: ``decode`` is its span minus
``preempted`` gaps (handoff op durs are reported separately but stay
inside decode — the §4f staged copy overlaps the decode batch by
design).  All values are seconds.
"""

import json
import time

import numpy as np

__all__ = [
    "BLAME_PHASES",
    "EXEC_EVENTS",
    "FlightRecorder",
    "NULL_RECORDER",
    "NullFlightRecorder",
    "classify",
    "derive_phases",
    "record_verdict",
    "build_report",
]

#: Blame categories a missed deadline resolves to (the ISSUE's
#: queueing / prefill / handoff / preemption / decode).
BLAME_PHASES = ("queue", "prefill", "handoff", "preempt", "decode")

#: Event names whose ``dur`` counts as prefill execution.
EXEC_EVENTS = frozenset(("prefill", "prefill_chunk", "resume",
                         "restore"))

#: Event names whose ``dur`` counts as handoff copy work.
HANDOFF_EVENTS = frozenset(("handoff_stage", "handoff_commit"))

_EPS = 1e-9


class FlightEvent:
    """One lifecycle event: ``dur`` is None for point events."""

    __slots__ = ("t", "name", "args")

    def __init__(self, t, name, args):
        self.t = t
        self.name = name
        self.args = args

    @property
    def dur(self):
        return self.args.get("dur")

    def to_json(self):
        return {"t": self.t, "name": self.name, **self.args}

    def __repr__(self):
        return f"FlightEvent({self.name!r}, t={self.t:.6f}, {self.args})"


class NullFlightRecorder:
    """Disabled recorder: every call is a constant-time no-op."""

    enabled = False

    def event(self, rid, name, t=None, **args):
        return None

    def timeline(self, rid):
        return ()

    def rids(self):
        return ()

    def phases(self, rid):
        return {}

    def to_json(self):
        return {"requests": {}}

    def clear(self):
        return None


NULL_RECORDER = NullFlightRecorder()


class FlightRecorder:
    """Per-request lifecycle timelines, bounded by ``retain``."""

    enabled = True

    def __init__(self, retain=4096, clock=None):
        self.retain = int(retain)
        self.clock = clock if clock is not None else time.perf_counter
        self._events = {}          # rid -> [FlightEvent, ...]
        self._finished = []        # rids in finish order (FIFO evict)

    def event(self, rid, name, t=None, **args):
        """Append one event to ``rid``'s timeline.  ``t`` defaults to
        the recorder clock NOW; exec hooks pass ``dur=seconds``."""
        ev = FlightEvent(self.clock() if t is None else t, name, args)
        self._events.setdefault(rid, []).append(ev)
        if name == "finish":
            self._finished.append(rid)
            while len(self._finished) > self.retain:
                self._events.pop(self._finished.pop(0), None)
        return ev

    def timeline(self, rid):
        """``rid``'s events in append order (appends are monotone in
        recorder-clock time)."""
        return tuple(self._events.get(rid, ()))

    def rids(self):
        return sorted(self._events)

    def phases(self, rid):
        return derive_phases(self.timeline(rid))

    def to_json(self):
        return {"requests": {
            str(rid): {"events": [e.to_json() for e in evs],
                       "phases": derive_phases(tuple(evs))}
            for rid, evs in sorted(self._events.items())}}

    def dump_json(self, path):
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)
        return path

    def clear(self):
        self._events.clear()
        self._finished.clear()


def _clip(a, b, lo, hi):
    """Overlap of [a, b] with [lo, hi]."""
    return max(0.0, min(b, hi) - max(a, lo))


def derive_phases(timeline):
    """Decompose one timeline into per-phase seconds (see module
    docstring).  Robust to partial timelines: a request that never
    reached its first token (or never finished) reports the phases
    of the window it did traverse."""
    if not timeline:
        return {}
    t_submit = timeline[0].t
    t_first = None
    t_finish = None
    binds = []
    preempts = []
    exec_events = []
    handoff_durs = []
    for ev in timeline:
        if ev.name == "submit":
            t_submit = ev.t
        elif ev.name == "bind":
            binds.append(ev.t)
        elif ev.name == "preempt":
            preempts.append(ev.t)
        elif ev.name == "first_token":
            t_first = ev.t
        elif ev.name == "finish":
            t_finish = ev.t
        if ev.name in EXEC_EVENTS and ev.dur is not None:
            exec_events.append(ev)
        elif ev.name in HANDOFF_EVENTS and ev.dur is not None:
            handoff_durs.append(ev)
    t_end = t_finish if t_finish is not None else timeline[-1].t
    t_cut = t_first if t_first is not None else t_end
    # queue: submit -> first bind (never past the first token)
    queue = _clip(t_submit, binds[0] if binds else t_cut,
                  t_submit, t_cut)
    # preempted: each preempt -> next bind (or end-of-trace) gap,
    # split at the first token
    pre_gaps_pre = pre_gaps_post = 0.0
    for pt in preempts:
        nxt = next((b for b in binds if b > pt + _EPS), t_end)
        pre_gaps_pre += _clip(pt, nxt, t_submit, t_cut)
        pre_gaps_post += _clip(pt, nxt, t_cut, t_end)
    # exec durs, split by the window the op STARTED in (events are
    # stamped at op end; the final prefill chunk samples the first
    # token inside itself, so its dur belongs to the TTFT window)
    exec_pre = sum(e.dur for e in exec_events
                   if e.t - e.dur <= t_cut + _EPS)
    exec_post = sum(e.dur for e in exec_events
                    if e.t - e.dur > t_cut + _EPS)
    hand_pre = sum(e.dur for e in handoff_durs
                   if e.t - e.dur <= t_cut + _EPS)
    hand_post = sum(e.dur for e in handoff_durs
                    if e.t - e.dur > t_cut + _EPS)
    ttft = max(0.0, t_cut - t_submit)
    wait = max(0.0, ttft - queue - pre_gaps_pre - exec_pre - hand_pre)
    decode = 0.0
    if t_first is not None:
        decode = max(0.0, t_end - t_first - pre_gaps_post)
    return {
        "queue": queue,
        "prefill_exec": exec_pre,
        "prefill_wait": wait,
        "prefill_exec_post": exec_post,     # mid-prefill preemption
        "handoff": hand_pre + hand_post,    # op durs (copy work)
        "preempted": pre_gaps_pre + pre_gaps_post,
        "preempted_pre_first": pre_gaps_pre,
        "decode": decode,
        "ttft_s": ttft if t_first is not None else None,
        "e2e_s": max(0.0, t_end - t_submit),
        "complete": t_finish is not None,
    }


def _blame_ttft(ph):
    """Largest TTFT-window contributor."""
    buckets = {
        "queue": ph.get("queue", 0.0),
        "prefill": ph.get("prefill_exec", 0.0)
        + ph.get("prefill_wait", 0.0),
        "handoff": 0.0,   # §4f samples the first token before detach
        "preempt": ph.get("preempted_pre_first", 0.0),
    }
    return max(buckets, key=lambda k: buckets[k])


def _blame_itl(ph):
    """Largest decode-window contributor."""
    post_pre = ph.get("preempted", 0.0) \
        - ph.get("preempted_pre_first", 0.0)
    buckets = {
        "decode": ph.get("decode", 0.0),
        "preempt": post_pre + ph.get("prefill_exec_post", 0.0),
        "handoff": ph.get("handoff", 0.0),
    }
    return max(buckets, key=lambda k: buckets[k])


def classify(req, comp, timeline=None):
    """Deadline verdict for one completion.

    ``req`` needs ``ttft_deadline_ms`` / ``itl_deadline_ms`` (both
    optional — a request carrying neither is untracked and never
    counts against goodput).  ``comp`` is a serving Completion
    (``ttft_s``, ``itl_s``).  ``timeline`` (flight-recorder events)
    enables per-phase blame; without it a miss is ``unattributed``.
    """
    ttft_dl = getattr(req, "ttft_deadline_ms", None)
    itl_dl = getattr(req, "itl_deadline_ms", None)
    tracked = ttft_dl is not None or itl_dl is not None
    ttft_ms = comp.ttft_s * 1e3
    itl_p95_ms = (float(np.percentile(comp.itl_s, 95.0)) * 1e3
                  if comp.itl_s else 0.0)
    ttft_miss = ttft_dl is not None and ttft_ms > ttft_dl
    itl_miss = itl_dl is not None and itl_p95_ms > itl_dl
    met = tracked and not (ttft_miss or itl_miss)
    blame = None
    if ttft_miss or itl_miss:
        ph = derive_phases(timeline) if timeline else {}
        if not ph:
            blame = "unattributed"
        elif ttft_miss:        # TTFT is the tighter promise: blame it
            blame = _blame_ttft(ph)
        else:
            blame = _blame_itl(ph)
    return {
        "rid": comp.rid,
        "tracked": tracked,
        "met": met,
        "ttft_miss": ttft_miss,
        "itl_miss": itl_miss,
        "blame": blame,
        "ttft_ms": ttft_ms,
        "ttft_deadline_ms": ttft_dl,
        "itl_p95_ms": itl_p95_ms,
        "itl_deadline_ms": itl_dl,
    }


def record_verdict(metrics, verdict):
    """Stream one verdict into the §10 registry (``slo.*``)."""
    if not verdict["tracked"]:
        return
    req_c = metrics.counter("slo.requests")
    met_c = metrics.counter("slo.met")
    req_c.inc()
    if verdict["met"]:
        met_c.inc()
    if verdict["ttft_miss"]:
        metrics.counter("slo.ttft_misses").inc()
    if verdict["itl_miss"]:
        metrics.counter("slo.itl_misses").inc()
    if verdict["blame"] is not None:
        metrics.counter(f"slo.blame.{verdict['blame']}").inc()
    metrics.gauge("slo.goodput").set(met_c.value / req_c.value)


def build_report(engine):
    """End-of-run goodput report: registry aggregates + per-request
    verdicts and phase decompositions (when the engine ran with a
    flight recorder).  JSON-serializable."""
    snap = engine.metrics.snapshot()
    verdicts = getattr(engine, "slo_verdicts", {})
    recorder = getattr(engine, "recorder", NULL_RECORDER)
    blame = {p: int(snap.get(f"slo.blame.{p}", 0))
             for p in BLAME_PHASES}
    blame["unattributed"] = int(snap.get("slo.blame.unattributed", 0))
    totals = {}
    per_request = []
    for rid in sorted(verdicts):
        v = verdicts[rid]
        ph = recorder.phases(rid) if recorder.enabled else {}
        for k, s in ph.items():
            if isinstance(s, (int, float)) and k not in (
                    "ttft_s", "e2e_s", "complete"):
                totals[k] = totals.get(k, 0.0) + s
        per_request.append({**v, "phases": ph})
    return {
        "requests": int(snap.get("slo.requests", 0)),
        "met": int(snap.get("slo.met", 0)),
        "goodput": float(snap.get("slo.goodput", 0.0)),
        "ttft_misses": int(snap.get("slo.ttft_misses", 0)),
        "itl_misses": int(snap.get("slo.itl_misses", 0)),
        "blame": blame,
        "phase_totals_s": totals,
        "per_request": per_request,
    }
