"""Paper Fig 7: strong scaling, HPX(dataflow) vs MPI(barrier), by
refinement depth.

The paper's finding: "As levels of refinement were added to the
simulation, strong scaling improved in the HPX version. The MPI
comparison code showed the opposite behavior."  We report parallel
efficiency at increasing worker counts for 1-3 levels under both
engines (identical task graphs, measured cost model).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro import amr
from repro.amr import taskgraph as tg
from repro.core import barrier_schedule, list_schedule

WORKERS = (1, 2, 4, 8, 16, 32)


def run(n_points=512, grain=8, verbose=True):
    prob = amr.WaveProblem(n_points=n_points, rmax=20.0,
                           amplitude=0.005)
    out = {}
    for levels in (1, 2, 3):
        specs = amr.default_specs(prob, levels)
        wg = tg.build_window_graph(specs, 2, grain)
        eff = {"dataflow": [], "barrier": []}
        base = {}
        for p in WORKERS:
            tg.assign_owners(wg, p)
            df = list_schedule(wg.graph, p, overhead=4e-6,
                               comm_latency=1e-6)
            ba = barrier_schedule(wg.graph, p, overhead=4e-6,
                                  barrier_cost=2e-5)
            for name, r in (("dataflow", df), ("barrier", ba)):
                if p == 1:
                    base[name] = r.makespan
                eff[name].append(base[name] / (r.makespan * p))
        out[levels] = eff
        if verbose:
            for name in ("dataflow", "barrier"):
                row = " ".join(f"P{p}:{e:.2f}" for p, e in
                               zip(WORKERS, eff[name]))
            print(f"# fig7 L={levels} dataflow " + " ".join(
                f"{e:.2f}" for e in eff["dataflow"]))
            print(f"# fig7 L={levels} barrier  " + " ".join(
                f"{e:.2f}" for e in eff["barrier"]))
        emit(f"fig7_eff32_dataflow_L{levels}",
             eff["dataflow"][-1] * 100, "efficiency_pct_at_P32")
        emit(f"fig7_eff32_barrier_L{levels}",
             eff["barrier"][-1] * 100, "efficiency_pct_at_P32")
    # the paper's qualitative claim, quantified:
    trend_df = out[3]["dataflow"][-1] - out[1]["dataflow"][-1]
    trend_ba = out[3]["barrier"][-1] - out[1]["barrier"][-1]
    emit("fig7_scaling_trend_with_levels", 0.0,
         f"dataflow_delta={trend_df:+.3f} barrier_delta={trend_ba:+.3f}")
    return out


if __name__ == "__main__":
    run()
