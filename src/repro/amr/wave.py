"""The paper's application physics: semilinear wave equation in
spherical symmetry (paper Sec. III, Eqns. 1-3; Liebling PRD 71 044019).

    chi_t = Pi                                   (1)
    Phi_t = d_r Pi                               (2)
    Pi_t  = (1/r^2) d_r (r^2 Phi) + chi^p        (3)    p = 7

Second-order centered finite differences in space, third-order SSP
Runge-Kutta (Shu-Osher) in time, initial data a Gaussian pulse

    chi0 = A exp[-(r - R0)^2 / delta^2],  Phi0 = d_r chi0,  Pi0 = 0,

R0 = 8, delta = 1, amplitude A tuned to explore criticality.

The *fused block step* is the unit of work of a ParalleX task: one RK3
step on a block carrying a halo of H = 3 ghost cells per side (one
stencil radius per RK stage), so a task needs neighbor data only once
per step — the communication-avoiding form that makes the task's domain
of dependence explicit (paper Sec. III: "the domain of dependence of
each point is much smaller than the global computational domain").

Physical boundaries are local: the origin uses even/odd/even mirror
symmetry for (chi, Phi, Pi) plus the l'Hopital regularization
(1/r^2) d_r(r^2 Phi)|_{r=0} = 3 Phi'(0); the outer boundary uses linear
extrapolation ghosts (adequate for domains with the outer edge far from
the pulse; a simplification vs. full Sommerfeld, noted in DESIGN.md).
Because both are local functions of the block's own data they are
refreshed after every RK stage, so a boundary block loses no halo width
at its physical side.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

H = 3          # halo width: 1 stencil radius x 3 RK stages
NFIELDS = 3    # chi, Phi, Pi
SIGNS = np.array([1.0, -1.0, 1.0])  # mirror parity of (chi, Phi, Pi) at r=0


@dataclasses.dataclass(frozen=True)
class WaveProblem:
    """Static problem definition (paper Sec. III parameters)."""

    p: int = 7
    amplitude: float = 0.01
    r0: float = 8.0
    delta: float = 1.0
    rmax: float = 20.0
    n_points: int = 512          # base (level-0) grid points
    cfl: float = 0.25
    dtype: str = "float32"

    @property
    def dr(self) -> float:
        # r_i = i * dr, i = 0 .. n_points-1; r=0 is on the grid.
        return self.rmax / (self.n_points - 1)

    @property
    def dt(self) -> float:
        return self.cfl * self.dr

    def jnp_dtype(self):
        return jnp.dtype(self.dtype)


def initial_data(prob: WaveProblem, level_dr: float | None = None,
                 n: int | None = None, offset: int = 0) -> jnp.ndarray:
    """(3, n) initial state on a grid r_i = (offset + i) * level_dr."""
    dr = prob.dr if level_dr is None else level_dr
    n = prob.n_points if n is None else n
    r = (offset + jnp.arange(n, dtype=prob.jnp_dtype())) * dr
    chi = prob.amplitude * jnp.exp(-((r - prob.r0) ** 2) / prob.delta**2)
    phi = chi * (-2.0 * (r - prob.r0) / prob.delta**2)  # analytic d_r chi
    pi = jnp.zeros_like(chi)
    return jnp.stack([chi, phi, pi])


def rhs(u: jnp.ndarray, r: jnp.ndarray, dr: float, p: int) -> jnp.ndarray:
    """RHS of Eqns. (1)-(3) on the interior [1, W-1) of a width-W array.

    Edge cells of the result are zero-filled garbage; callers slice.
    """
    chi, phi, pi = u[0], u[1], u[2]
    inner = slice(1, u.shape[-1] - 1)
    dpi = (pi[2:] - pi[:-2]) / (2.0 * dr)
    r2phi = r * r * phi
    dmono = (r2phi[2:] - r2phi[:-2]) / (2.0 * dr)
    rc = r[inner]
    # l'Hopital at r=0: (1/r^2) d_r(r^2 Phi) -> 3 Phi'(0).
    near_zero = jnp.abs(rc) < 0.5 * dr
    safe_r2 = jnp.where(near_zero, 1.0, rc * rc)
    dphi3 = 3.0 * (phi[2:] - phi[:-2]) / (2.0 * dr)
    mono = jnp.where(near_zero, dphi3, dmono / safe_r2)
    dchi = pi[inner]
    dphi = dpi
    dpi_t = mono + chi[inner] ** p
    out = jnp.zeros_like(u)
    out = out.at[0, inner].set(dchi)
    out = out.at[1, inner].set(dphi)
    out = out.at[2, inner].set(dpi_t)
    return out


def refresh_physical_ghosts(u: jnp.ndarray, left_phys, right_phys
                            ) -> jnp.ndarray:
    """Refill the H ghost cells at physical sides from interior data.

    `left_phys`/`right_phys` may be Python bools or traced booleans
    (scalar jnp arrays) — the masked form keeps the compiled engine's
    block batch uniform.  Left: mirror symmetry about r=0 (interior
    index H is the r=0 point).  Right: linear extrapolation.
    """
    w = u.shape[-1]
    signs = jnp.asarray(SIGNS, u.dtype)[:, None]
    # ghosts 0,1,2 mirror interior 6,5,4 (about index H=3).
    left_vals = signs * u[:, [2 * H, 2 * H - 1, 2 * H - 2]]
    u = u.at[:, 0:H].set(
        jnp.where(left_phys, left_vals, u[:, 0:H]))
    last = u[:, w - H - 1]
    prev = u[:, w - H - 2]
    slope = last - prev
    right_vals = jnp.stack(
        [last + (k + 1) * slope for k in range(H)], axis=-1)
    u = u.at[:, w - H:].set(
        jnp.where(right_phys, right_vals, u[:, w - H:]))
    return u


def fused_rk3_block(u_ext: jnp.ndarray, r_ext: jnp.ndarray, dr: float,
                    dt: float, p: int, left_phys=False, right_phys=False
                    ) -> jnp.ndarray:
    """One fused SSP-RK3 step on a block with H-cell halos.

    u_ext: (3, g + 2H) state at time t, halos filled with neighbor data
    at time t (or physical ghosts).  Returns (3, g): interior at t + dt,
    bit-identical to the global reference step restricted to the block.

    Stage validity shrinks by one cell per side and stage at interior
    sides; physical sides are refreshed after every stage, so they do
    not shrink.  The discarded edge bands absorb the invalid cells.
    """
    def L(u):
        return rhs(u, r_ext, dr, p)

    u0 = refresh_physical_ghosts(u_ext, left_phys, right_phys)
    u1 = u0 + dt * L(u0)
    u1 = refresh_physical_ghosts(u1, left_phys, right_phys)
    u2 = 0.75 * u0 + 0.25 * (u1 + dt * L(u1))
    u2 = refresh_physical_ghosts(u2, left_phys, right_phys)
    u3 = u0 / 3.0 + (2.0 / 3.0) * (u2 + dt * L(u2))
    u3 = refresh_physical_ghosts(u3, left_phys, right_phys)
    return u3[:, H:-H]


def _rhs_np(u: np.ndarray, r: np.ndarray, dr: float, p: int) -> np.ndarray:
    """NumPy twin of `rhs` (host-engine fast path; same arithmetic)."""
    phi, pi = u[1], u[2]
    w = u.shape[-1]
    inner = slice(1, w - 1)
    dpi = (pi[2:] - pi[:-2]) / (2.0 * dr)
    r2phi = r * r * phi
    dmono = (r2phi[2:] - r2phi[:-2]) / (2.0 * dr)
    rc = r[inner]
    near_zero = np.abs(rc) < 0.5 * dr
    safe_r2 = np.where(near_zero, 1.0, rc * rc)
    dphi3 = 3.0 * (phi[2:] - phi[:-2]) / (2.0 * dr)
    mono = np.where(near_zero, dphi3, dmono / safe_r2)
    out = np.zeros_like(u)
    out[0, inner] = pi[inner]
    out[1, inner] = dpi
    out[2, inner] = mono + u[0, inner] ** p
    return out


def _refresh_np(u: np.ndarray, left_phys: bool, right_phys: bool
                ) -> np.ndarray:
    w = u.shape[-1]
    if left_phys:
        u[:, 0:H] = SIGNS[:, None].astype(u.dtype) * \
            u[:, [2 * H, 2 * H - 1, 2 * H - 2]]
    if right_phys:
        last = u[:, w - H - 1]
        slope = last - u[:, w - H - 2]
        for k in range(H):
            u[:, w - H + k] = last + (k + 1) * slope
    return u


def fused_rk3_block_np(u_ext: np.ndarray, r_ext: np.ndarray, dr: float,
                       dt: float, p: int, left_phys: bool = False,
                       right_phys: bool = False) -> np.ndarray:
    """NumPy twin of `fused_rk3_block` for the host dataflow engine.

    Static bool boundary flags only (host tasks know their sides).
    Kept in lockstep with the jnp version; tests/test_amr_equivalence
    asserts they agree to float roundoff.
    """
    dr = u_ext.dtype.type(dr)
    dt = u_ext.dtype.type(dt)
    u0 = _refresh_np(u_ext.copy(), left_phys, right_phys)
    u1 = u0 + dt * _rhs_np(u0, r_ext, dr, p)
    u1 = _refresh_np(u1, left_phys, right_phys)
    u2 = u0.dtype.type(0.75) * u0 + u0.dtype.type(0.25) * \
        (u1 + dt * _rhs_np(u1, r_ext, dr, p))
    u2 = _refresh_np(u2, left_phys, right_phys)
    u3 = u0 / u0.dtype.type(3.0) + u0.dtype.type(2.0 / 3.0) * \
        (u2 + dt * _rhs_np(u2, r_ext, dr, p))
    u3 = _refresh_np(u3, left_phys, right_phys)
    return u3[:, H:-H]


@partial(jax.jit, static_argnames=("dr", "dt", "p"))
def global_step(u: jnp.ndarray, r: jnp.ndarray, dr: float, dt: float,
                p: int) -> jnp.ndarray:
    """Reference RK3 step on the whole level array (the jnp oracle).

    Pads with physical ghosts on both sides and runs the identical fused
    kernel, so block-decomposed execution at ANY granularity must agree
    bitwise (tests/test_amr_equivalence.py).
    """
    dtype = u.dtype
    pad = jnp.zeros((NFIELDS, H), dtype)
    u_ext = jnp.concatenate([pad, u, pad], axis=-1)
    r_ext = jnp.concatenate([
        r[0] + (jnp.arange(-H, 0, dtype=dtype)) * dr,
        r,
        r[-1] + (jnp.arange(1, H + 1, dtype=dtype)) * dr,
    ])
    return fused_rk3_block(u_ext, r_ext, dr, dt, p,
                           left_phys=True, right_phys=True)


def grid(prob: WaveProblem, level_dr: float | None = None,
         n: int | None = None, offset: int = 0) -> jnp.ndarray:
    dr = prob.dr if level_dr is None else level_dr
    n = prob.n_points if n is None else n
    return (offset + jnp.arange(n, dtype=prob.jnp_dtype())) * dr


def energy(u: jnp.ndarray, r: jnp.ndarray, dr: float) -> jnp.ndarray:
    """Diagnostic energy integral E = int (Pi^2 + Phi^2) r^2 dr.

    Not conserved for p=7 (the nonlinearity pumps energy) but smooth in
    time; used by tests as a NaN/blow-up sentinel and by the criticality
    driver as the collapse indicator.
    """
    dens = (u[2] ** 2 + u[1] ** 2) * r * r
    return jnp.sum(dens) * dr


def linf(u: jnp.ndarray) -> jnp.ndarray:
    return jnp.max(jnp.abs(u))
