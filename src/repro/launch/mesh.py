"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required for the dry-run's
XLA_FLAGS ordering contract (launch/dryrun.py sets the flag before ANY
jax-touching import).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.distributed.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever devices exist locally, as (data, model)."""
    n = len(jax.devices())
    return make_mesh((n // model, model), ("data", "model"))


# TPU v5e constants for the roofline analysis (task statement).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
