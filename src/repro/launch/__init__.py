"""launch subpackage."""
