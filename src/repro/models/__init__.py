"""models subpackage."""
