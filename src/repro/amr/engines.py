"""The two AMR execution engines the paper compares (Sec. IV).

`BarrierEngine`  — the CSP/MPI baseline: lockstep Berger-Oliger with a
global barrier after every (level, substep) op, static contiguous block
ownership.  "If a global timestep barrier were in place, all points in
the computational domain would have to wait for the slowest point in
the domain to update before proceeding."

`DataflowEngine` — barrier-free ParalleX execution: the window task
graph runs under the work-queue execution model; values flow through
dataflow LCO edges; load balance emerges from the queue ("the thread
task manager acts as load balancer ensuring that processors have a
steady stream of tasks").

Both engines execute the SAME op stream / task graph, so their final
states agree to float associativity (tested), and both report a
`ScheduleResult` from the identical cost model — makespans are directly
comparable, which is how benchmarks/fig6-8 reproduce the paper's
comparisons.  Regridding runs between windows (an AGAS event).
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.amr import hierarchy as hi
from repro.amr import regrid as rg
from repro.amr import taskgraph as tg
from repro.amr.wave import WaveProblem
from repro.core.scheduler import (ScheduleResult, barrier_schedule,
                                  list_schedule, pack_rounds)


@dataclasses.dataclass
class EngineConfig:
    grain: int = 16
    n_workers: int = 4
    overhead: float = 4.0e-6          # sigma: Fig 9's 3-5 us midpoint
    barrier_cost: float = 2.0e-5      # per-phase global-barrier cost
    comm_latency: float = 1.0e-6      # parcel hop latency (dataflow)
    cost: tg.CostModel = dataclasses.field(default_factory=tg.CostModel)
    policy: str = "local_stealing"
    placement: str = "contiguous"
    regrid_threshold: Optional[float] = None   # None = static hierarchy
    max_levels: int = 3


@dataclasses.dataclass
class WindowResult:
    schedule: ScheduleResult
    graph_work: float
    graph_span: float
    n_tasks: int
    wallclock_s: float
    window_graph: "tg.WindowGraph"


@dataclasses.dataclass
class RunResult:
    states: List[hi.LevelState]
    windows: List[WindowResult]

    @property
    def makespan(self) -> float:
        return float(sum(w.schedule.makespan for w in self.windows))

    @property
    def wallclock(self) -> float:
        return float(sum(w.wallclock_s for w in self.windows))

    @property
    def total_tasks(self) -> int:
        return int(sum(w.n_tasks for w in self.windows))


class _EngineBase:
    mode = "abstract"

    def __init__(self, prob: WaveProblem, cfg: EngineConfig):
        self.prob = prob
        self.cfg = cfg

    def _schedule(self, wg: tg.WindowGraph) -> ScheduleResult:
        raise NotImplementedError

    def run(self, specs: Sequence[hi.LevelSpec], n_coarse: int,
            window: int = 4,
            states: Optional[List[hi.LevelState]] = None) -> RunResult:
        specs = list(specs)
        states = states or hi.make_hierarchy(self.prob, specs)
        windows: List[WindowResult] = []
        done = 0
        while done < n_coarse:
            w = min(window, n_coarse - done)
            wg = tg.build_window_graph(specs, w, self.cfg.grain,
                                       self.cfg.cost)
            tg.assign_owners(wg, self.cfg.n_workers, self.cfg.placement)
            t0 = time.perf_counter()
            states = tg.run_window(wg, states, self.prob)
            wall = time.perf_counter() - t0
            sched = self._schedule(wg)
            windows.append(WindowResult(
                sched, wg.graph.work(),
                wg.graph.span(self.cfg.overhead), len(wg.graph), wall, wg))
            done += w
            if self.cfg.regrid_threshold is not None and done < n_coarse:
                new_specs = rg.propose_specs(
                    states, self.prob, self.cfg.regrid_threshold,
                    self.cfg.max_levels)
                if [s.__dict__ for s in new_specs] != \
                        [s.__dict__ for s in specs]:
                    states = rg.transfer(states, new_specs, self.prob)
                    specs = new_specs
        return RunResult(states, windows)


class BarrierEngine(_EngineBase):
    """MPI-style: global barrier per (level, substep) op."""

    mode = "barrier"

    def _schedule(self, wg: tg.WindowGraph) -> ScheduleResult:
        return barrier_schedule(
            wg.graph, self.cfg.n_workers, overhead=self.cfg.overhead,
            barrier_cost=self.cfg.barrier_cost)


class DataflowEngine(_EngineBase):
    """ParalleX: point-to-point LCO synchronization, work queues."""

    mode = "dataflow"

    def _schedule(self, wg: tg.WindowGraph) -> ScheduleResult:
        return list_schedule(
            wg.graph, self.cfg.n_workers, overhead=self.cfg.overhead,
            policy=self.cfg.policy, comm_latency=self.cfg.comm_latency)


class CompiledDataflowEngine(_EngineBase):
    """The compiled wavefront: rounds as batched launches.

    Models the schedule that amr/compiled.py lowers to XLA: per-task
    overhead is zero (paid at compile time), one round-launch overhead
    per wavefront instead.
    """

    mode = "compiled"
    round_overhead: float = 2.0e-6

    def _schedule(self, wg: tg.WindowGraph) -> ScheduleResult:
        rs = pack_rounds(wg.graph, self.cfg.n_workers)
        ms = rs.makespan(wg.graph, self.round_overhead)
        # Synthesize a ScheduleResult-compatible record for reporting.
        n = len(wg.graph)
        finish = np.zeros(n)
        start = np.zeros(n)
        worker = np.zeros(n, np.int32)
        busy = np.zeros(self.cfg.n_workers)
        t = 0.0
        for rnd in rs.rounds:
            dur = max((sum(wg.graph.tasks[x].cost for x in wl)
                       for wl in rnd), default=0.0)
            for wkr, wl in enumerate(rnd):
                off = 0.0
                for x in wl:
                    start[x] = t + off
                    off += wg.graph.tasks[x].cost
                    finish[x] = t + off
                    worker[x] = wkr
                    busy[wkr] += wg.graph.tasks[x].cost
            t += dur + self.round_overhead
        return ScheduleResult(t, finish, start, worker, busy, 0,
                              "compiled_rounds", self.cfg.n_workers, 0.0)


def compare_engines(prob: WaveProblem, specs: Sequence[hi.LevelSpec],
                    n_coarse: int, cfg: EngineConfig
                    ) -> Tuple[RunResult, RunResult]:
    """Run both engines on identical work; verify state agreement."""
    df = DataflowEngine(prob, cfg).run(specs, n_coarse)
    ba = BarrierEngine(prob, cfg).run(specs, n_coarse)
    for a, b in zip(df.states, ba.states):
        pa, pb = a.spec.proper_extent
        np.testing.assert_allclose(
            np.asarray(a.arr[:, pa:pb]), np.asarray(b.arr[:, pa:pb]),
            atol=1e-6, err_msg="engines diverged — dependence bug")
    return df, ba
