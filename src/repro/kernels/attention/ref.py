"""Pure-jnp oracles: flash kernel (chunked online softmax) and the
gather-based paged attention ops (decode and chunked prefill)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import flash_jnp, repeat_kv


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray,
                        v: jnp.ndarray, *, causal: bool = True,
                        window: int = 0,
                        q_offset: int = 0) -> jnp.ndarray:
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D).  Returns (B, Sq, H, D)."""
    n_rep = q.shape[2] // k.shape[2]
    k = repeat_kv(k, n_rep)
    v = repeat_kv(v, n_rep)
    return flash_jnp(q, k, v, causal=causal, window=window,
                     q_offset=q_offset,
                     chunk_q=min(128, q.shape[1]),
                     chunk_k=min(128, k.shape[1]))


def _gather_pages(pages: jnp.ndarray, block_tables: jnp.ndarray,
                  b: int, kvh: int, d: int) -> jnp.ndarray:
    """Resolve block-table rows to page contents: (B, P*ps, KV, D).

    Flat pool: pages (N, ps, KV, D), rows index axis 0 directly.
    Sharded pool (DESIGN.md §4c): pages (S, R, ps, KV, D) — one AGAS
    locality per leading-axis shard — and each row encodes
    ``locality * R + slot``, so the gather decodes (locality, slot)
    and reads the page on the shard that owns it (under a mesh the
    locality axis is sharded over "kv" and GSPMD lowers the cross-
    shard reads to collectives).
    """
    if pages.ndim == 5:
        rps = pages.shape[1]
        out = pages[block_tables // rps, block_tables % rps]
    else:
        out = pages[block_tables]
    return out.reshape(b, -1, kvh, d)


def paged_attention_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray,
                        block_tables: jnp.ndarray,
                        positions: jnp.ndarray, *,
                        window: int = 0) -> jnp.ndarray:
    """Gather-based paged decode attention (one layer, one new token).

    q:            (B, 1, H, D) query for the token being decoded.
    k/v_pages:    (N, ps, KV, D) page pool rows (N includes the null
                  row idle slots point at), or (S, R, ps, KV, D) for a
                  locality-sharded pool (see _gather_pages).
    block_tables: (B, P) int32 physical page rows per slot; entries
                  past the slot's length may be any valid row (masked).
    positions:    (B,) int32 absolute position of the new token per
                  slot — the per-slot clock.  The new token's K/V must
                  already be written at its page slot.
    window > 0 restricts each slot to its trailing `window` positions
    (the ring-buffer SWA semantics, expressed as an absolute-position
    mask because pages are never trimmed).
    """
    b, _, h, d = q.shape
    kvh = k_pages.shape[-2]
    k = _gather_pages(k_pages, block_tables, b, kvh, d)
    v = _gather_pages(v_pages, block_tables, b, kvh, d)
    n_rep = h // kvh
    k = repeat_kv(k, n_rep)
    v = repeat_kv(v, n_rep)
    scale = d ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    j = jnp.arange(k.shape[1])
    mask = j[None, :] <= positions[:, None]
    if window > 0:
        mask &= positions[:, None] - j[None, :] < window
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return out.astype(q.dtype)


def paged_prefill_attention_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                                v_pages: jnp.ndarray,
                                block_tables: jnp.ndarray,
                                start: jnp.ndarray, *,
                                window: int = 0) -> jnp.ndarray:
    """Gather-based chunked-prefill attention (one layer, T chunk
    tokens at absolute positions start..start+T-1).

    q:            (B, T, H, D) queries for the chunk being prefilled.
    k/v_pages:    (N, ps, KV, D) page pool rows — or (S, R, ps, KV, D)
                  for a locality-sharded pool (see _gather_pages); the
                  chunk's own K/V must already be written into its
                  pages.
    block_tables: (B, P) int32 physical page rows per slot.
    start:        (B,) int32 absolute position of q[:, 0] — query t
                  attends key positions <= start + t (causal across
                  earlier chunks AND within this chunk).
    window > 0 additionally restricts each query to its trailing
    `window` positions (absolute-position SWA mask; pages are never
    trimmed).
    """
    b, t, h, d = q.shape
    kvh = k_pages.shape[-2]
    k = _gather_pages(k_pages, block_tables, b, kvh, d)
    v = _gather_pages(v_pages, block_tables, b, kvh, d)
    n_rep = h // kvh
    k = repeat_kv(k, n_rep)
    v = repeat_kv(v, n_rep)
    scale = d ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    j = jnp.arange(k.shape[1])
    qpos = start[:, None] + jnp.arange(t)[None, :]       # (B, T)
    mask = j[None, None, :] <= qpos[:, :, None]          # (B, T, K)
    if window > 0:
        mask &= qpos[:, :, None] - j[None, None, :] < window
    s = jnp.where(mask[:, None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return out.astype(q.dtype)
