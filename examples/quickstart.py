"""Quickstart: the paper's claim in 60 seconds.

Runs the same 3-level AMR problem under the MPI-style barrier engine
and the ParalleX dataflow engine, verifies they compute identical
physics, and prints the schedule comparison + the Fig-5 cone.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import amr
from repro.amr import taskgraph as tg
from repro.core import list_schedule


def main():
    prob = amr.WaveProblem(n_points=256, rmax=20.0, amplitude=0.005)
    specs = amr.default_specs(prob, 3)
    cfg = amr.EngineConfig(grain=8, n_workers=8)
    print("running barrier (MPI-style) and dataflow (ParalleX) "
          "engines on identical work...")
    df, ba = amr.compare_engines(prob, specs, 4, cfg)
    print(f"  physics identical: yes (asserted)")
    print(f"  barrier  makespan: {ba.makespan * 1e3:8.3f} ms")
    print(f"  dataflow makespan: {df.makespan * 1e3:8.3f} ms  "
          f"({ba.makespan / df.makespan:.2f}x faster)")

    # the Fig-5 cone under a FIFO work queue
    wg = tg.build_window_graph(specs, 4, 8)
    tg.assign_owners(wg, 8)
    r = list_schedule(wg.graph, 8, overhead=4e-6,
                      priority=lambda t: t.tid)
    front = tg.timestep_front(wg, r.finish, r.makespan * 0.5,
                              prob.n_points)
    print("\ntimestep front at 50% wall-clock (paper Fig 5): each "
          "char = 8 points,\nheight = steps completed (finest region "
          "lags -> upward-opening cone):")
    ds = front[::8]
    for level in np.arange(4, -0.5, -0.5):
        row = "".join("#" if f >= level - 1e-9 else " " for f in ds)
        print(f"  {level:3.1f} |{row}")


if __name__ == "__main__":
    main()
