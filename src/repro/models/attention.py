"""Attention: GQA, RoPE (full / partial-2d), sliding window, cross-attn,
chunked-flash prefill, and sequence-sharded decode.

Design notes (DESIGN.md §6):
* Prefill/train uses a chunked online-softmax attention (`flash_jnp`)
  whose memory is O(S * chunk) rather than O(S^2) — the pure-jnp twin
  of kernels/attention (the Pallas TPU kernel), selected by
  `use_pallas`.
* Decode attends one query against a KV cache laid out (B, S, KV, D).
  Under the production sharding the cache's S axis is sharded over the
  "model" mesh axis (context parallelism): the partial-softmax combine
  (m, l, o) is an associative reduction the SPMD partitioner lowers to
  one small all-reduce — this works for any kv-head count, which is why
  it is the default decode plan (chatglm has kv=2 < 16-way TP).
* Sliding-window archs (danube, mixtral) cap their decode cache at the
  window size — the sub-quadratic property that qualifies them for the
  long_500k cell.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import Params, _init_dense


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: jnp.ndarray, rot_dim: int, theta: float
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(positions...) -> cos/sin of shape (..., rot_dim/2)."""
    freqs = 1.0 / (theta ** (
        jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
               fraction: float = 1.0) -> jnp.ndarray:
    """Rotary embedding on the first `fraction` of head dims.

    x: (..., S, H, D); cos/sin: (S, rot/2).  chatglm3's "2d RoPE"
    rotates only the first half of each head (fraction=0.5), leaving
    the rest as pass-through channels.
    """
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    xr = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([xr, xp], axis=-1) if rot < d else xr


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ArchConfig, d_in: Optional[int] = None) -> Params:
    d = d_in or cfg.d_model
    hd, h, kv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wq": _init_dense(ks[0], d, h * hd, dt),
        "wk": _init_dense(ks[1], d, kv * hd, dt),
        "wv": _init_dense(ks[2], d, kv * hd, dt),
        "wo": _init_dense(ks[3], h * hd, cfg.d_model, dt),
    }


def qkv(params: Params, x: jnp.ndarray, cfg: ArchConfig
        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (x @ params["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ params["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, kv, d = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (b, s, kv, n_rep, d)
    ).reshape(b, s, kv * n_rep, d)


# ---------------------------------------------------------------------------
# Chunked flash attention (jnp oracle of kernels/attention)
# ---------------------------------------------------------------------------

def flash_jnp(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, window: int = 0,
              q_offset: int = 0, chunk_q: int = 512,
              chunk_k: int = 512) -> jnp.ndarray:
    """Online-softmax attention, O(S*chunk) memory.

    q: (B, Sq, H, D); k/v: (B, Sk, H, D) (kv already head-repeated).
    window > 0 restricts to keys within `window` positions before the
    query (sliding-window attention).  q_offset is the absolute
    position of q[0] relative to k[0] (for decode/continuation).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    nq = max(sq // chunk_q, 1)
    cq = sq // nq
    nk = max(sk // chunk_k, 1)
    ck = sk // nk
    scale = d ** -0.5
    qs = q.reshape(b, nq, cq, h, d).transpose(1, 0, 3, 2, 4)  # nq,b,h,cq,d
    ks_ = k.reshape(b, nk, ck, h, d).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, nk, ck, h, d).transpose(1, 0, 3, 2, 4)

    def q_block(qi_q):
        qi, qb = qi_q
        q_pos = q_offset + qi * cq + jnp.arange(cq)

        def k_step(carry, ki_kb):
            m, l, acc = carry
            ki, kb, vb = ki_kb
            k_pos = ki * ck + jnp.arange(ck)
            s_ = jnp.einsum("bhqd,bhkd->bhqk", qb, kb,
                            preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window > 0:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            s_ = jnp.where(mask[None, None], s_, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s_, axis=-1))
            # guard fully-masked rows (all -inf)
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s_ - m_safe[..., None])
            p = jnp.where(mask[None, None], p, 0.0)
            corr = jnp.exp(
                jnp.where(jnp.isinf(m), 0.0, m) - m_safe)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, h, cq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0),
            (jnp.arange(nk), ks_, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)

    outs = jax.lax.map(q_block, (jnp.arange(nq), qs))     # nq,b,h,cq,d
    return outs.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, d)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              cfg: ArchConfig, causal: bool = True,
              q_offset: int = 0, use_pallas: bool = False,
              chunk_q: int = 512, chunk_k: int = 512) -> jnp.ndarray:
    """Full prefill/train attention with GQA repeat + window."""
    n_rep = cfg.n_heads // max(cfg.n_kv_heads, 1)
    k = repeat_kv(k, n_rep)
    v = repeat_kv(v, n_rep)
    if use_pallas:
        from repro.kernels.attention.ops import flash_attention
        return flash_attention(q, k, v, causal=causal,
                               window=cfg.sliding_window,
                               q_offset=q_offset)
    return flash_jnp(q, k, v, causal=causal, window=cfg.sliding_window,
                     q_offset=q_offset, chunk_q=chunk_q, chunk_k=chunk_k)


# ---------------------------------------------------------------------------
# Decode (one new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len: jnp.ndarray,
                     cfg: ArchConfig) -> jnp.ndarray:
    """q: (B, 1, H, D); caches: (B, S, KV, D); cache_len: () valid len.

    Computed as masked full attention over the cache: with the cache's
    S axis sharded over "model", XLA's partitioner reduces the softmax
    stats across shards (the log-sum-exp combine) — flash-decoding's
    parallelism for free.
    """
    from repro.models.layers import constrain_spec
    n_rep = cfg.n_heads // max(cfg.n_kv_heads, 1)
    k = repeat_kv(k_cache, n_rep)
    v = repeat_kv(v_cache, n_rep)
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    # Split-K (flash-decoding): keep the score/softmax S axis sharded
    # over "model" so the partitioner reduces softmax statistics and
    # the PV product across shards (two tiny all-reduces) instead of
    # ALL-GATHERING the sequence-sharded KV cache (which cost ~34 GB
    # per decode step at 32k context — §Perf fix F3).
    s = constrain_spec(s, "U", "U", "U", "model")
    # SWA caches are already window-sized ring buffers, so validity is
    # purely a slot count (softmax is permutation-invariant over keys
    # whose RoPE phases were baked at write time).
    pos = jnp.arange(k.shape[1])
    mask = pos[None, None, None, :] < cache_len
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    p = constrain_spec(p, "U", "U", "U", "model")
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return out.astype(q.dtype)
