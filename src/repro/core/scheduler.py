"""The dataflow scheduler: task DAG -> (a) work-queue execution model,
(b) compiled round/wavefront schedules.

This is the framework's rendering of the HPX thread manager (paper,
Sec. II "Threads and their Management" and Fig 1): a work-queue based
execution model with a *global queue* policy and a *local priority queue
with work stealing* policy.  Because the container (and a TPU) cannot
host a real preemptive thread pool per device, the scheduler is split:

* `list_schedule` — a deterministic discrete-event execution model of P
  workers pulling from work queues, with per-task management overhead
  sigma (the paper's measured 3-5 us per HPX-thread, Fig 9) and optional
  inter-locality parcel latency.  All of the paper's scheduling claims
  (Figs 3, 5, 6, 7, 8, 9) are reproduced on this model with *real task
  costs measured on this machine* feeding it.

* `barrier_schedule` — the CSP/MPI baseline: static block ownership,
  bulk-synchronous phases, a global barrier per phase.

* `pack_rounds` — the compiled path: ASAP wavefront levels, LPT-balanced
  per-round worker assignment.  amr/compiled.py turns these rounds into
  a single XLA program (shard_map + ppermute); per-task overhead at run
  time is ~0 because the schedule is a compiled constant (DESIGN.md §2).

The same `TaskGraph` feeds all three, so baseline and dataflow runs are
guaranteed to execute identical work.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict, deque
from typing import Any, Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np


class ScheduleError(RuntimeError):
    pass


@dataclasses.dataclass
class Task:
    tid: int
    cost: float                     # useful work (seconds or model units)
    key: Hashable = None            # app meta, e.g. (level, block, step)
    owner: int = 0                  # static placement (locality id)
    phase: Hashable = None          # barrier phase key (e.g. global substep)
    deps: List[int] = dataclasses.field(default_factory=list)
    succs: List[int] = dataclasses.field(default_factory=list)


class TaskGraph:
    """A DAG of tasks; the host-side image of the dataflow LCO network.

    Each dependence edge is conceptually one LCO: the successor's
    dataflow object counts down as predecessors finish (see
    core/lco.DependencyCounter, which `list_schedule` instantiates).
    """

    def __init__(self):
        self.tasks: List[Task] = []
        self._by_key: Dict[Hashable, int] = {}

    def add(self, cost: float, key: Hashable = None, owner: int = 0,
            phase: Hashable = None, deps: Sequence[int] = ()) -> int:
        tid = len(self.tasks)
        t = Task(tid, float(cost), key, owner, phase, list(deps))
        self.tasks.append(t)
        if key is not None:
            if key in self._by_key:
                raise ScheduleError(f"duplicate task key {key!r}")
            self._by_key[key] = tid
        for d in t.deps:
            self.tasks[d].succs.append(tid)
        return tid

    def add_dep(self, tid: int, dep: int) -> None:
        self.tasks[tid].deps.append(dep)
        self.tasks[dep].succs.append(tid)

    def by_key(self, key: Hashable) -> int:
        return self._by_key[key]

    def has_key(self, key: Hashable) -> bool:
        return key in self._by_key

    def __len__(self) -> int:
        return len(self.tasks)

    # -- analysis ----------------------------------------------------------
    def topo_order(self) -> List[int]:
        indeg = [len(t.deps) for t in self.tasks]
        q = deque(t.tid for t in self.tasks if not t.deps)
        order = []
        while q:
            v = q.popleft()
            order.append(v)
            for s in self.tasks[v].succs:
                indeg[s] -= 1
                if indeg[s] == 0:
                    q.append(s)
        if len(order) != len(self.tasks):
            raise ScheduleError("task graph has a cycle")
        return order

    def work(self) -> float:
        """T_1: total useful work."""
        return float(sum(t.cost for t in self.tasks))

    def span(self, overhead: float = 0.0) -> float:
        """T_inf: critical-path length (with per-task overhead included)."""
        dist = [0.0] * len(self.tasks)
        for v in self.topo_order():
            t = self.tasks[v]
            base = max((dist[d] for d in t.deps), default=0.0)
            dist[v] = base + t.cost + overhead
        return max(dist, default=0.0)

    def depth_levels(self) -> List[int]:
        """ASAP level of each task (longest #edges from any root)."""
        lvl = [0] * len(self.tasks)
        for v in self.topo_order():
            t = self.tasks[v]
            lvl[v] = max((lvl[d] + 1 for d in t.deps), default=0)
        return lvl


@dataclasses.dataclass
class ScheduleResult:
    makespan: float
    finish: np.ndarray          # per-task finish time
    start: np.ndarray           # per-task start time
    worker: np.ndarray          # per-task executing worker
    busy: np.ndarray            # per-worker busy time (incl. overhead)
    steals: int
    policy: str
    n_workers: int
    overhead: float

    @property
    def idle_fraction(self) -> float:
        if self.makespan <= 0:
            return 0.0
        return float(1.0 - self.busy.sum() / (self.makespan * self.n_workers))

    @property
    def speedup_vs_serial(self) -> float:
        serial = float(self.busy.sum())
        return serial / self.makespan if self.makespan > 0 else 1.0


def list_schedule(
    graph: TaskGraph,
    n_workers: int,
    overhead: float = 0.0,
    policy: str = "local_stealing",
    comm_latency: float = 0.0,
    priority: Optional[Callable[[Task], float]] = None,
) -> ScheduleResult:
    """Deterministic work-queue execution model (the HPX thread manager).

    policy:
      "global_queue"    — one shared queue, workers pull in FIFO order
                          (HPX "global queue scheduler").
      "local_stealing"  — per-worker queues keyed by task.owner; an idle
                          worker pulls its own queue front, else steals
                          from the back of the longest queue (HPX "local
                          priority scheduler" with work stealing).

    overhead      — per-task management cost sigma (thread create/schedule/
                    destroy — Fig 9's measured quantity).
    comm_latency  — added to a dependence edge when predecessor ran on a
                    different worker than task.owner (a parcel hop).
    priority      — optional task priority (smaller first); default is
                    critical-path-from-task (longest downstream work),
                    matching an LPT-flavoured priority queue.
    """
    n = len(graph)
    if n == 0:
        return ScheduleResult(0.0, np.zeros(0), np.zeros(0),
                              np.zeros(0, np.int32), np.zeros(n_workers),
                              0, policy, n_workers, overhead)

    # Downstream critical path as default priority (negated: larger = first).
    if priority is None:
        down = [0.0] * n
        for v in reversed(graph.topo_order()):
            t = graph.tasks[v]
            down[v] = t.cost + max((down[s] for s in t.succs), default=0.0)
        prio = [-down[v] for v in range(n)]
    else:
        prio = [priority(graph.tasks[v]) for v in range(n)]

    remaining = [len(t.deps) for t in graph.tasks]
    ready_time = [0.0] * n      # earliest start due to deps (+ parcels)
    finish = np.zeros(n)
    start = np.zeros(n)
    worker_of = np.full(n, -1, np.int32)
    busy = np.zeros(n_workers)
    steals = 0

    if policy == "global_queue":
        queues = [[]]
        home = lambda t: 0
    elif policy == "local_stealing":
        queues = [[] for _ in range(n_workers)]
        home = lambda t: t.owner % n_workers
    else:
        raise ScheduleError(f"unknown policy {policy!r}")

    def push(tid: int):
        t = graph.tasks[tid]
        heapq.heappush(queues[home(t)], (prio[tid], tid))

    for t in graph.tasks:
        if not t.deps:
            push(t.tid)

    # Event loop: (time, worker) of workers becoming free; all free at 0.
    free = [(0.0, w) for w in range(n_workers)]
    heapq.heapify(free)
    # Tasks whose deps are met but whose ready_time is in the future get
    # re-queued as timed events.
    pending_events: List[Tuple[float, int]] = []   # (ready_time, tid)
    done_count = 0
    now = 0.0

    def pop_for(w: int) -> Optional[Tuple[int, bool]]:
        """Return (tid, stolen) or None."""
        if policy == "global_queue":
            if queues[0]:
                return heapq.heappop(queues[0])[1], False
            return None
        if queues[w]:
            return heapq.heappop(queues[w])[1], False
        # steal from the longest queue (deterministic tie-break: low id)
        best, best_len = -1, 0
        for i, q in enumerate(queues):
            if len(q) > best_len:
                best, best_len = i, len(q)
        if best >= 0:
            # steal the *worst-priority* (back) item: nlargest-1 pop
            victim = queues[best]
            item = max(victim)      # largest prio value = least urgent
            victim.remove(item)
            heapq.heapify(victim)
            return item[1], True
        return None

    idle_workers: List[Tuple[float, int]] = []
    while done_count < n:
        # Release timed tasks that became ready.
        while pending_events and pending_events[0][0] <= now + 1e-18:
            _, tid = heapq.heappop(pending_events)
            push(tid)
        progressed = False
        while free:
            t_free, w = free[0]
            if t_free > now + 1e-18:
                break
            got = pop_for(w)
            if got is None:
                break
            heapq.heappop(free)
            tid, stolen = got
            steals += int(stolen)
            t = graph.tasks[tid]
            s = max(now, t_free, ready_time[tid])
            e = s + overhead + t.cost
            start[tid], finish[tid], worker_of[tid] = s, e, w
            busy[w] += overhead + t.cost
            heapq.heappush(free, (e, w))
            progressed = True
            # Dependence bookkeeping (the DependencyCounter firing).
            for succ in t.succs:
                lat = comm_latency if graph.tasks[succ].owner % n_workers != w else 0.0
                ready_time[succ] = max(ready_time[succ], e + lat)
                remaining[succ] -= 1
                if remaining[succ] == 0:
                    if ready_time[succ] <= e + 1e-18:
                        push(succ)
                    else:
                        heapq.heappush(pending_events,
                                       (ready_time[succ], succ))
            done_count += 1
        if done_count >= n:
            break
        if not progressed:
            # Advance time to the next event: a worker finishing or a
            # pending task becoming ready.
            candidates = []
            if free:
                candidates.append(free[0][0])
            if pending_events:
                candidates.append(pending_events[0][0])
            nxt = min(c for c in candidates if c > now + 1e-18) \
                if any(c > now + 1e-18 for c in candidates) else None
            if nxt is None:
                raise ScheduleError("scheduler deadlock (cycle or lost task)")
            now = nxt
        else:
            now = max(now, min(t for t, _ in free)) if free else now

    return ScheduleResult(float(finish.max()), finish, start, worker_of,
                          busy, steals, policy, n_workers, overhead)


def barrier_schedule(
    graph: TaskGraph,
    n_workers: int,
    overhead: float = 0.0,
    barrier_cost: float = 0.0,
    comm_cost_per_phase: float = 0.0,
) -> ScheduleResult:
    """The CSP/MPI baseline: static ownership + a global barrier per phase.

    Tasks are grouped by `task.phase` (e.g. the global substep index);
    each phase ends with a global barrier, so the phase costs the *max*
    over workers of their owned work — the paper's "all points ... wait
    for the slowest point in the domain" (Sec. IV).  Dependences are
    validated to cross phases in order (a barrier violation is a bug in
    the task-graph builder, not something to silently absorb).
    """
    n = len(graph)
    phases: Dict[Hashable, List[int]] = defaultdict(list)
    for t in graph.tasks:
        if t.phase is None:
            raise ScheduleError(f"task {t.tid} has no barrier phase")
        phases[t.phase].append(t.tid)
    order = sorted(phases)
    phase_rank = {p: i for i, p in enumerate(order)}
    for t in graph.tasks:
        for d in t.deps:
            if phase_rank[graph.tasks[d].phase] > phase_rank[t.phase]:
                raise ScheduleError(
                    f"dep {d}->{t.tid} runs backwards across barriers")

    finish = np.zeros(n)
    start = np.zeros(n)
    worker_of = np.full(n, -1, np.int32)
    busy = np.zeros(n_workers)
    now = 0.0
    for p in order:
        loads = np.zeros(n_workers)
        for tid in phases[p]:
            w = graph.tasks[tid].owner % n_workers
            start[tid] = now + loads[w]
            loads[w] += overhead + graph.tasks[tid].cost
            finish[tid] = now + loads[w]
            worker_of[tid] = w
            busy[w] += overhead + graph.tasks[tid].cost
        now += float(loads.max()) + barrier_cost + comm_cost_per_phase
    return ScheduleResult(now, finish, start, worker_of, busy, 0,
                          "barrier", n_workers, overhead)


@dataclasses.dataclass
class RoundSchedule:
    """A compiled wavefront schedule: the LCO graph erased into rounds.

    rounds[r][w] is the ordered list of task ids worker/locality w runs
    in round r.  All dependences point to strictly earlier rounds, so a
    round is a data-parallel batch — on device it is ONE batched kernel
    launch over its tasks plus one halo-parcel exchange.
    """

    rounds: List[List[List[int]]]
    n_workers: int

    def makespan(self, graph: TaskGraph, round_overhead: float = 0.0) -> float:
        total = 0.0
        for r in self.rounds:
            total += max((sum(graph.tasks[t].cost for t in wl) for wl in r),
                         default=0.0) + round_overhead
        return total

    def validate(self, graph: TaskGraph) -> None:
        round_of = {}
        for ri, r in enumerate(self.rounds):
            for wl in r:
                for t in wl:
                    round_of[t] = ri
        if len(round_of) != len(graph):
            raise ScheduleError("round schedule drops or repeats tasks")
        for t in graph.tasks:
            for d in t.deps:
                if round_of[d] >= round_of[t.tid]:
                    raise ScheduleError(
                        f"dep {d}->{t.tid} not strictly earlier round")


def pack_rounds(graph: TaskGraph, n_workers: int,
                balance: bool = True) -> RoundSchedule:
    """ASAP wavefront rounds + LPT per-round balancing.

    With `balance=False` tasks stay on their static owner (the
    MPI-decomposition flavour, for A/B comparisons); with True, tasks in
    a round are LPT-packed across workers — the static image of work
    stealing.  Mixed AMR levels naturally share rounds, which is exactly
    how the paper's "coarse points run ahead" cone materializes in a
    compiled program.
    """
    lvls = graph.depth_levels()
    n_rounds = (max(lvls) + 1) if lvls else 0
    rounds: List[List[List[int]]] = [
        [[] for _ in range(n_workers)] for _ in range(n_rounds)
    ]
    by_round: Dict[int, List[int]] = defaultdict(list)
    for tid, l in enumerate(lvls):
        by_round[l].append(tid)
    for r in range(n_rounds):
        tids = by_round[r]
        if balance:
            tids = sorted(tids, key=lambda t: -graph.tasks[t].cost)
            loads = np.zeros(n_workers)
            for tid in tids:
                w = int(np.argmin(loads))
                rounds[r][w].append(tid)
                loads[w] += graph.tasks[tid].cost
        else:
            for tid in tids:
                rounds[r][graph.tasks[tid].owner % n_workers].append(tid)
    sched = RoundSchedule(rounds, n_workers)
    sched.validate(graph)
    return sched


def execute_topologically(graph: TaskGraph,
                          run: Callable[[Task], None]) -> None:
    """Value-producing execution in dependence order (host engine).

    Wires real `DependencyCounter` LCOs: `run(task)` fires when the
    task's counter hits zero.  Results are whatever `run` stores —
    determinism w.r.t. scheduling order is a *property test*
    (tests/test_properties.py), because it is the correctness claim the
    paper's barrier removal rests on.
    """
    from repro.core.lco import DependencyCounter

    fire_queue: deque = deque()
    counters: List[DependencyCounter] = []

    def make_on_zero(tid: int):
        return lambda: fire_queue.append(tid)

    for t in graph.tasks:
        counters.append(DependencyCounter(len(t.deps), make_on_zero(t.tid)))

    executed = 0
    while fire_queue:
        tid = fire_queue.popleft()
        run(graph.tasks[tid])
        executed += 1
        for s in graph.tasks[tid].succs:
            counters[s].satisfy()
    if executed != len(graph):
        raise ScheduleError(
            f"only {executed}/{len(graph)} tasks fired — dependency cycle")
