"""Jitted public wrapper for the stencil kernel.

`stencil_rk3_step` is what amr/compiled.py calls when
CompiledAMRConfig.use_pallas is set: it adapts the pool layout
(slots, 3, g+2H) + broadcast masks to the kernel's (nb, ...) layout.
On CPU the kernel runs in interpret mode (env REPRO_PALLAS_INTERPRET
defaults to 1 there); on TPU set it to 0 for the compiled kernel.
"""

from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.stencil.stencil import stencil_rk3


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("dr", "dt", "p"))
def stencil_rk3_step(pool_ext: jnp.ndarray, r_ext: jnp.ndarray,
                     left_phys: jnp.ndarray, right_phys: jnp.ndarray,
                     *, dr: float, dt: float, p: int) -> jnp.ndarray:
    """(slots, 3, g+2H) -> (slots, 3, g); masks broadcast (slots,1,1)."""
    nb = pool_ext.shape[0]
    flags = jnp.stack(
        [left_phys.reshape(nb).astype(jnp.int32),
         right_phys.reshape(nb).astype(jnp.int32)], axis=-1)
    return stencil_rk3(pool_ext, r_ext, flags, dr=dr, dt=dt, p=p,
                       interpret=_interpret_default())
