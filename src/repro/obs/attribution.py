"""Per-step overhead attribution and causal-link validation.

The paper's Fig. 9 decomposes per-task cost into thread management,
parcel handling, and AGAS operations.  ``attribute`` applies the same
analysis online to serving traces: every engine ``step`` span is a
root; each span in its tree contributes its *self time* (duration minus
direct children) to the category named by its ``kind``:

- ``compute``  kernel work (prefill/chunk/decode dispatch)
- ``sched``    scheduling: admit bookkeeping, rebalance planning
- ``pages``    page accounting: attach/alloc/COW/write staging
- ``parcel``   parcel staging: migration plans, percolation prefetch
- ``copy``     host<->device copies (demote/promote/offload)
- ``other``    uncategorized runtime glue (incl. step self time)

Self times of a tree sum to the root's duration by construction, so
compute + overhead always reconciles with step wall-clock (the
``sum_residual`` in the report is float noise).  Overhead is everything
that is not ``compute``.

``check_nesting`` / ``check_causal`` validate the trace itself: child
intervals nest within parents, and every causal id resolves — rids
trace back to a submit, slot references fall inside a bind interval,
page gids are referenced only within their alloc..free lifetime (gids
are never recycled, so this is decidable).  Causal validation assumes a
complete trace: check ``tracer.dropped == 0`` before trusting it.
"""

CATEGORIES = ("compute", "sched", "pages", "parcel", "copy", "other")
ROLES = ("prefill", "decode", "handoff", "other")
_EPS = 1e-9

__all__ = ["CATEGORIES", "ROLES", "attribute", "attribute_roles",
           "check_nesting", "check_causal", "subsystems"]


def subsystems(records):
    return {r.subsystem for r in records}


def attribute(records, root_subsystem="engine", root_name="step"):
    """Decompose step wall-clock into per-category self times."""
    spans = [r for r in records if r.dur is not None]
    children = {}
    for s in spans:
        if s.parent is not None:
            children.setdefault(s.parent, []).append(s)
    steps = [s for s in spans
             if s.subsystem == root_subsystem and s.name == root_name]
    cat = {c: 0.0 for c in CATEGORIES}
    wall = 0.0
    for step in steps:
        wall += step.dur
        stack = [step]
        while stack:
            s = stack.pop()
            kids = children.get(s.sid, ())
            self_t = s.dur - sum(k.dur for k in kids)
            if self_t < 0.0:
                self_t = 0.0
            key = s.kind if s.kind in cat else "other"
            cat[key] += self_t
            stack.extend(kids)
    total = sum(cat.values())
    compute = cat["compute"]
    overhead = total - compute
    return {
        "steps": len(steps),
        "wall_ms": wall * 1e3,
        "compute_ms": compute * 1e3,
        "overhead_ms": overhead * 1e3,
        "compute_fraction": compute / wall if wall else 0.0,
        "overhead_fraction": overhead / wall if wall else 0.0,
        "categories_ms": {c: v * 1e3 for c, v in cat.items()},
        "sum_residual": abs(total - wall) / wall if wall else 0.0,
    }


_ROLE_BY_NAME = {
    "prefill": "prefill",
    "prefill_chunk": "prefill",
    "resume": "prefill",
    "decode_batch": "decode",
    "handoff_stage": "handoff",
    "handoff_commit": "handoff",
}


def span_role(span):
    """Disagg role a span's self time belongs to.

    Prefill execution (whole-prompt, chunked, compute-skip resume)
    is prefill-worker work; the decode batch is decode-worker work;
    percolation handoff stage/commit is the copy seam between them.
    Everything else (admit bookkeeping, page accounting, tier
    traffic, step glue) is role-neutral runtime -> ``other``.
    """
    return _ROLE_BY_NAME.get(span.name, "other")


def span_locality(span):
    """AGAS locality a span executed against, or None."""
    loc = span.args.get("loc")
    return loc


def attribute_roles(records, root_subsystem="engine", root_name="step"):
    """Fig. 9 buckets split by disagg role and AGAS locality.

    Same self-time tree walk as ``attribute`` — self times sum to
    step wall by construction — but each span's self time lands in
    (a) the role bucket named by the span (prefill worker vs decode
    worker vs handoff copy vs role-neutral runtime) and (b) the
    locality bucket from the span's ``loc`` arg (spans without one
    aggregate under ``"engine"``).  Under ``--disagg --kv-shards N``
    this proves *where* overhead lives: which role pays it, and on
    which locality's pool it runs.
    """
    spans = [r for r in records if r.dur is not None]
    children = {}
    for s in spans:
        if s.parent is not None:
            children.setdefault(s.parent, []).append(s)
    steps = [s for s in spans
             if s.subsystem == root_subsystem and s.name == root_name]
    roles = {r: 0.0 for r in ROLES}
    locs = {}
    wall = 0.0
    for step in steps:
        wall += step.dur
        stack = [step]
        while stack:
            s = stack.pop()
            kids = children.get(s.sid, ())
            self_t = s.dur - sum(k.dur for k in kids)
            if self_t < 0.0:
                self_t = 0.0
            roles[span_role(s)] += self_t
            lkey = span_locality(s)
            lkey = "engine" if lkey is None else f"loc{lkey}"
            locs[lkey] = locs.get(lkey, 0.0) + self_t
            stack.extend(kids)
    total = sum(roles.values())
    return {
        "steps": len(steps),
        "wall_ms": wall * 1e3,
        "roles_ms": {r: v * 1e3 for r, v in roles.items()},
        "localities_ms": {k: v * 1e3
                          for k, v in sorted(locs.items())},
        "sum_residual": abs(total - wall) / wall if wall else 0.0,
    }


def check_nesting(records):
    """Every child interval must nest within its recorded parent."""
    spans = {r.sid: r for r in records if r.dur is not None}
    problems = []
    for r in records:
        if r.parent is None:
            continue
        p = spans.get(r.parent)
        if p is None:
            continue  # parent evicted from the ring or still open
        end = r.t0 if r.dur is None else r.t0 + r.dur
        if r.t0 < p.t0 - _EPS or end > p.t0 + p.dur + _EPS:
            problems.append(
                f"{r.subsystem}/{r.name} sid={r.sid} "
                f"[{r.t0:.9f}, {end:.9f}] escapes parent "
                f"{p.subsystem}/{p.name} sid={p.sid} "
                f"[{p.t0:.9f}, {p.t0 + p.dur:.9f}]")
    return problems


def _ref_gids(r):
    gids = r.args.get("gids")
    if gids is not None:
        return gids
    g = r.args.get("gid")
    return () if g is None else (g,)


def check_causal(records):
    """request -> slot -> page links: nothing may dangle."""
    problems = []
    submitted = set()
    binds = {}    # slot -> [(t, rid), ...] in time order
    alloc_t = {}  # gid -> t   (gids never recycled)
    free_t = {}   # gid -> t
    events = sorted(records, key=lambda r: (r.t0, r.sid))
    for r in events:
        if r.subsystem == "engine":
            if r.name == "submit":
                submitted.add(r.args.get("rid"))
            elif r.name == "slot_bind":
                binds.setdefault(r.args.get("slot"), []).append(
                    (r.t0, r.args.get("rid")))
        elif r.subsystem == "kvcache":
            if r.name == "page_alloc":
                alloc_t[r.args.get("gid")] = r.t0
            elif r.name == "page_free":
                free_t[r.args.get("gid")] = r.t0
    for r in events:
        end = r.t0 if r.dur is None else r.t0 + r.dur
        rid = r.args.get("rid")
        if rid is not None and not (r.subsystem == "engine"
                                    and r.name == "submit"):
            if rid not in submitted:
                problems.append(
                    f"{r.subsystem}/{r.name}: rid {rid!r} never "
                    f"submitted")
        slot = r.args.get("slot")
        if slot is not None and r.subsystem == "kvcache":
            live = [b for b in binds.get(slot, []) if b[0] <= end + _EPS]
            if not live:
                problems.append(
                    f"{r.subsystem}/{r.name}: slot {slot} used before "
                    f"any bind")
            elif live[-1][1] not in submitted:
                problems.append(
                    f"{r.subsystem}/{r.name}: slot {slot} bound to "
                    f"unsubmitted rid {live[-1][1]!r}")
        if r.subsystem == "kvcache" and r.name in ("page_alloc",
                                                   "page_free"):
            continue
        for g in _ref_gids(r):
            at = alloc_t.get(g)
            if at is None:
                problems.append(
                    f"{r.subsystem}/{r.name}: gid {g} never allocated")
                continue
            if at > end + _EPS:
                problems.append(
                    f"{r.subsystem}/{r.name}: gid {g} referenced "
                    f"before alloc")
            ft = free_t.get(g)
            if ft is not None and ft < r.t0 - _EPS:
                problems.append(
                    f"{r.subsystem}/{r.name}: gid {g} referenced "
                    f"after free")
    return problems
