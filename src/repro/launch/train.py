"""Training driver: config -> mesh -> sharded train loop with
checkpointing, failure recovery, and straggler monitoring.

Host-scale runs (this container) use the reduced arch configs on a
(n_devices, 1) mesh; at pod scale the same driver takes the production
mesh — nothing in the loop changes, which is the point of keeping
sharding in specs rather than code.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def make_state(arch, mesh, opt_cfg):
    from repro.distributed import sharding as shd
    from repro.launch import steps as S
    from repro.models import transformer as T
    from repro.optim.adamw import init_opt_state

    tp = S.model_tp(arch, mesh)
    params_abs = S.abstract_params(arch, mesh)
    shardings = jax.tree.map(lambda a: a.sharding, params_abs)
    params = jax.jit(
        lambda k: T.init_params(k, arch, tp),
        out_shardings=shardings)(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    return params, opt


def train(arch, steps: int, batch: int, seq: int,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 20,
          log_every: int = 10, mesh=None, opt_cfg=None,
          fail_plan=None, resume: bool = True):
    from repro.checkpoint.checkpoint import Checkpointer
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    from repro.ft.failures import FailurePlan
    from repro.ft.straggler import StragglerMonitor
    from repro.launch import steps as S
    from repro.launch.mesh import make_host_mesh
    from repro.models.config import ShapeConfig
    from repro.optim.adamw import AdamWConfig

    mesh = mesh or make_host_mesh()
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps, warmup_steps=max(steps // 20, 1))
    shape = ShapeConfig("host_train", seq, batch, "train")
    corpus = SyntheticCorpus(DataConfig(arch.vocab_size, seq, batch))
    step_fn, n_accum = S.make_train_step(arch, shape, mesh, opt_cfg)
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))
    monitor = StragglerMonitor(mesh.devices.size)
    fail_plan = fail_plan or FailurePlan()
    already_failed: set = set()

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    params, opt = make_state(arch, mesh, opt_cfg)
    start = 0
    if ckpt and resume and ckpt.latest_step() is not None:
        (params, opt), extra = ckpt.restore(
            ckpt.latest_step(), (params, opt))
        start = int(extra.get("next_step", 0))
        print(f"[train] resumed from step {start}")

    losses = []
    t0 = time.perf_counter()
    i = start
    while i < steps:
        try:
            fail_plan.check(i, already_failed)
            b = corpus.batch_fast(i)
            with mesh:
                params, opt, metrics = jstep(params, opt, b)
            loss = float(metrics["loss"])
            losses.append((i, loss))
            t1 = time.perf_counter()
            monitor.observe([t1 - t0] * mesh.devices.size)
            t0 = t1
            if i % log_every == 0:
                print(f"[train] step {i:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f}",
                      flush=True)
            i += 1
            if ckpt and i % ckpt_every == 0:
                ckpt.save_async(i, (params, opt),
                                extra={"next_step": i})
        except Exception as e:
            from repro.ft.failures import InjectedFailure
            if not isinstance(e, InjectedFailure) or ckpt is None:
                raise
            print(f"[train] FAILURE at step {i}: {e}; restarting")
            ckpt.wait()
            latest = ckpt.latest_step()
            params, opt = make_state(arch, mesh, opt_cfg)
            if latest is not None:
                (params, opt), extra = ckpt.restore(latest,
                                                    (params, opt))
                i = int(extra.get("next_step", 0))
            else:
                i = 0
    if ckpt:
        ckpt.wait()
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    import repro.configs as configs
    arch = configs.get_reduced(args.arch) if args.reduced \
        else configs.get(args.arch)
    _, _, losses = train(arch, args.steps, args.batch, args.seq,
                         args.ckpt_dir, args.ckpt_every)
    first = np.mean([l for _, l in losses[:5]])
    last = np.mean([l for _, l in losses[-5:]])
    print(f"[train] loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
