"""Hypothesis property tests for the two-tier page pool (DESIGN.md
§4d).

Random interleaved alloc / incref / decref / demote-evict / promote /
prefix-register / drill sequences must preserve the tier invariants:

* every live (refcounted) page resides in exactly one tier, and its
  global name never changes across demotion/promotion;
* pages with refcount > 0 on device are never evicted (refcount
  pinning) — eviction and the demote drill only ever touch
  refcount-0 retained pages;
* a demote -> promote round trip is byte-identical;
* per-tier accounting stays consistent: free rows + resident pages
  == capacity on every locality, and `free_pages` (device rows +
  evictable cold) never exceeds the device capacity.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

import repro.configs as configs
from repro.core.agas import GlobalAddress
from repro.core.percolation import Tier
from repro.serving.kvcache import PageExhausted
from repro.serving.tiering import TieredPagePool

N_PAGES = 4
HOST_PAGES = 6
PAGE_SIZE = 4

OPS = st.lists(
    st.tuples(st.sampled_from(["alloc", "incref", "decref", "share",
                               "promote", "drill", "evict"]),
              st.integers(0, 7)),
    min_size=1, max_size=60)


def _stamp(pool, row, value):
    shape = pool.pages["k"].shape              # (L, N, ps, KV, D)
    span = jnp.full((shape[0], 1) + shape[2:], float(value),
                    pool.pages["k"].dtype)
    pool.write_pages([row], span, span)


def _content(pool, addr):
    """The stamp of a page wherever it lives (device or host)."""
    if pool.on_device(addr):
        return float(np.asarray(
            pool.pages["k"][0, pool.row(addr), 0, 0, 0]))
    return float(pool.host["k"][0, pool.host_slot(addr), 0, 0, 0])


@settings(max_examples=25, deadline=None)
@given(ops=OPS)
def test_tier_invariants_under_random_interleaving(ops):
    cfg = configs.get_reduced("yi-6b")
    pool = TieredPagePool(cfg, n_pages=N_PAGES, page_size=PAGE_SIZE,
                          host_pages=HOST_PAGES)
    held = []                   # (addr, stamp): refs we hold
    stamps = {}                 # gid -> stamped content
    next_stamp = 1
    next_key = 0

    def check_invariants():
        # 1. exactly-one-tier: the directory answers one locality per
        # live or cold gid, and the tier split covers everything
        live = set(pool._refs)
        cold = set(pool._cold)
        assert not live & cold, "a page cannot be live AND cold"
        resident = set()
        for l in range(pool.n_shards + 1):
            r = pool.agas.residents(l)
            assert not resident & r, "a page resides in two localities"
            resident |= r
        assert resident == live | cold
        # 2. per-tier accounting
        for l in range(pool.n_shards):
            assert pool.agas.free_count(l) + \
                len(pool.agas.residents(l)) == pool.pages_per_shard
        assert pool.host_free_rows + pool.host_used == pool.host_pages
        assert 0 <= pool.free_pages <= pool.capacity
        # 3. refcount pinning: everything we hold is live and its
        # content is wherever the directory says, intact
        for addr, s in held:
            assert pool.refcount(addr) >= 1
            assert _content(pool, addr) == s

    for kind, param in ops:
        if kind == "alloc":
            try:
                addr = pool.alloc()
            except PageExhausted:
                # only legal when nothing on device was evictable
                assert pool.free_pages == 0
                continue
            assert pool.on_device(addr)
            _stamp(pool, pool.row(addr), next_stamp)
            stamps[addr.gid] = next_stamp
            held.append((addr, next_stamp))
            next_stamp += 1
            # fresh pages registered so decref retains them cold
            pool.register_prefix((b"t%d" % next_key, PAGE_SIZE), addr)
            next_key += 1
        elif kind == "incref" and held:
            addr, s = held[param % len(held)]
            pool.incref(addr)
            held.append((addr, s))
        elif kind == "decref" and held:
            addr, _ = held.pop(param % len(held))
            pool.decref(addr)
        elif kind == "share" and next_key:
            key = (b"t%d" % (param % next_key), PAGE_SIZE)
            addr = pool.lookup_prefix(key)
            if addr is not None:
                was_host = not pool.on_device(addr)
                pool.incref(addr)           # pin first,
                try:
                    pool.ensure_device(addr)    # then promote
                except PageExhausted:
                    pool.discard(addr)
                    continue
                assert pool.on_device(addr)
                # demote -> promote round trip is byte-identical
                assert _content(pool, addr) == stamps[addr.gid]
                if was_host:
                    assert pool.promoted >= 1
                held.append((addr, stamps[addr.gid]))
        elif kind == "promote" and held:
            # promoting an already-device page is a no-op
            addr, s = held[param % len(held)]
            pool.promote_pages([addr])
            assert pool.on_device(addr) and _content(pool, addr) == s
        elif kind == "drill":
            pinned = {a.gid for a, _ in held}
            moved = pool.demote_all_cold()
            assert moved >= 0
            # refcount>0 pages were never touched by the drill
            for addr, s in held:
                assert pool.on_device(addr)
                assert _content(pool, addr) == s
            assert not pinned & {g for g in pool._cold
                                 if not pool.on_device(
                                     GlobalAddress(g, pool.agas.space))}
        elif kind == "evict":
            before = {a.gid for a, _ in held
                      if pool.on_device(a)}
            if pool._evict_one():
                # the evicted page was NOT one we hold a ref on
                assert {a.gid for a, _ in held
                        if pool.on_device(a)} == before
        check_invariants()

    # drain: everything restorable, accounting returns to empty
    for addr, s in held:
        assert _content(pool, addr) == s
        pool.decref(addr)
    held.clear()
    pool.drop_all_cold()
    assert pool.used_pages == 0
    assert pool.device_free_rows == pool.capacity
    assert pool.host_free_rows == pool.host_pages
    # the pool is fully reusable after the storm
    again = [pool.alloc() for _ in range(pool.capacity)]
    assert len({pool.row(a) for a in again}) == pool.capacity
    for a in again:
        pool.discard(a)
    assert pool.free_pages == pool.capacity
