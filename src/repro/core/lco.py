"""Local Control Objects (LCOs): futures, dataflow, and friends.

The paper (Sec. II, "Local Control Objects") describes LCOs as the
synchronization abstraction that lets "every single function proceed as
far as possible": futures proxy not-yet-computed values, dataflow LCOs
fire a continuation once their precedent constraints are satisfied, and
both eliminate global barriers in favour of point-to-point dependence.

Two realizations live here:

* Host LCOs (`Future`, `Dataflow`, `FullEmptyBit`, `CountingSemaphore`)
  — real synchronization objects used by the host dataflow engine.  They
  are deliberately *cooperative*: `Dataflow.set_input` runs ready
  continuations inline on the caller (the analogue of an HPX-thread being
  scheduled on the OS-thread that satisfied the last dependency), so a
  single-threaded driver exhibits exactly the paper's event-driven
  semantics without preemption.

* Compiled LCOs — when a task graph is lowered onto a device mesh the
  LCO disappears into HLO data dependence (see core/scheduler.py).  That
  is this framework's answer to the paper's Sec. V "hardware acceleration
  of runtime functions": synchronization costs are paid at compile time,
  not at run time.
"""

from __future__ import annotations

import itertools
import threading
from typing import Any, Callable, Optional

from repro.obs import trace as _trace

_UNSET = object()


class LCOError(RuntimeError):
    pass


class Future:
    """A write-once value proxy (paper refs [15-17]).

    `set` may be called exactly once; `get` returns the value, running
    queued continuations first if needed.  Continuations registered via
    `then` run inline when the value arrives (cooperative scheduling).
    """

    _ids = itertools.count()

    __slots__ = ("gid", "_value", "_error", "_cbs", "_lock")

    def __init__(self, gid: Optional[int] = None):
        self.gid = gid if gid is not None else next(Future._ids)
        self._value = _UNSET
        self._error: Optional[BaseException] = None
        self._cbs: list[Callable[[Any], None]] = []
        self._lock = threading.Lock()

    # -- producer side ----------------------------------------------------
    def set(self, value: Any) -> None:
        with self._lock:
            if self._value is not _UNSET or self._error is not None:
                raise LCOError(f"future {self.gid} set twice")
            self._value = value
            cbs, self._cbs = self._cbs, []
        _trace.GLOBAL.instant("lco", "future_set", lco=self.gid,
                              waiters=len(cbs))
        for cb in cbs:  # run continuations inline, outside the lock
            cb(value)

    def set_error(self, err: BaseException) -> None:
        with self._lock:
            if self._value is not _UNSET or self._error is not None:
                raise LCOError(f"future {self.gid} set twice")
            self._error = err
            self._cbs = []
        _trace.GLOBAL.instant("lco", "future_error", lco=self.gid)

    # -- consumer side ----------------------------------------------------
    def done(self) -> bool:
        return self._value is not _UNSET or self._error is not None

    def get(self) -> Any:
        if self._error is not None:
            raise self._error
        if self._value is _UNSET:
            raise LCOError(
                f"future {self.gid} read before set: in the cooperative "
                "host engine a get() on an unset future means the task "
                "graph has a missing dependence edge"
            )
        return self._value

    def then(self, cb: Callable[[Any], None]) -> None:
        with self._lock:
            if self._value is _UNSET and self._error is None:
                self._cbs.append(cb)
                _trace.GLOBAL.instant("lco", "future_wait", lco=self.gid)
                return
            value = self._value
        if self._error is None:
            cb(value)


class Dataflow:
    """Dataflow LCO: fires a continuation when all N inputs are set.

    "The dataflow LCO construct acquires result values (or references)
    and is event driven updating its internal state accordingly until one
    or more precedent constraints are satisfied; then it initiates
    further program action" (paper, Sec. II).
    """

    __slots__ = ("n", "inputs", "_remaining", "_action", "_fired", "_lock")

    def __init__(self, n_inputs: int, action: Callable[[list], Any]):
        if n_inputs < 0:
            raise ValueError("n_inputs must be >= 0")
        self.n = n_inputs
        self.inputs: list = [_UNSET] * n_inputs
        self._remaining = n_inputs
        self._action = action
        self._fired = False
        self._lock = threading.Lock()
        if n_inputs == 0:
            self._fire()

    def set_input(self, slot: int, value: Any) -> None:
        fire = False
        with self._lock:
            if self.inputs[slot] is not _UNSET:
                raise LCOError(f"dataflow input {slot} set twice")
            self.inputs[slot] = value
            self._remaining -= 1
            fire = self._remaining == 0
        if fire:
            self._fire()

    def _fire(self) -> None:
        if self._fired:
            raise LCOError("dataflow fired twice")
        self._fired = True
        _trace.GLOBAL.instant("lco", "dataflow_fire", inputs=self.n)
        self._action(list(self.inputs))

    @property
    def fired(self) -> bool:
        return self._fired


class FullEmptyBit:
    """Classic full/empty synchronization word (single producer/consumer)."""

    __slots__ = ("_full", "_value", "_waiters")

    def __init__(self):
        self._full = False
        self._value = None
        self._waiters: list[Callable[[Any], None]] = []

    def write_ef(self, value: Any) -> None:
        """Write when empty, mark full, wake readers."""
        if self._full:
            raise LCOError("write_ef on a full cell")
        self._value, self._full = value, True
        waiters, self._waiters = self._waiters, []
        for w in waiters:
            w(value)

    def read_fe(self) -> Any:
        """Read when full, mark empty."""
        if not self._full:
            raise LCOError("read_fe on an empty cell")
        self._full = False
        v, self._value = self._value, None
        return v

    def read_ff(self, cb: Callable[[Any], None]) -> None:
        """Read-when-full leaving the cell full (continuation form)."""
        if self._full:
            cb(self._value)
        else:
            self._waiters.append(cb)


class CountingSemaphore:
    """Cooperative counting semaphore; continuations instead of blocking."""

    __slots__ = ("_count", "_waiters")

    def __init__(self, initial: int = 0):
        self._count = initial
        self._waiters: list[Callable[[], None]] = []

    def signal(self, n: int = 1) -> None:
        self._count += n
        while self._count > 0 and self._waiters:
            self._count -= 1
            self._waiters.pop(0)()

    def wait(self, cb: Callable[[], None]) -> None:
        if self._count > 0:
            self._count -= 1
            cb()
        else:
            self._waiters.append(cb)


class DependencyCounter:
    """The minimal LCO behind compiled scheduling: a countdown trigger.

    Used by the scheduler to convert a task DAG into firing order without
    materializing values; this is the exact object that gets "compiled
    away" on device.
    """

    __slots__ = ("remaining", "on_zero")

    def __init__(self, n: int, on_zero: Callable[[], None]):
        self.remaining = n
        self.on_zero = on_zero
        if n == 0:
            on_zero()

    def satisfy(self) -> None:
        self.remaining -= 1
        if self.remaining == 0:
            self.on_zero()
        elif self.remaining < 0:
            raise LCOError("dependency counter over-satisfied")
