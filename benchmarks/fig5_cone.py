"""Paper Fig 5: timestep-front snapshots ("upward facing cone").

Runs the barrier-free dataflow schedule and reports, at wall-clock
budgets of 25/50/75 % of the makespan, the timestep each base-grid
point has reached.  With the paper-faithful FIFO work queue the front
is an upward-opening cone whose tip sits at the finest region; with
our beyond-paper critical-path priority the cone inverts (the
scheduler races the critical fine region ahead) — both are printed.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro import amr
from repro.amr import taskgraph as tg
from repro.core import list_schedule


def run(n_points=256, n_coarse=6, grain=8, workers=8, verbose=True):
    prob = amr.WaveProblem(n_points=n_points, rmax=20.0,
                           amplitude=0.005)
    specs = amr.default_specs(prob, 3)
    wg = tg.build_window_graph(specs, n_coarse, grain)
    tg.assign_owners(wg, workers)
    out = {}
    for label, prio in (("fifo", lambda t: t.tid),
                        ("critpath", None)):
        r = list_schedule(wg.graph, workers, overhead=4e-6,
                          priority=prio)
        fronts = {}
        for frac in (0.25, 0.5, 0.75):
            f = tg.timestep_front(wg, r.finish, r.makespan * frac,
                                  prob.n_points)
            fronts[frac] = f
            if verbose:
                ds = f[:: max(n_points // 16, 1)]
                print(f"# fig5 {label} tau={frac:.2f} front="
                      + " ".join(f"{x:.2f}" for x in ds))
        fine = specs[-1]
        scale = 2 ** fine.level
        mid = fronts[0.5]
        fine_sl = slice(fine.lo // scale + 2, fine.hi // scale - 2)
        cone_depth = float(np.max(mid) - np.mean(mid[fine_sl]))
        out[label] = cone_depth
        emit(f"fig5_cone_depth_{label}", r.makespan * 1e6,
             f"depth_steps={cone_depth:.3f}")
    return out


if __name__ == "__main__":
    run()
