"""serving subpackage: paged KV cache + continuous-batching engines."""

from repro.serving.engine import (ChunkedPagedServingEngine, Completion,
                                  DenseServingEngine,
                                  PagedServingEngine, Request,
                                  ServingEngine, make_engine)
from repro.serving.kvcache import (PagedKVCache, PageExhausted,
                                   PagePool, page_keys)

__all__ = [
    "ChunkedPagedServingEngine", "Completion", "DenseServingEngine",
    "PagedServingEngine", "Request", "ServingEngine", "make_engine",
    "PagedKVCache", "PageExhausted", "PagePool", "page_keys",
]
