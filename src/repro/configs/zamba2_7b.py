"""zamba2-7b: hybrid Mamba-2 backbone + one shared attention block
applied periodically over concat(hidden, embedding).
[arXiv:2411.15242; unverified]

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    mamba_version=2,
    ssm_head_dim=64,
    shared_attn_every=6,      # 13 shared-block applications + 3 tail
    microbatch_per_device=2,
)
