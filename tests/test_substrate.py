"""Substrate: data pipeline, checkpointing, optimizer, FT, serving."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.checkpoint.checkpoint import Checkpointer
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.ft.failures import FailurePlan, InjectedFailure
from repro.ft.straggler import StragglerMonitor, rebalance
from repro.ft.supervisor import SupervisorConfig, run_supervised
from repro.models import transformer as T
from repro.optim.adamw import (AdamWConfig, apply_updates,
                               global_norm, init_opt_state, schedule)


# -- data ---------------------------------------------------------------

def test_data_deterministic():
    c = SyntheticCorpus(DataConfig(256, 32, 4, seed=1))
    b1 = c.batch_fast(5)
    b2 = c.batch_fast(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = c.batch_fast(6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_data_has_learnable_structure():
    cfg = DataConfig(64, 256, 8, seed=0, markov_strength=0.9)
    c = SyntheticCorpus(cfg)
    b = c.batch_fast(0)
    toks = np.asarray(b["tokens"])
    succ = np.asarray(c._succ)
    hits = (succ[toks[:, :-1]] == toks[:, 1:]).mean()
    assert hits > 0.5        # bigram structure >> chance (1/64)


# -- optimizer ------------------------------------------------------------

def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=100)
    params = {"w": jnp.ones((4, 4))}
    state = init_opt_state(params)
    for _ in range(60):
        grads = jax.tree.map(lambda p: 2 * p, params)   # d/dp ||p||^2
        params, state, m = apply_updates(params, grads, state, cfg)
    assert float(global_norm(params)) < 0.5


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)


def test_grad_clip():
    from repro.optim.adamw import clip_by_global_norm
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, n = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# -- checkpoint -----------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ck.save(3, tree, extra={"next_step": 3})
    got, extra = ck.restore(3, tree)
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree["a"]))
    assert got["b"]["c"].dtype == jnp.bfloat16
    assert extra["next_step"] == 3


def test_checkpoint_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_async_overlaps(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"x": jnp.zeros((512, 512))}
    ck.save_async(1, tree)
    ck.wait()
    assert ck.latest_step() == 1


def test_checkpoint_tree_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.zeros(2)})
    with pytest.raises(ValueError):
        ck.restore(1, {"b": jnp.zeros(2)})


# -- fault tolerance -------------------------------------------------------

def _toy_step(state, step):
    new = {"w": state["w"] * 0.9 + step * 0.01}
    return new, float(jnp.sum(new["w"]))


def test_supervisor_recovers_identically(tmp_path):
    cfg = SupervisorConfig(ckpt_every=5, total_steps=30)
    clean = run_supervised(
        cfg, Checkpointer(str(tmp_path / "clean")),
        lambda: {"w": jnp.ones(3)}, _toy_step)
    faulty = run_supervised(
        cfg, Checkpointer(str(tmp_path / "faulty")),
        lambda: {"w": jnp.ones(3)}, _toy_step,
        failure_plan=FailurePlan.at(7, 18, 18 + 100))
    assert faulty.restarts == 2
    assert faulty.steps_replayed > 0
    np.testing.assert_allclose(clean.losses, faulty.losses, rtol=1e-6)


def test_supervisor_gives_up_after_budget(tmp_path):
    cfg = SupervisorConfig(ckpt_every=100, total_steps=10,
                           max_restarts=1)
    plan = FailurePlan(frozenset(range(10)))   # always failing

    class AlwaysFail(FailurePlan):
        def check(self, step, done):
            raise InjectedFailure("boom")

    with pytest.raises(InjectedFailure):
        run_supervised(cfg, Checkpointer(str(tmp_path)),
                       lambda: {"w": jnp.ones(2)}, _toy_step,
                       failure_plan=AlwaysFail())


def test_straggler_monitor_detects():
    m = StragglerMonitor(4, threshold=1.5)
    rep = m.observe([1.0, 1.0, 1.0, 3.0])
    assert rep.stragglers == [3]
    assert rep.imbalance > 1.5


def test_straggler_rebalance_improves_load():
    from repro.core import AGAS, LocalityDomain
    ag = AGAS(LocalityDomain.simulated(4), pool_capacity=32)
    addrs = [ag.allocate(0) for _ in range(16)]   # all on locality 0
    costs = {a: 1.0 for a in addrs}
    plan, load = rebalance(ag, costs)
    assert len(plan.moves) == 12                  # 4 stay, 12 move
    assert np.asarray(ag.load()).max() == 4


def test_straggler_rebalance_respects_speed():
    from repro.core import AGAS, LocalityDomain
    ag = AGAS(LocalityDomain.simulated(2), pool_capacity=32)
    addrs = [ag.allocate(i % 2) for i in range(12)]
    costs = {a: 1.0 for a in addrs}
    plan, load = rebalance(ag, costs, speed=[1.0, 0.5])
    counts = np.asarray(ag.load())
    assert counts[0] > counts[1]     # slow locality gets less work


# -- serving -----------------------------------------------------------

def test_serving_engine_completes():
    from repro.serving.engine import Request, ServingEngine
    cfg = configs.get_reduced("yi-6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, slots=2, max_len=96,
                        prefill_buckets=(32,))
    rng = np.random.default_rng(0)
    for rid in range(3):
        eng.submit(Request(rid, rng.integers(
            0, cfg.vocab_size, size=16).astype(np.int32),
            max_new_tokens=4))
    eng.run_to_completion()
    assert len(eng.completions) == 3
    for c in eng.completions:
        assert len(c.tokens) == 4
