"""Localities: the ParalleX boundary between synchronous and asynchronous.

In the paper (Sec. II) a *locality* is "a contiguous physical domain,
managing intra-locality latencies, while guaranteeing compound atomic
operations on local state"; HPX equates a locality with a cluster node.

In this framework a locality is one mesh device (a TPU chip in the
production mesh, a host CPU worker in the simulator).  Intra-locality
operations are vectorized block-batched computations that XLA keeps in
VMEM; inter-locality operations are explicit collectives (parcels).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class Locality:
    """A single ParalleX locality.

    Attributes:
      lid:   dense locality id in [0, num_localities).
      coords: coordinates in the device mesh (e.g. (pod, data, model)),
              empty for host-simulated localities.
      kind:  "device" for mesh-backed, "sim" for the scheduler simulator.
    """

    lid: int
    coords: tuple = ()
    kind: str = "sim"

    def __index__(self) -> int:
        return self.lid


@dataclasses.dataclass(frozen=True)
class LocalityDomain:
    """An ordered set of localities cooperating on one computation.

    The domain is the unit over which AGAS distributes first-class
    objects and over which the scheduler balances tasks.
    """

    localities: tuple

    @staticmethod
    def simulated(n: int) -> "LocalityDomain":
        return LocalityDomain(tuple(Locality(i, (), "sim") for i in range(n)))

    @staticmethod
    def from_mesh_axis(mesh, axis: str | Sequence[str]) -> "LocalityDomain":
        """One locality per device along `axis` of a jax Mesh.

        Several mesh axes may be folded together (e.g. ("pod", "data")),
        producing their cartesian product in row-major order.
        """
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        sizes = [mesh.shape[a] for a in axes]
        n = 1
        for s in sizes:
            n *= s
        locs = []
        for i in range(n):
            rem, coords = i, []
            for s in reversed(sizes):
                coords.append(rem % s)
                rem //= s
            locs.append(Locality(i, tuple(reversed(coords)), "device"))
        return LocalityDomain(tuple(locs))

    def __len__(self) -> int:
        return len(self.localities)

    def __iter__(self):
        return iter(self.localities)

    def __getitem__(self, i: int) -> Locality:
        return self.localities[i]
