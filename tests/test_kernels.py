"""Pallas kernel sweeps vs. pure-jnp oracles (interpret mode)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.attention.ops import flash_attention
from repro.kernels.attention.ref import flash_attention_ref
from repro.kernels.scan.ops import selective_scan_op
from repro.kernels.scan.ref import selective_scan_ref
from repro.kernels.stencil.ref import stencil_rk3_ref
from repro.kernels.stencil.stencil import H, stencil_rk3

RNG = np.random.default_rng(42)


# -- stencil ------------------------------------------------------------

@pytest.mark.parametrize("grain", [8, 32, 128])
@pytest.mark.parametrize("nb", [1, 4])
def test_stencil_shapes(grain, nb):
    u = jnp.asarray(RNG.normal(size=(nb, 3, grain + 2 * H))
                    .astype(np.float32)) * 0.01
    r = jnp.asarray(np.stack(
        [(np.arange(-H, grain + H) + b * grain) * 0.05
         for b in range(nb)]).astype(np.float32))
    flags = jnp.zeros((nb, 2), jnp.int32)
    flags = flags.at[0, 0].set(1).at[-1, 1].set(1)
    got = stencil_rk3(u, r, flags, dr=0.05, dt=0.01, p=7)
    ref = stencil_rk3_ref(u, r, flags, dr=0.05, dt=0.01, p=7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-6)


@pytest.mark.parametrize("p", [1, 3, 7])
def test_stencil_nonlinearity_power(p):
    g = 32
    u = jnp.asarray(RNG.normal(size=(2, 3, g + 2 * H))
                    .astype(np.float32)) * 0.1
    r = jnp.asarray(np.stack(
        [(np.arange(-H, g + H) + b * g) * 0.1 for b in range(2)])
        .astype(np.float32))
    flags = jnp.zeros((2, 2), jnp.int32).at[0, 0].set(1)
    got = stencil_rk3(u, r, flags, dr=0.1, dt=0.02, p=p)
    ref = stencil_rk3_ref(u, r, flags, dr=0.1, dt=0.02, p=p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-6)


def test_stencil_matches_amr_engine_numerics():
    """The kernel must agree with the value the host engine computes."""
    from repro.amr.wave import WaveProblem, fused_rk3_block_np
    g = 64
    u = (RNG.normal(size=(3, g + 2 * H)) * 0.01).astype(np.float32)
    r = ((np.arange(-H, g + H)) * 0.05).astype(np.float32)
    host = fused_rk3_block_np(u.copy(), r, 0.05, 0.01, 7,
                              left_phys=True)
    flags = jnp.asarray([[1, 0]], jnp.int32)
    kern = stencil_rk3(jnp.asarray(u)[None], jnp.asarray(r)[None],
                       flags, dr=0.05, dt=0.01, p=7)[0]
    np.testing.assert_allclose(np.asarray(kern), host, atol=1e-6)


# -- flash attention ------------------------------------------------------

@pytest.mark.parametrize("s,d,kv,h", [(128, 32, 2, 4), (256, 64, 1, 2),
                                      (128, 16, 4, 4)])
def test_flash_gqa_shapes(s, d, kv, h):
    q = jnp.asarray(RNG.normal(size=(2, s, h, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(2, s, kv, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(2, s, kv, d)).astype(np.float32))
    got = flash_attention(q, k, v, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_sliding_window(window):
    s, d = 128, 32
    q = jnp.asarray(RNG.normal(size=(1, s, 2, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(1, s, 2, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(1, s, 2, d)).astype(np.float32))
    got = flash_attention(q, k, v, window=window, bq=32, bk=32)
    ref = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5)


def test_flash_noncausal():
    s, d = 64, 32
    q = jnp.asarray(RNG.normal(size=(1, s, 2, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(1, s, 2, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(1, s, 2, d)).astype(np.float32))
    got = flash_attention(q, k, v, causal=False, bq=32, bk=32)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5)


def test_flash_bf16():
    s, d = 128, 32
    q = jnp.asarray(RNG.normal(size=(1, s, 4, d))).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, s, 2, d))).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, s, 2, d))).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        atol=3e-2)


def test_flash_q_offset_decode_continuation():
    """q_offset shifts causality for continuation chunks."""
    s, d = 64, 16
    q = jnp.asarray(RNG.normal(size=(1, 32, 2, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(1, s, 2, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(1, s, 2, d)).astype(np.float32))
    got = flash_attention(q, k, v, q_offset=32, bq=32, bk=32)
    ref = flash_attention_ref(q, k, v, q_offset=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5)


# -- selective scan -------------------------------------------------------

@pytest.mark.parametrize("s,d,n,chunk,dblk",
                         [(64, 32, 8, 16, 16), (128, 64, 16, 32, 32),
                          (32, 16, 4, 32, 16)])
def test_scan_shapes(s, d, n, chunk, dblk):
    da = jnp.asarray(np.exp(
        -np.abs(RNG.normal(size=(2, s, d, n)))).astype(np.float32))
    dbx = jnp.asarray(
        RNG.normal(size=(2, s, d, n)).astype(np.float32)) * 0.1
    c = jnp.asarray(RNG.normal(size=(2, s, n)).astype(np.float32))
    got = selective_scan_op(da, dbx, c, chunk=chunk, d_block=dblk)
    ref = selective_scan_ref(da, dbx, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5)


def test_scan_long_memory():
    """Decay ~1 carries state across many chunks exactly."""
    s, d, n = 128, 8, 4
    da = jnp.ones((1, s, d, n), jnp.float32) * 0.999
    dbx = jnp.zeros((1, s, d, n), jnp.float32).at[:, 0].set(1.0)
    c = jnp.ones((1, s, n), jnp.float32)
    got = selective_scan_op(da, dbx, c, chunk=16, d_block=8)
    ref = selective_scan_ref(da, dbx, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5)
