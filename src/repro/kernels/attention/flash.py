"""Pallas TPU kernel: flash attention (causal + sliding window + GQA).

Tiling (TPU-idiomatic): grid = (BH, nq, nk) with the LAST axis the
sequential one; online-softmax statistics (m, l) and the output
accumulator persist in VMEM scratch across the nk steps of one (BH, nq)
tile and are flushed on the final step.

  q tile  : (1, bq, D) VMEM        k/v tile: (1, bk, D) VMEM
  scratch : acc (bq, D) f32, m (bq,) f32, l (bq,) f32

GQA is handled in the k/v index_map: query row bh = b*H + h reads kv
row b*KV + h // (H/KV) — no materialized head repetition, which is the
memory win over the jnp oracle (models/attention.flash_jnp).

Block pruning: fully-masked (q, k) tiles are skipped via @pl.when on
the block indices (causal upper triangle; outside the sliding window),
so compute scales with the touched area, matching the cost model's
S_eff accounting.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bq, bk, nk, causal, window, q_offset, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos0 = q_offset + qi * bq
    k_pos0 = ki * bk
    # Block-level pruning: skip tiles with no unmasked element.
    live = jnp.bool_(True)
    if causal:
        live &= q_pos0 + bq - 1 >= k_pos0
    if window > 0:
        live &= q_pos0 - (k_pos0 + bk - 1) < window

    @pl.when(live)
    def _body():
        q = q_ref[0]                          # (bq, D)
        k = k_ref[0]                          # (bk, D)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        q_pos = q_pos0 + jax.lax.broadcasted_iota(jnp.int32,
                                                  (bq, bk), 0)
        k_pos = k_pos0 + jax.lax.broadcasted_iota(jnp.int32,
                                                  (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v,
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q: jnp.ndarray, k: jnp.ndarray,
                         v: jnp.ndarray, *, causal: bool = True,
                         window: int = 0, q_offset: int = 0,
                         n_rep: int = 1, bq: int = 128, bk: int = 128,
                         interpret: bool = True) -> jnp.ndarray:
    """q: (BH, Sq, D); k/v: (BKV, Sk, D) with BH = BKV * n_rep."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, sk)
    nq = sq // bq
    nk = sk // bk
    kern = functools.partial(
        _kernel, bq=bq, bk=bk, nk=nk, causal=causal, window=window,
        q_offset=q_offset, scale=d ** -0.5)
    kv_map = lambda b, i, j: (b // n_rep, j, 0)
    return pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
