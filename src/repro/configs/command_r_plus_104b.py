"""command-r-plus-104b: dense 104B, GQA, no-bias.
[hf:CohereForAI/c4ai-command-r-v01; unverified]

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    rope_theta=7.5e4,
    tie_embeddings=True,      # command-r ties input/output embeddings
    microbatch_per_device=1,
    # §Perf F5/F6: per-layer remat stacks + an f32 accumulation buffer
    # overflow 16 GiB at 104B; group remat 8x and accumulate in bf16.
    remat_group_size=8,
    grad_accum_dtype="bfloat16",
)
