"""mixtral-8x7b: MoE 8 experts top-2, GQA, SWA.
[arXiv:2401.04088; hf]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, 8e top-2.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    top_k=2,
    sliding_window=4096,      # mixtral SWA (sub-quadratic path)
    rope_theta=1.0e6,
    microbatch_per_device=1,
)
