"""Distributed-runtime tests that need >1 device: run in a subprocess
with 8 forced host devices (the main pytest process keeps 1 device per
the dry-run contract)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, timeout=600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_compiled_amr_multidevice_matches_reference():
    out = run_sub("""
        from repro.distributed.compat import make_mesh, shard_map
        import jax, numpy as np
        from repro.amr import wave, compiled as cp
        mesh = make_mesh((4, 2), ('data', 'model'))
        prob = wave.WaveProblem(rmax=20.0, amplitude=0.005)
        cfg = cp.CompiledAMRConfig(grain=32, slots=4, n_steps=6)
        step, mk, init, to_g, shd, info = cp.make_uniform_step(
            prob, cfg, mesh, ('data','model'))
        pool = jax.device_put(init(), shd)
        u = to_g(jax.jit(step)(pool))
        ref = cp.reference_uniform(prob, info['n_points'], 6,
                                   info['dr'], info['dt'])
        np.testing.assert_allclose(np.asarray(u), np.asarray(ref),
                                   atol=1e-6)
        print('AMR_OK')
    """)
    assert "AMR_OK" in out


def test_hierarchical_psum_exact():
    out = run_sub("""
        from repro.distributed.compat import make_mesh, shard_map
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import hierarchical_psum
        mesh = make_mesh((2, 4), ('pod', 'data'))
        x = jnp.arange(8.0)
        fn = shard_map(
            lambda v: hierarchical_psum(v, 'pod', 'data'),
            mesh=mesh, in_specs=P(), out_specs=P(), check=False)
        got = fn(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x) * 8)
        print('HIER_OK')
    """)
    assert "HIER_OK" in out


def test_compressed_psum_error_feedback():
    out = run_sub("""
        from repro.distributed.compat import make_mesh, shard_map
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import (
            compressed_cross_pod_psum)
        mesh = make_mesh((8,), ('pod',))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        def one(x, err):
            return compressed_cross_pod_psum(x, err, 'pod')
        fn = shard_map(one, mesh=mesh, in_specs=(P(), P()),
                        out_specs=(P(), P()), check=False)
        err = jnp.zeros_like(g)
        # accumulated compressed sums converge to accumulated true sums
        acc_c, acc_t = jnp.zeros_like(g), jnp.zeros_like(g)
        for i in range(20):
            s, err = fn(g * (1.0 + 0.01 * i), err)
            acc_c = acc_c + s
            acc_t = acc_t + 8 * g * (1.0 + 0.01 * i)
        rel = float(jnp.linalg.norm(acc_c - acc_t) /
                    jnp.linalg.norm(acc_t))
        assert rel < 0.01, rel
        print('COMP_OK', rel)
    """)
    assert "COMP_OK" in out


def test_sharded_train_step_runs():
    out = run_sub("""
        from repro.distributed.compat import make_mesh, shard_map
        import jax, numpy as np
        import repro.configs as configs
        from repro.launch import steps as S
        from repro.launch.train import make_state
        from repro.models.config import ShapeConfig
        from repro.optim.adamw import AdamWConfig
        from repro.data.pipeline import DataConfig, SyntheticCorpus
        mesh = make_mesh((4, 2), ('data', 'model'))
        arch = configs.get_reduced('yi-6b')
        shape = ShapeConfig('t', 64, 8, 'train')
        opt_cfg = AdamWConfig(total_steps=50, warmup_steps=1, lr=5e-3)
        step, n_accum = S.make_train_step(arch, shape, mesh, opt_cfg)
        params, opt = make_state(arch, mesh, opt_cfg)
        corpus = SyntheticCorpus(DataConfig(arch.vocab_size, 64, 8))
        jstep = jax.jit(step, donate_argnums=(0, 1))
        losses = []
        with mesh:
            for i in range(10):
                params, opt, m = jstep(params, opt, corpus.batch_fast(i))
                losses.append(float(m['loss']))
        assert all(np.isfinite(l) for l in losses)
        assert np.mean(losses[-3:]) < np.mean(losses[:3])
        print('TRAIN_OK', losses[0], losses[-1])
    """)
    assert "TRAIN_OK" in out


def test_elastic_checkpoint_across_meshes(tmp_path):
    out = run_sub(f"""
        from repro.distributed.compat import make_mesh, shard_map
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.checkpoint import Checkpointer
        mesh_a = make_mesh((4, 2), ('data', 'model'))
        mesh_b = make_mesh((2, 4), ('data', 'model'))
        x = jnp.arange(64.0).reshape(8, 8)
        xa = jax.device_put(x, NamedSharding(mesh_a,
                                             P('data', 'model')))
        ck = Checkpointer({str(tmp_path)!r})
        ck.save(1, {{'w': xa}})
        shard_b = {{'w': NamedSharding(mesh_b, P('model', 'data'))}}
        got, _ = ck.restore(1, {{'w': x}}, shardings=shard_b)
        np.testing.assert_array_equal(np.asarray(got['w']),
                                      np.asarray(x))
        assert got['w'].sharding.spec == P('model', 'data')
        print('ELASTIC_OK')
    """)
    assert "ELASTIC_OK" in out


def test_param_shardings_consistent_on_production_mesh():
    """Rule table produces valid, divisible specs for every arch on a
    small stand-in production mesh."""
    out = run_sub("""
        from repro.distributed.compat import make_mesh, shard_map
        import jax
        import repro.configs as configs
        from repro.launch import steps as S
        mesh = make_mesh((2, 4), ('data', 'model'))
        for name in configs.ARCHS:
            arch = configs.get_reduced(name)
            pa = S.abstract_params(arch, mesh)   # raises if indivisible
            n = len(jax.tree.leaves(pa))
            assert n > 0
        print('SPECS_OK')
    """)
    assert "SPECS_OK" in out
