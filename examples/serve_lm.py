"""Batched serving demo: continuous batching over an AGAS page pool,
then the same traffic through disaggregated prefill/decode roles
(DESIGN.md §4f) — prefill chunks dispatched as parcels to the
locality owning their KV, finished prompts handed off to the decode
role via percolation snapshots.

  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

import repro.configs as configs
from repro.models import transformer as T
from repro.serving.engine import Request, ServingEngine, make_engine


def _traffic(cfg, rid0=0):
    rng = np.random.default_rng(0)
    return [Request(rid0 + i,
                    rng.integers(0, cfg.vocab_size,
                                 size=int(rng.integers(8, 60)))
                    .astype(np.int32), max_new_tokens=12)
            for i in range(10)]


def _serve(eng, reqs):
    t0 = time.perf_counter()
    futures = [eng.submit(r) for r in reqs]
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    tok = sum(len(c.tokens) for c in eng.completions)
    print(f"{len(eng.completions)} completions, {tok} tokens, "
          f"{dt:.2f}s ({tok / dt:.1f} tok/s incl. compile)")
    return futures


def main():
    cfg = configs.get_reduced("yi-6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    # a page pool half the dense footprint: 4 slots x 160 tokens dense
    # would be 40 pages of 16; 20 pages serve the same traffic because
    # pages are allocated on demand (preempting under pressure)
    eng = ServingEngine(params, cfg, slots=4, max_len=160,
                        prefill_buckets=(32, 64), page_size=16,
                        n_pages=20)
    futures = _serve(eng, _traffic(cfg))
    for fut in futures[:5]:
        c = fut.get()                  # completion arrives via the LCO
        print(f"  rid={c.rid:2d} prefill={c.prefill_s * 1e3:6.0f}ms "
              f"decode={c.decode_s * 1e3:6.0f}ms tokens={c.tokens[:6]}...")
    s = eng.stats()
    print(f"pages: peak occupancy {s['peak_page_occupancy']:.0%}, "
          f"{s['page_shares']} prefix-shared, "
          f"{s['preemptions']} preemptions")

    # the same traffic, disaggregated (§4f): a 2-shard pool, one
    # prefill worker per shard, parcels carrying each chunk to its
    # KV's locality and percolation handoffs into the decode role
    deng = make_engine(params, cfg, engine="chunked", disagg=True,
                       slots=4, max_len=160, prefill_buckets=(32, 64),
                       page_size=16, n_pages=20, kv_shards=2)
    print(f"\ndisagg: {deng.prefill_workers} prefill / "
          f"{deng.decode_workers} decode worker(s)")
    _serve(deng, _traffic(cfg, rid0=100))
    d = deng.stats()
    print(f"parcels: {d['prefill_parcels']} "
          f"(owner={d['prefill_parcels_owner']} "
          f"cold={d['prefill_parcels_cold']}), "
          f"handoffs: {d['handoffs']} ({d['handoff_bytes']}B, "
          f"overlap={d['handoff_overlap']:.2f})")


if __name__ == "__main__":
    main()
