"""Architecture + shape configuration system.

One `ArchConfig` per assigned architecture (src/repro/configs/<id>.py),
one `ShapeConfig` per assigned input shape.  Configs are frozen
dataclasses; `reduced()` derives the CPU smoke-test variant of the same
family (small widths/depths, same structural features).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | ssm | hybrid | moe | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # attention features
    rope_theta: float = 1.0e4
    rope_fraction: float = 1.0      # chatglm applies rotary to half dims
    sliding_window: int = 0         # 0 = full attention
    norm_eps: float = 1.0e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_group_size: int = 2048      # GShard dispatch group size (tokens)
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    mamba_version: int = 1
    ssm_head_dim: int = 64          # mamba2 head size
    # hybrid (zamba2): one shared attention block applied every k layers
    shared_attn_every: int = 0
    # VLM: every k-th layer is a cross-attention layer over patch embeds
    cross_attn_every: int = 0
    n_frontend_tokens: int = 0      # stub image/audio token count
    frontend: str = "none"          # none | vision_stub | encodec_stub
    dtype: str = "bfloat16"

    # training knobs (per-arch defaults; launcher may override)
    microbatch_per_device: int = 1
    remat: bool = True
    loss_chunk: int = 512           # chunked vocab projection (tokens)
    # remat granularity: checkpoint groups of k layers instead of every
    # layer — the saved-residual stack shrinks k-fold at the cost of
    # holding one group's recompute live (§Perf F5, command-r memory).
    remat_group_size: int = 1
    # gradient-accumulation buffer dtype (bf16 halves the buffer and
    # its traffic; set per arch where the f32 buffer breaks HBM)
    grad_accum_dtype: str = "float32"
    # force FSDP (params+grads+opt also sharded over "data") below the
    # default 20B auto-threshold (§Perf F9: falcon-mamba's 16-way-only
    # sharded f32 grad buffers)
    force_fsdp: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))
        if self.n_heads and self.n_kv_heads and \
                self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be divisible by n_kv_heads")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM/hybrid state or a sliding window."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        n = 0
        embed = self.vocab_size * d
        n += embed if self.tie_embeddings else 2 * embed
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d + 2 * d
        mlp = 3 * d * ff + d
        if self.family == "ssm":
            di, st = self.d_inner, self.ssm_state
            dt_rank = max(d // 16, 1)
            blk = (d * 2 * di + di * self.ssm_conv +
                   di * (dt_rank + 2 * st) + dt_rank * di +
                   2 * di + di * d + d)
            n += L * blk
        elif self.family == "hybrid":
            di = self.d_inner
            nh = di // self.ssm_head_dim
            blk = (d * 2 * di + di * self.ssm_conv + 3 * nh +
                   di * d + d)
            n += L * blk
            n_shared = 1
            shared = (2 * d) * h * hd + 2 * (2 * d) * kv * hd + \
                h * hd * d + 3 * mlp // 3 + 2 * d
            n += n_shared * shared
        elif self.family == "moe":
            n += L * (attn + d * self.n_experts +
                      self.n_experts * 3 * d * ff + d)
        elif self.family == "vlm":
            n_cross = L // self.cross_attn_every if self.cross_attn_every \
                else 0
            n += (L - n_cross) * (attn + mlp) + \
                n_cross * (attn + mlp + 2 * d)
        else:
            n += L * (attn + mlp)
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE top-k)."""
        if self.family != "moe":
            return self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d + 2 * d
        n = (self.vocab_size * d) * (1 if self.tie_embeddings else 2)
        n += L * (attn + d * self.n_experts +
                  self.top_k * 3 * d * ff + d) + d
        return n

    def reduced(self) -> "ArchConfig":
        """CPU smoke-test variant: same family/features, tiny sizes."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2))
            if self.n_kv_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16,
            sliding_window=32 if self.sliding_window else 0,
            n_experts=min(self.n_experts, 4),
            moe_group_size=32,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_head_dim=16,
            shared_attn_every=2 if self.shared_attn_every else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            n_frontend_tokens=8 if self.n_frontend_tokens else 0,
            dtype="float32",
            loss_chunk=64,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The four assigned LM shapes (system task statement).
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k requires a sub-quadratic attention path (task statement)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "skip: pure full-attention arch at 512k context"
    return True, ""
