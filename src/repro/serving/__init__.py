"""serving subpackage."""
