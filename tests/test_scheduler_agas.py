"""Scheduler + AGAS + parcels: unit and hypothesis property tests."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (AGAS, AGASError, LocalityDomain, TaskGraph,
                        balanced_placement, barrier_schedule,
                        contiguous_placement, list_schedule,
                        lower_halo_parcels, migration_plan,
                        pack_rounds)


def diamond():
    g = TaskGraph()
    a = g.add(1.0, key="a", phase=0)
    b = g.add(2.0, key="b", phase=1, deps=[a])
    c = g.add(1.0, key="c", phase=1, deps=[a], owner=1)
    g.add(1.0, key="d", phase=2, deps=[b, c])
    return g


def test_list_schedule_runs_all_tasks():
    g = diamond()
    r = list_schedule(g, 2, overhead=0.1)
    assert (r.worker >= 0).all()
    assert r.makespan == pytest.approx(4.3)


def test_barrier_never_faster_than_dataflow():
    g = diamond()
    df = list_schedule(g, 2, overhead=0.1)
    ba = barrier_schedule(g, 2, overhead=0.1, barrier_cost=0.05)
    assert ba.makespan >= df.makespan - 1e-12


def test_round_schedule_valid_and_complete():
    g = diamond()
    rs = pack_rounds(g, 2)
    rs.validate(g)
    assert len(rs.rounds) == 3


@st.composite
def random_dag(draw):
    n = draw(st.integers(3, 40))
    g = TaskGraph()
    for i in range(n):
        deps = []
        if i:
            k = draw(st.integers(0, min(3, i)))
            deps = sorted(draw(st.sets(st.integers(0, i - 1),
                                       min_size=k, max_size=k)))
        g.add(draw(st.floats(0.1, 5.0)), phase=i,
              owner=draw(st.integers(0, 7)), deps=deps)
    return g


@settings(max_examples=40, deadline=None)
@given(random_dag(), st.integers(1, 8), st.booleans())
def test_greedy_bound_holds(g, p, use_global):
    """Graham bound: max(T1/P, Tinf) <= T_P <= T1/P + Tinf."""
    policy = "global_queue" if use_global else "local_stealing"
    r = list_schedule(g, p, overhead=0.0, policy=policy)
    t1, tinf = g.work(), g.span()
    assert r.makespan >= max(t1 / p, tinf) - 1e-9
    assert r.makespan <= t1 / p + tinf + 1e-9


@settings(max_examples=30, deadline=None)
@given(random_dag(), st.integers(1, 6))
def test_rounds_makespan_at_least_span(g, p):
    rs = pack_rounds(g, p)
    assert rs.makespan(g) >= g.span() - 1e-9
    rs.validate(g)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.1, 10.0), min_size=1, max_size=50),
       st.integers(1, 8))
def test_lpt_placement_near_optimal(costs, p):
    """LPT is a 4/3-approximation: load <= 4/3 OPT + max."""
    place = balanced_placement(costs, p)
    loads = np.zeros(p)
    for c, w in zip(costs, place):
        loads[w] += c
    lower = max(sum(costs) / p, max(costs))
    assert loads.max() <= 4.0 / 3.0 * lower + 1e-9


def test_contiguous_placement_is_contiguous():
    pl = contiguous_placement(10, 3)
    assert pl == sorted(pl)
    assert set(pl) <= {0, 1, 2}


# -- AGAS -------------------------------------------------------------

def test_agas_alloc_lookup_free():
    ag = AGAS(LocalityDomain.simulated(4), pool_capacity=4)
    a = ag.allocate(2)
    assert ag.locality_of(a) == 2
    ag.free(a)
    with pytest.raises(AGASError):
        ag.lookup(a)


def test_agas_pool_exhaustion():
    ag = AGAS(LocalityDomain.simulated(2), pool_capacity=1)
    ag.allocate(0)
    with pytest.raises(AGASError):
        ag.allocate(0)


def test_agas_migration_keeps_name():
    ag = AGAS(LocalityDomain.simulated(4), pool_capacity=4)
    a = ag.allocate(0)
    gid = a.gid
    ag.migrate(a, 3)
    assert a.gid == gid and ag.locality_of(a) == 3
    assert ag.migrations == 1


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(2, 5), st.integers(1, 12))
def test_agas_checkpoint_restore_remap(n_old, n_new, n_obj):
    dom_old = LocalityDomain.simulated(n_old)
    ag = AGAS(dom_old, pool_capacity=max(n_obj, 4))
    addrs = [ag.allocate(i % n_old) for i in range(n_obj)]
    state = ag.checkpoint_state()
    dom_new = LocalityDomain.simulated(n_new)
    ag2 = AGAS.restore_state(state, dom_new)
    # every object still resolvable, on a valid locality
    for a in addrs:
        loc, slot = ag2.lookup(a)
        assert 0 <= loc < n_new


def test_migration_plan_payload_roundtrip():
    """Applying the lowered permutation restores AGAS consistency."""
    ag = AGAS(LocalityDomain.simulated(3), pool_capacity=4)
    addrs = [ag.allocate(i % 3) for i in range(6)]
    # payload arrays: data[loc][slot] = gid
    data = np.full((3, 4), -1)
    for a in addrs:
        loc, slot = ag.lookup(a)
        data[loc, slot] = a.gid
    plan = migration_plan(ag, {addrs[0]: 2, addrs[4]: 0})
    for gid, sl, ss, dl, ds in plan.moves:
        data[dl, ds] = data[sl, ss]
    for a in addrs:
        loc, slot = ag.lookup(a)
        assert data[loc, slot] == a.gid


def test_halo_lowering_legs_are_valid_permutes():
    ag = AGAS(LocalityDomain.simulated(4), pool_capacity=8)
    addrs = [ag.allocate(i % 4) for i in range(12)]
    edges = [(addrs[i], addrs[(i + 1) % 12]) for i in range(12)]
    low = lower_halo_parcels(edges, ag)
    total = 0
    for perm in low.perms:
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        assert len(set(srcs)) == len(srcs)     # ppermute contract
        assert len(set(dsts)) == len(dsts)
        total += len(perm)
    assert total == 12
