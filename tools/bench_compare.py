"""Diff a bench trajectory (BENCH_<n>.json) against the previous one.

serve_bench ``--bench-out`` writes a schema'd snapshot of
per-scenario bench metrics (latency percentiles, throughput, goodput,
skip/handoff rates) plus the floors the committed numbers were
calibrated against.  This tool:

* finds the previous trajectory — the highest ``BENCH_<m>.json`` with
  ``m`` below the current file's bench id, searched next to the
  current file (override with ``--dir``) — and prints a per-metric
  diff over the scenario intersection;
* checks the CURRENT file's values against its own embedded floors
  (dotted ``scenario.metric`` keys).

Exit status: 1 if any floor is violated, 0 otherwise.
``--report-only`` always exits 0 (CI smoke runs produce smaller
numbers than the committed full-run floors by construction — the
diff is the signal there, not the gate).

Usage::

    python tools/bench_compare.py BENCH_9.json
    python tools/bench_compare.py BENCH_9.json --report-only
"""

from __future__ import annotations

import argparse
import glob
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))

from benchmarks.common import read_bench  # noqa: E402

_BENCH_RE = re.compile(r"BENCH_(\d+)\.json$")


def find_previous(current_path: str, current_id: int,
                  search_dir: str | None = None) -> str | None:
    """Highest-id BENCH_<m>.json with m < current_id, or None."""
    d = search_dir or os.path.dirname(os.path.abspath(current_path))
    best_id, best = -1, None
    for p in glob.glob(os.path.join(d, "BENCH_*.json")):
        m = _BENCH_RE.search(os.path.basename(p))
        if not m:
            continue
        bid = int(m.group(1))
        if best_id < bid < current_id:
            best_id, best = bid, p
    return best


def _flat(scenarios: dict) -> dict:
    """scenario.metric -> value, numeric leaves only."""
    out = {}
    for sc, metrics in scenarios.items():
        for k, v in metrics.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                out[f"{sc}.{k}"] = float(v)
    return out


def diff(prev: dict, cur: dict) -> list[str]:
    """Human-readable per-metric delta lines over the intersection."""
    pf, cf = _flat(prev["scenarios"]), _flat(cur["scenarios"])
    lines = []
    for key in sorted(set(pf) & set(cf)):
        a, b = pf[key], cf[key]
        if a == b:
            lines.append(f"  {key:44s} {b:12.4g}  (unchanged)")
        else:
            rel = (b - a) / abs(a) * 100 if a else float("inf")
            lines.append(f"  {key:44s} {a:12.4g} -> {b:12.4g}"
                         f"  ({rel:+.1f}%)")
    only_prev = sorted(set(pf) - set(cf))
    only_cur = sorted(set(cf) - set(pf))
    for key in only_prev:
        lines.append(f"  {key:44s} {pf[key]:12.4g} -> (gone)")
    for key in only_cur:
        lines.append(f"  {key:44s} (new) {cf[key]:12.4g}")
    return lines


def check_floors(doc: dict) -> list[str]:
    """Violation messages for the doc's own embedded floors."""
    flat = _flat(doc["scenarios"])
    bad = []
    for key, floor in sorted(doc.get("floors", {}).items()):
        got = flat.get(key)
        if got is None:
            # the scenario was not exercised this run (flag subset):
            # absence is not a regression
            continue
        if got < float(floor):
            bad.append(f"{key} = {got:.4g} is below its floor "
                       f"{float(floor):.4g}")
    return bad


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="diff a BENCH_<n>.json bench trajectory against "
                    "the previous one and check its embedded floors")
    ap.add_argument("current", help="current BENCH_<n>.json")
    ap.add_argument("--dir", default=None,
                    help="directory to search for previous "
                         "BENCH_*.json (default: next to CURRENT)")
    ap.add_argument("--against", default=None, metavar="PATH",
                    help="diff against this trajectory instead of "
                         "auto-discovering the previous bench id "
                         "(CI: smoke run vs the committed "
                         "trajectory)")
    ap.add_argument("--report-only", action="store_true",
                    help="report floors/diff but always exit 0 "
                         "(CI smoke runs)")
    args = ap.parse_args(argv)

    cur = read_bench(args.current)
    prev_path = args.against or find_previous(
        args.current, cur["bench_id"], args.dir)
    if prev_path is None:
        print(f"bench_compare: no BENCH_*.json before id "
              f"{cur['bench_id']} — nothing to diff")
    else:
        prev = read_bench(prev_path)
        print(f"bench_compare: {os.path.basename(prev_path)} "
              f"(id {prev['bench_id']}) -> "
              f"{os.path.basename(args.current)} "
              f"(id {cur['bench_id']})")
        for line in diff(prev, cur):
            print(line)

    bad = check_floors(cur)
    for msg in bad:
        print(f"bench_compare: FLOOR VIOLATION: {msg}")
    if bad and not args.report_only:
        return 1
    if bad:
        print("bench_compare: --report-only: violations reported, "
              "not enforced")
    else:
        print(f"bench_compare: {len(cur.get('floors', {}))} floors "
              "ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
