"""Paper Fig 3: optimal task granularity vs refinement levels & cores.

The paper sweeps grain size for the (3-D, homogeneous) mesh-refinement
problem and finds (a) an interior optimum much finer than MPI
clustering sizes, (b) weak dependence on core count.  We reproduce both
findings on the paper's actual 1+1-D application under the measured
work-queue execution model (per-point cost and per-task overhead sigma
from Fig 9's range).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro import amr
from repro.amr import taskgraph as tg
from repro.core import list_schedule
from repro.core.granularity import auto_tune, sweep

GRAINS = [2, 4, 8, 16, 32, 64, 128]


def run(n_points=512, sigma=4e-6, verbose=True):
    prob = amr.WaveProblem(n_points=n_points, rmax=20.0,
                           amplitude=0.005)
    rows = []
    for levels in (1, 2, 3):
        specs = amr.default_specs(prob, levels)
        for workers in (4, 8, 16, 32):
            def build(g):
                wg = tg.build_window_graph(specs, 2, g)
                tg.assign_owners(wg, workers)
                return list_schedule(wg.graph, workers,
                                     overhead=sigma)
            pts = sweep(GRAINS, build)
            best = auto_tune(GRAINS, build)
            ms = {p.grain: p.makespan for p in pts}
            rows.append((levels, workers, best, ms[best]))
            if verbose:
                curve = " ".join(f"{g}:{ms[g] * 1e3:.2f}" for g in GRAINS)
                print(f"# fig3 levels={levels} P={workers} "
                      f"opt_grain={best}  (ms) {curve}")
    # paper claim: optimum weakly depends on core count
    by_level = {}
    for lv, p, best, t in rows:
        by_level.setdefault(lv, []).append(best)
    for lv, bests in by_level.items():
        emit(f"fig3_opt_grain_L{lv}", float(np.median(bests)),
             f"spread={min(bests)}-{max(bests)}")
    return rows


if __name__ == "__main__":
    run()
