"""Trip-count-aware HLO traversal for exact collective accounting.

`compiled.cost_analysis()` counts every while-loop body ONCE, so any
program built from lax.scan (all of ours: microbatch accumulation,
scan-over-layers, flash-attention chunks) under-reports totals by the
trip factors.  Collectives, however, are sparse and parseable: this
module walks the HLO text's computation call graph, extracts each while
loop's trip count from its condition computation (the `s32[]
constant(N)` bound), multiplies nested trips, and weights every
collective op by its enclosing computation's execution count.

This gives the EXACT per-shard collective bytes of one step — the
roofline's collective term.  The compute/memory terms come from the
analytic model (launch/cost_model.py); see EXPERIMENTS.md §Roofline
for the methodology note.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.launch.hlo_analysis import (_ALGO_FACTOR, _COLLECTIVE_KINDS,
                                       _shape_bytes)

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*?\))?\s*->"
                       r".*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\)[^\n]*?condition=%?([\w.\-]+)[^\n]*?body=%?([\w.\-]+)")
_WHILE_RE2 = re.compile(
    r"while\(.*?\)[^\n]*?body=%?([\w.\-]+)[^\n]*?condition=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
                    r"(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
                    r"([\w\-]+)\(")


@dataclasses.dataclass
class HloGraph:
    computations: Dict[str, List[str]]      # name -> op lines
    entry: str
    while_edges: Dict[str, List[Tuple[str, int]]]  # comp -> [(body, trip)]
    call_edges: Dict[str, List[str]]


def parse_hlo(txt: str) -> HloGraph:
    comps: Dict[str, List[str]] = {}
    entry = ""
    cur: Optional[str] = None
    for line in txt.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)

    def trip_of(cond: str) -> int:
        for ln in comps.get(cond, []):
            c = _CONST_RE.search(ln)
            if c:
                return int(c.group(1))
        return 1

    wes: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    ces: Dict[str, List[str]] = defaultdict(list)
    for name, lines in comps.items():
        for ln in lines:
            mw = _WHILE_RE.search(ln) or None
            if mw:
                cond, body = mw.group(1), mw.group(2)
                wes[name].append((body, trip_of(cond)))
                wes[name].append((cond, trip_of(cond)))
                continue
            mw2 = _WHILE_RE2.search(ln)
            if mw2:
                body, cond = mw2.group(1), mw2.group(2)
                wes[name].append((body, trip_of(cond)))
                wes[name].append((cond, trip_of(cond)))
                continue
            for mc in _CALL_RE.finditer(ln):
                ces[name].append(mc.group(1))
    return HloGraph(comps, entry, dict(wes), dict(ces))


def execution_counts(g: HloGraph) -> Dict[str, float]:
    """Times each computation executes per program run."""
    mult: Dict[str, float] = defaultdict(float)
    mult[g.entry] = 1.0
    # The computation reference graph is acyclic; process in topological
    # order via repeated relaxation (small graphs: fine).
    order = list(g.computations)
    for _ in range(len(order)):
        changed = False
        new = defaultdict(float)
        new[g.entry] = 1.0
        for name, m in list(mult.items()):
            for body, trip in g.while_edges.get(name, []):
                new[body] += m * trip
            for callee in g.call_edges.get(name, []):
                new[callee] += m
        for k, v in new.items():
            if abs(mult.get(k, 0.0) - v) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break
    return dict(mult)


@dataclasses.dataclass
class CollectiveTotals:
    counts: Dict[str, float]          # executions (trip-weighted)
    bytes_by_kind: Dict[str, float]   # per-shard operand bytes
    wire_bytes: float                 # algo-weighted
    static_counts: Dict[str, int]     # ops in text (structure)
    # XLA's CPU backend float-normalizes bf16 compute to f32, so
    # activation collectives in this artifact carry 2x the bytes a TPU
    # compilation would.  `wire_bytes_tpu` halves f32-dtyped collective
    # traffic (bf16-model assumption) — the roofline's corrected term.
    wire_bytes_tpu: float = 0.0

    def to_dict(self) -> dict:
        return {"counts": self.counts,
                "bytes_by_kind": self.bytes_by_kind,
                "wire_bytes": self.wire_bytes,
                "wire_bytes_tpu": self.wire_bytes_tpu,
                "static_counts": self.static_counts}


def top_collectives(txt: str, n: int = 12) -> List[dict]:
    """The n largest collectives by trip-weighted bytes, with source
    metadata (op_name=...) for attribution — the §Perf microscope."""
    g = parse_hlo(txt)
    mult = execution_counts(g)
    rows = []
    meta_re = re.compile(r'op_name="([^"]+)"')
    for name, lines in g.computations.items():
        m = mult.get(name, 0.0)
        for ln in lines:
            mo = _OP_RE.match(ln)
            if not mo:
                continue
            shape_str, opname = mo.group(1), mo.group(2)
            kind = None
            for k in _COLLECTIVE_KINDS:
                if opname == k or opname.startswith(k + "-"):
                    kind = k
                    break
            if kind is None or opname.endswith("-done"):
                continue
            b = _shape_bytes(shape_str)
            src = meta_re.search(ln)
            rows.append({
                "kind": kind, "shape": shape_str[:60],
                "bytes_each": b, "execs": m, "total_bytes": b * m,
                "source": (src.group(1)[-110:] if src else "?"),
            })
    rows.sort(key=lambda r: -r["total_bytes"])
    return rows[:n]


def collective_totals(txt: str) -> CollectiveTotals:
    g = parse_hlo(txt)
    mult = execution_counts(g)
    counts: Dict[str, float] = defaultdict(float)
    byts: Dict[str, float] = defaultdict(float)
    static: Dict[str, int] = defaultdict(int)
    wire = 0.0
    wire_tpu = 0.0
    for name, lines in g.computations.items():
        m = mult.get(name, 0.0)
        for ln in lines:
            mo = _OP_RE.match(ln)
            if not mo:
                continue
            shape_str, opname = mo.group(1), mo.group(2)
            kind = None
            for k in _COLLECTIVE_KINDS:
                if opname == k or opname.startswith(k + "-"):
                    kind = k
                    break
            if kind is None or opname.endswith("-done"):
                continue
            b = _shape_bytes(shape_str)
            static[kind] += 1
            counts[kind] += m
            byts[kind] += b * m
            wire += b * m * _ALGO_FACTOR[kind]
            # f32 traffic would be bf16 on TPU (see class docstring)
            b_tpu = b / 2.0 if "f32[" in shape_str else b
            wire_tpu += b_tpu * m * _ALGO_FACTOR[kind]
    return CollectiveTotals(dict(counts), dict(byts), wire,
                            dict(static), wire_tpu)
