"""Dataflow task graph for barrier-free AMR (the paper's Sec. III-IV).

Expands the canonical Berger-Oliger op stream (hierarchy.py) into a
per-block task DAG whose edges are exactly the domain-of-dependence
relations: "points in the computational domain are updated when those
points in their domain of dependence have been updated".

Task kinds
  ("step", level, block, s)   one fused RK3 step of one block
  ("taper", level, k)         prolongation refill of taper bands
  ("restrict", level, k)      fine->parent injection

Hazard edges are derived mechanically by a `FrameIndex` that records,
per (level, frame) array, every write range and read range: a reader
depends on all intersecting earlier writers (RAW = the dataflow LCO), a
writer depends on intersecting earlier writers (WAW) and readers (WAR).
The construction order is the lockstep program order, so the index is
always complete when queried, and the resulting graph executes
identically under ANY topological order — the property the paper's
barrier removal rests on, and one we test with randomized orders.

The same graph feeds:
  * value execution  (`run_window`) — real numbers, frame buffers;
  * `core.list_schedule` — the work-queue execution model (cone, Figs 5/6);
  * `core.barrier_schedule` — the MPI baseline (one barrier per op);
  * `core.pack_rounds` — the compiled wavefront (amr/compiled.py).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.amr import hierarchy as hi
from repro.amr.wave import (H, NFIELDS, WaveProblem, fused_rk3_block,
                            fused_rk3_block_np)
from repro.core.scheduler import TaskGraph


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Per-task cost accounting for the execution model (seconds).

    c_point — useful work per point update (measure with
    benchmarks/fig9_overhead.py or pass the paper's implied values);
    sigma is applied by the *scheduler*, not stored in task costs.
    """

    c_point: float = 1.0e-6
    c_copy: float = 1.0e-7


@dataclasses.dataclass
class TaskMeta:
    kind: str
    level: int
    index: int                      # substep s (step) or sync k
    block: int = -1
    out_range: Tuple[int, int] = (0, 0)   # array coords, this level
    in_range: Tuple[int, int] = (0, 0)
    left_phys: bool = False
    right_phys: bool = False


class FrameIndex:
    """Write/read range index per (level, frame) for hazard edges."""

    def __init__(self):
        self._writes: Dict[Tuple[int, int], List[Tuple[int, int, int]]] = \
            defaultdict(list)
        self._reads: Dict[Tuple[int, int], List[Tuple[int, int, int]]] = \
            defaultdict(list)

    @staticmethod
    def _hits(entries, lo, hi):
        return [t for (a, b, t) in entries if a < hi and lo < b]

    def read(self, level: int, frame: int, lo: int, hi: int,
             tid: int) -> List[int]:
        """Record a read; return RAW deps (intersecting writers)."""
        deps = self._hits(self._writes[(level, frame)], lo, hi)
        self._reads[(level, frame)].append((lo, hi, tid))
        return deps

    def write(self, level: int, frame: int, lo: int, hi: int,
              tid: int) -> List[int]:
        """Record a write; return WAW + WAR deps."""
        deps = self._hits(self._writes[(level, frame)], lo, hi)
        deps += self._hits(self._reads[(level, frame)], lo, hi)
        self._writes[(level, frame)].append((lo, hi, tid))
        return deps

    def written_ranges(self, level: int, frame: int):
        return [(a, b) for (a, b, _t) in self._writes[(level, frame)]]


@dataclasses.dataclass
class WindowGraph:
    graph: TaskGraph
    meta: List[TaskMeta]
    specs: List[hi.LevelSpec]
    n_coarse: int
    grain: int
    blocks: List[List[Tuple[int, int]]]   # per level: block out base ranges
    cost: CostModel


def _level_blocks(spec: hi.LevelSpec, grain: int) -> List[Tuple[int, int]]:
    """Partition the proper region into blocks of `grain` points."""
    lp, hp = spec.proper_extent
    out = []
    a = lp
    while a < hp:
        out.append((a, min(a + grain, hp)))
        a += grain
    return out


def build_window_graph(specs: Sequence[hi.LevelSpec], n_coarse: int,
                       grain: int, cost: CostModel = CostModel()
                       ) -> WindowGraph:
    specs = list(specs)
    n_levels = len(specs)
    ops = hi.enumerate_window_ops(n_levels, n_coarse)
    g = TaskGraph()
    meta: List[TaskMeta] = []
    fidx = FrameIndex()
    blocks = [_level_blocks(s, grain) for s in specs]

    def add(cost_s, key, phase, deps, m: TaskMeta) -> int:
        tid = g.add(cost_s, key=key, phase=phase, deps=sorted(set(deps)))
        meta.append(m)
        return tid

    # Track, per level, the taper-extension remaining at each substep:
    # right after a taper fill (sync) the extension is TAPER; each step
    # consumes H per interior side.
    ext_left = [0] * n_levels   # current valid extension beyond proper
    ext_right = [0] * n_levels

    for op in ops:
        spec = specs[op.level]
        lp, hp = spec.proper_extent
        if op.kind == "taper":
            parent = specs[op.level - 1]
            deps: List[int] = []
            tid_placeholder = len(g)
            for (c_a, c_b, p_lo, p_hi) in hi.taper_source_ranges(spec):
                pa = parent.l2a(p_lo)
                pb = parent.l2a(p_hi)
                deps += fidx.read(op.level - 1, op.index, pa, pb,
                                  tid_placeholder)
            # writes both taper bands into child frame 2*k
            child_frame = 2 * op.index
            w_deps: List[int] = []
            width = 0
            for (c_a, c_b, _pl, _ph) in hi.taper_source_ranges(spec):
                w_deps += fidx.write(op.level, child_frame, c_a, c_b,
                                     tid_placeholder)
                width += c_b - c_a
            tid = add(width * cost.c_copy,
                      ("taper", op.level, op.index), op.phase,
                      deps + w_deps,
                      TaskMeta("taper", op.level, op.index))
            assert tid == tid_placeholder
            ext_left[op.level] = 0 if spec.left_phys else hi.TAPER
            ext_right[op.level] = 0 if spec.right_phys else hi.TAPER

        elif op.kind == "step":
            s = op.index
            # Output extension into taper shrinks by H per step.
            new_el = max(ext_left[op.level] - H, 0) \
                if not spec.left_phys else 0
            new_er = max(ext_right[op.level] - H, 0) \
                if not spec.right_phys else 0
            lvl_blocks = blocks[op.level]
            nb = len(lvl_blocks)
            for b, (oa0, ob0) in enumerate(lvl_blocks):
                oa, ob = oa0, ob0
                left_phys = spec.left_phys and b == 0
                right_phys = spec.right_phys and b == nb - 1
                if b == 0 and not spec.left_phys:
                    oa = lp - new_el
                if b == nb - 1 and not spec.right_phys:
                    ob = hp + new_er
                ia = oa if left_phys else oa - H
                ib = ob if right_phys else ob + H
                tid_placeholder = len(g)
                deps = fidx.read(op.level, s, ia, ib, tid_placeholder)
                deps += fidx.write(op.level, s + 1, oa, ob,
                                   tid_placeholder)
                tid = add((ob - oa) * cost.c_point,
                          ("step", op.level, b, s), op.phase, deps,
                          TaskMeta("step", op.level, s, b, (oa, ob),
                                   (ia, ib), left_phys, right_phys))
                assert tid == tid_placeholder
            ext_left[op.level], ext_right[op.level] = new_el, new_er

        elif op.kind == "restrict":
            parent = specs[op.level - 1]
            lo, hi_ = hi.restriction_range(parent, spec)
            # read child frame 2*k over [2*lo, 2*(hi-1)+1]
            ca = spec.l2a(2 * lo)
            cb = spec.l2a(2 * (hi_ - 1)) + 1
            pa = parent.l2a(lo)
            pb = parent.l2a(hi_)
            child_frame = 2 * op.index
            tid_placeholder = len(g)
            deps = fidx.read(op.level, child_frame, ca, cb,
                             tid_placeholder)
            deps += fidx.write(op.level - 1, op.index, pa, pb,
                               tid_placeholder)
            add((hi_ - lo) * cost.c_copy,
                ("restrict", op.level, op.index), op.phase, deps,
                TaskMeta("restrict", op.level, op.index, -1,
                         (pa, pb), (ca, cb)))
        else:
            raise hi.HierarchyError(f"unknown op {op.kind}")

    return WindowGraph(g, meta, specs, n_coarse, grain, blocks, cost)


def assign_owners(wg: WindowGraph, n_workers: int,
                  scheme: str = "contiguous") -> None:
    """Static placement of blocks on localities.

    "contiguous" — each level's blocks split into contiguous chunks
    (the MPI decomposition); "balanced" — LPT on per-block cost;
    "round_robin" — cyclic.  taper/restrict tasks follow the nearest
    child edge block.
    """
    from repro.core.agas import balanced_placement, contiguous_placement

    place: Dict[Tuple[int, int], int] = {}
    for l, lvl_blocks in enumerate(wg.blocks):
        nb = len(lvl_blocks)
        if scheme == "contiguous":
            pl = contiguous_placement(nb, n_workers)
        elif scheme == "balanced":
            costs = [(b_hi - b_lo) for (b_lo, b_hi) in lvl_blocks]
            pl = balanced_placement(costs, n_workers)
        elif scheme == "round_robin":
            pl = [b % n_workers for b in range(nb)]
        else:
            raise ValueError(scheme)
        for b in range(nb):
            place[(l, b)] = pl[b]
    for tid, m in enumerate(wg.meta):
        if m.kind == "step":
            wg.graph.tasks[tid].owner = place[(m.level, m.block)]
        elif m.kind in ("taper", "restrict"):
            wg.graph.tasks[tid].owner = place[(m.level, 0)]


# ---------------------------------------------------------------------------
# Value execution over frame buffers
# ---------------------------------------------------------------------------

class FrameStore:
    """Dense per-(level, frame) buffers, NaN-poisoned until written.

    Reading a NaN cell means a missing dependence edge — it fails loudly
    instead of silently reading stale data.
    """

    def __init__(self, states: Sequence[hi.LevelState]):
        self.states = list(states)
        self._frames: Dict[Tuple[int, int], np.ndarray] = {}
        for l, st in enumerate(states):
            buf = np.full((NFIELDS, st.spec.width), np.nan,
                          dtype=np.asarray(st.arr).dtype)
            a, b = st.valid
            buf[:, a:b] = np.asarray(st.arr)[:, a:b]
            self._frames[(l, 0)] = buf

    def frame(self, level: int, f: int) -> np.ndarray:
        key = (level, f)
        if key not in self._frames:
            st = self.states[level]
            self._frames[key] = np.full(
                (NFIELDS, st.spec.width), np.nan,
                dtype=np.asarray(st.arr).dtype)
        return self._frames[key]

    def read(self, level: int, f: int, lo: int, hi_: int) -> np.ndarray:
        out = self.frame(level, f)[:, lo:hi_]
        if np.any(np.isnan(out)):
            raise hi.HierarchyError(
                f"read of unwritten cells: level {level} frame {f} "
                f"[{lo},{hi_}) — missing dependence edge")
        return out

    def write(self, level: int, f: int, lo: int, hi_: int,
              vals: np.ndarray) -> None:
        self.frame(level, f)[:, lo:hi_] = vals

    def last_frames(self, substeps: Sequence[int]) -> List[np.ndarray]:
        return [self.frame(l, s) for l, s in enumerate(substeps)]


def make_task_runner(wg: WindowGraph, store: FrameStore,
                     prob: WaveProblem):
    """Returns run(task) for core.execute_topologically."""
    specs = wg.specs

    def run(task) -> None:
        m = wg.meta[task.tid]
        spec = specs[m.level]
        if m.kind == "step":
            st = store.states[m.level]
            dt_l = prob.dt / (2 ** m.level)
            ia, ib = m.in_range
            oa, ob = m.out_range
            # The kernel always takes out_width + 2H cells; at physical
            # sides the extra H cells are the (derived) ghost slots.
            ea, eb = oa - H, ob + H
            frame = store.frame(m.level, m.index)
            ue = frame[:, ea:eb].copy()
            # Validate only the dependence window; zero the ghost slots
            # (the kernel refreshes them before any use).
            if np.any(np.isnan(ue[:, ia - ea:ib - ea])):
                raise hi.HierarchyError(f"step reads unwritten data: {m}")
            if m.left_phys:
                ue[:, :H] = 0.0
            if m.right_phys:
                ue[:, -H:] = 0.0
            out = fused_rk3_block_np(
                ue, np.asarray(st.r[ea:eb]), st.dr, dt_l, prob.p,
                left_phys=m.left_phys, right_phys=m.right_phys)
            store.write(m.level, m.index + 1, oa, ob, out)
        elif m.kind == "taper":
            pspec = specs[m.level - 1]
            pframe = store.frame(m.level - 1, m.index)
            for (c_a, c_b, p_lo, p_hi) in hi.taper_source_ranges(spec):
                store.read(m.level - 1, m.index, pspec.l2a(p_lo),
                           pspec.l2a(p_hi))        # NaN validation
                li = spec.a2l(np.arange(c_a, c_b))
                pa = pspec.l2a(li // 2)
                even = (li % 2 == 0)
                left = pframe[:, pa]
                right = pframe[:, np.minimum(pa + 1, pspec.width - 1)]
                vals = np.where(even[None, :], left,
                                left.dtype.type(0.5) * (left + right))
                store.write(m.level, 2 * m.index, c_a, c_b, vals)
        elif m.kind == "restrict":
            ca, cb = m.in_range
            pa, pb = m.out_range
            src = store.read(m.level, 2 * m.index, ca, cb)
            store.write(m.level - 1, m.index, pa, pb, src[:, ::2])
        else:
            raise hi.HierarchyError(f"unknown task kind {m.kind}")

    return run


def run_window(wg: WindowGraph, states: Sequence[hi.LevelState],
               prob: WaveProblem,
               order: Optional[Sequence[int]] = None
               ) -> List[hi.LevelState]:
    """Execute the window's tasks; returns final LevelStates.

    `order=None` uses the LCO-driven executor; otherwise the given
    topological order is used (randomized orders in property tests).
    """
    store = FrameStore(states)
    run = make_task_runner(wg, store, prob)
    if order is None:
        from repro.core.scheduler import execute_topologically
        execute_topologically(wg.graph, run)
    else:
        for tid in order:
            run(wg.graph.tasks[tid])
    out = []
    for l, st in enumerate(states):
        s_final = wg.n_coarse * (2 ** l)
        buf = store.frame(l, s_final)
        lp, hp = st.spec.proper_extent
        if np.any(np.isnan(buf[:, lp:hp])):
            raise hi.HierarchyError(f"final frame incomplete at level {l}")
        # Restriction wrote corrected coarse values into the final frame.
        arr = jnp.asarray(np.nan_to_num(buf))
        out.append(hi.LevelState(st.spec, arr, st.r,
                                 st.spec.proper_extent, st.dr))
    return out


# ---------------------------------------------------------------------------
# Cone extraction (paper Figs 5, 6)
# ---------------------------------------------------------------------------

def timestep_front(wg: WindowGraph, finish: np.ndarray, tau: float,
                   n_base: int) -> np.ndarray:
    """Timestep (coarse units, fractional) each base point reached by tau.

    For every base-grid point, uses the finest level covering it and the
    latest substep whose covering block task finished by wall-clock tau.
    Reproduces the paper's Fig 5/6 "upward facing cone".
    """
    front = np.zeros(n_base)
    best_level = np.full(n_base, -1)
    cover = np.zeros(n_base, dtype=bool)
    for l, spec in enumerate(wg.specs):
        scale = 2 ** l
        lo_b = -(-spec.lo // scale)
        hi_b = (spec.hi - 1) // scale       # last base point COVERED
        cover[:] = False
        cover[lo_b:min(hi_b + 1, n_base)] = True
        best_level[cover] = l
    # Dependence edges force substep monotonicity per block, so the max
    # finished substep per point is well-defined.
    for tid, m in enumerate(wg.meta):
        if m.kind != "step" or finish[tid] > tau:
            continue
        scale = 2 ** m.level
        spec = wg.specs[m.level]
        oa, ob = m.out_range
        b_lo = max(-(-spec.a2l(oa) // scale), 0)
        b_hi = min(spec.a2l(ob - 1) // scale, n_base - 1)
        t_reached = (m.index + 1) / scale
        sel = slice(b_lo, b_hi + 1)
        mask = best_level[sel] == m.level
        seg = front[sel]
        seg[mask] = np.maximum(seg[mask], t_reached)
        front[sel] = seg
    return front
