"""JAX version compatibility shims.

The repo targets the modern public API (``jax.make_mesh(...,
axis_types=...)``, ``jax.shard_map(..., check_vma=...)``) but must also
run on the 0.4.x line baked into the container, where mesh axis types
do not exist and shard_map lives in ``jax.experimental.shard_map`` with
the ``check_rep`` spelling.  Everything that builds meshes or wraps
shard_map goes through these two functions.
"""

from __future__ import annotations

from typing import Sequence

import jax


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    return jax.make_mesh(
        tuple(axis_shapes), tuple(axis_names),
        axis_types=(axis_type.Auto,) * len(tuple(axis_names)))


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` (new) or experimental shard_map (0.4.x)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check)
    from jax.experimental.shard_map import shard_map as exp_sm
    return exp_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check)
