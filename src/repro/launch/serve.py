"""Serving driver: reduced-config batched decode demo.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 8
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
      --engine paged --pages 24 --page-size 16   # oversubscribed pool
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
      --engine chunked --chunk-size 32 --step-tokens 64
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
      --kv-shards 4          # sharded AGAS page pool (DESIGN.md §4c)
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
      --pages 16 --tiering --host-pages 64   # two-tier percolation:
                             # preempted KV offloads to host DRAM and
                             # restores on re-admission (DESIGN.md §4d)
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
      --tiering --prefix-cache-compute   # prefix-cache compute skip
                             # (DESIGN.md §4e): covered prompts admit
                             # straight to decode off cached
                             # activation checkpoints
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
      --tiering --trace /tmp/serve.trace.json \
      --metrics-interval 8 --metrics-out /tmp/serve.metrics.jsonl
                             # causal trace (perfetto-viewable) +
                             # exporter-backed metrics snapshots:
                             # one {t, step, metrics, delta} JSON
                             # line per interval (DESIGN.md §10)
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
      --ttft-slo-ms 200 --itl-slo-ms 50 \
      --slo-report /tmp/serve.slo.json \
      --metrics-prom /tmp/serve.prom
                             # SLO/goodput tracking (DESIGN.md §10):
                             # deadline-tracked requests, per-request
                             # lifecycle flight recorder, end-of-run
                             # goodput report with per-phase blame,
                             # Prometheus text exposition
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
      --kv-shards 2 --disagg --prefill-workers 2 --decode-workers 1
                             # disaggregated prefill/decode
                             # (DESIGN.md §4f): prefill chunks
                             # parcel-dispatched to prefix-owner
                             # localities, finished KV handed to the
                             # decode role via percolation snapshots
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
      --kv-shards 2 --tiering --chaos-kill-shard 1 --chaos-at-step 4
                             # failure injection (DESIGN.md §4g):
                             # shard 1 dies at step 4; pages with a
                             # host-tier percolation copy rebuild on
                             # shard 0, the rest drain and re-prefill
                             # — every request still completes
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--engine",
                    choices=("auto", "chunked", "paged", "dense"),
                    default="auto")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pages", type=int, default=0,
                    help="page-pool size (0 = dense-equivalent)")
    ap.add_argument("--chunk-size", type=int, default=0,
                    help="prefill chunk width (0 = 2 pages)")
    ap.add_argument("--step-tokens", type=int, default=0,
                    help="per-step token budget (0 = slots + chunk)")
    ap.add_argument("--kv-shards", type=int, default=1,
                    help="AGAS localities the page pool is sharded "
                         "over (device-backed when the runtime has "
                         "one device per shard, simulated otherwise)")
    ap.add_argument("--tiering", action="store_true",
                    help="two-tier page pool (DESIGN.md §4d): cold "
                         "prefix pages spill to host DRAM and a "
                         "preempted request's KV is written back and "
                         "restored instead of re-prefilled")
    ap.add_argument("--host-pages", type=int, default=0,
                    help="host-tier capacity in pages "
                         "(0 = 4x the device pool)")
    ap.add_argument("--prefix-cache-compute", action="store_true",
                    help="prefix-cache compute skip (DESIGN.md §4e): "
                         "prompts covered by cached prefix pages skip "
                         "the covered prefill compute; fully-covered "
                         "prompts admit straight to decode from the "
                         "cached activation checkpoint")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated prefill/decode (DESIGN.md "
                         "§4f): prefill chunks dispatch as parcels to "
                         "the locality owning the prompt's prefix "
                         "pages (least-loaded when cold) and finished "
                         "KV hands to the decode role through staged "
                         "percolation snapshots; requires the chunked "
                         "engine")
    ap.add_argument("--prefill-workers", type=int, default=0,
                    help="prefill-worker localities for --disagg "
                         "(0 = one per KV shard)")
    ap.add_argument("--decode-workers", type=int, default=1,
                    help="decode-worker localities for --disagg")
    ap.add_argument("--pin-threshold", type=int, default=4,
                    help="radix-index hits before a prefix page is "
                         "pinned hot — pinned pages are the LAST "
                         "tiering-eviction candidates (0 disables "
                         "pinning)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="causal tracing (DESIGN.md §10): record "
                         "parcel/LCO/page/engine events and write a "
                         "Chrome trace-event JSON to PATH (open in "
                         "https://ui.perfetto.dev), plus a per-step "
                         "overhead attribution line")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    metavar="N",
                    help="metrics-registry snapshot every N engine "
                         "steps: a one-line console summary, plus a "
                         "JSONL record when --metrics-out is set "
                         "(0 = off; --metrics-out implies 8)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write interval snapshots of the unified "
                         "metrics registry as JSON lines — one "
                         "{t, step, metrics, delta} object per "
                         "interval, deltas against the previous "
                         "snapshot (obs/export.py)")
    ap.add_argument("--metrics-prom", default=None, metavar="PATH",
                    help="write the final metrics registry as "
                         "Prometheus text exposition (counters as "
                         "_total, histograms as summaries)")
    ap.add_argument("--slo-report", default=None, metavar="PATH",
                    help="end-of-run SLO/goodput report JSON: "
                         "met/missed per deadline-tracked request, "
                         "per-phase blame, lifecycle phase totals "
                         "(enables the flight recorder)")
    ap.add_argument("--ttft-slo-ms", type=float, default=0.0,
                    help="TTFT deadline attached to every request "
                         "(ms; 0 = untracked)")
    ap.add_argument("--itl-slo-ms", type=float, default=0.0,
                    help="inter-token p95 deadline attached to every "
                         "request (ms; 0 = untracked)")
    ap.add_argument("--chaos-kill-shard", type=int, default=-1,
                    metavar="SHARD",
                    help="failure injection (DESIGN.md §4g): kill KV "
                         "shard SHARD mid-run — pages with host-tier "
                         "copies rebuild on survivors, the rest drain "
                         "and re-prefill; every request still "
                         "finishes (-1 = off; requires --kv-shards>1)")
    ap.add_argument("--chaos-at-step", type=int, default=4,
                    metavar="N",
                    help="engine step at which --chaos-kill-shard "
                         "fires")
    ap.add_argument("--flight-recorder", action="store_true",
                    help="record per-request lifecycle timelines "
                         "(submit/bind/chunks/handoff/first-token/"
                         "preempt/finish) queryable via "
                         "engine.recorder (implied by --slo-report)")
    args = ap.parse_args()

    import repro.configs as configs
    from repro.distributed.sharding import kv_pool_mesh
    from repro.models import transformer as T
    from repro.serving.engine import Request, make_engine

    cfg = configs.get_reduced(args.arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    failure_plan = None
    if args.chaos_kill_shard >= 0:
        if args.kv_shards < 2:
            ap.error("--chaos-kill-shard requires --kv-shards > 1 "
                     "(a surviving shard must exist)")
        from repro.ft.failures import FailurePlan
        failure_plan = FailurePlan.kill_locality(
            args.chaos_kill_shard, at_step=args.chaos_at_step)
    kw = dict(slots=args.slots, max_len=args.max_len)
    engine = "chunked" if args.engine == "auto" else args.engine
    mesh = kv_pool_mesh(args.kv_shards)
    eng = make_engine(params, cfg, engine=engine,
                      page_size=args.page_size,
                      n_pages=args.pages or None,
                      chunk_size=args.chunk_size or None,
                      step_tokens=args.step_tokens or None,
                      kv_shards=args.kv_shards, mesh=mesh,
                      tiering=args.tiering,
                      host_pages=args.host_pages,
                      prefix_cache_compute=args.prefix_cache_compute,
                      pin_threshold=args.pin_threshold,
                      disagg=args.disagg,
                      prefill_workers=args.prefill_workers or None,
                      decode_workers=args.decode_workers,
                      flight_recorder=(args.flight_recorder
                                       or bool(args.slo_report)),
                      failure_plan=failure_plan,
                      **kw)
    if failure_plan is not None:
        print(f"[serve] chaos: shard {args.chaos_kill_shard} dies at "
              f"step {args.chaos_at_step} (§4g recovery on)")
    if args.disagg and hasattr(eng, "prefill_workers"):
        print(f"[serve] disaggregated roles: {eng.prefill_workers} "
              f"prefill worker(s) / {eng.decode_workers} decode "
              f"worker(s) over {eng.kvc.pool.n_shards} localit"
              f"{'ies' if eng.kvc.pool.n_shards > 1 else 'y'}")
    if args.tiering and hasattr(eng, "kvc"):
        pool = eng.kvc.pool
        print(f"[serve] two-tier pool: {pool.capacity} device pages "
              f"+ {pool.host_pages} host pages (percolation on)")
    if args.kv_shards > 1 and hasattr(eng, "kvc"):
        backing = "mesh" if mesh is not None else "simulated"
        print(f"[serve] kv page pool: {args.kv_shards} shards "
              f"({backing} localities), "
              f"{eng.kvc.pool.pages_per_shard} pages/shard")
    tracer = None
    if args.trace:
        from repro.obs.trace import Tracer, set_global
        tracer = Tracer(capacity=1 << 18)
        eng.set_tracer(tracer)
        set_global(tracer)

    # interval snapshots go through the exporter (obs/export.py) —
    # the full registry lands in the JSONL file; the console keeps a
    # one-line summary instead of the old hardcoded key list
    interval = args.metrics_interval
    if args.metrics_out and interval <= 0:
        interval = 8
    exporter = None
    if args.metrics_out:
        from repro.obs.export import JsonlExporter
        exporter = JsonlExporter(eng.metrics, args.metrics_out)
    on_step = None
    if interval > 0:
        def on_step(e, _every=interval):
            steps = e.metrics.counter("engine.steps").value
            if steps % _every:
                return
            if exporter is not None:
                rec = exporter.snap(step=steps)
                snap, delta = rec["metrics"], rec["delta"]
                sink = f" -> {args.metrics_out} " \
                       f"({len(snap)} series, {len(delta)} changed)"
            else:
                snap = e.metrics.snapshot()
                sink = ""
            print(f"[metrics] step={steps} "
                  f"resident={snap.get('engine.peak_resident', 0):g} "
                  f"decoded={snap.get('engine.decode_ms.count', 0):g} "
                  f"ttft_n={snap.get('engine.ttft_ms.count', 0):g}"
                  f"{sink}")

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    futs = []
    try:
        for rid in range(args.requests):
            n = int(rng.integers(8, 48))
            futs.append(eng.submit(Request(rid, rng.integers(
                0, cfg.vocab_size, size=n).astype(np.int32),
                max_new_tokens=args.max_new,
                ttft_deadline_ms=args.ttft_slo_ms or None,
                itl_deadline_ms=args.itl_slo_ms or None)))
        eng.run_to_completion(on_step=on_step)
    finally:
        if tracer is not None:
            from repro.obs.trace import set_global
            set_global(None)
    dt = time.perf_counter() - t0
    total_new = sum(len(c.tokens) for c in eng.completions)
    print(f"[serve] {type(eng).__name__}: "
          f"{len(eng.completions)} completions, "
          f"{total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s)")
    for f in futs[:4]:
        c = f.get()                       # the completion LCO
        print(f"  rid={c.rid} new={len(c.tokens)} "
              f"prefill={c.prefill_s * 1e3:.0f}ms "
              f"decode={c.decode_s * 1e3:.0f}ms "
              f"preempts={c.preemptions}")
    if hasattr(eng, "stats"):
        s = eng.stats()
        print(f"[serve] steps={s['steps']} "
              f"peak_active={s['peak_active']} "
              f"peak_page_occ={s['peak_page_occupancy']:.2f} "
              f"preemptions={s['preemptions']} "
              f"shares={s['page_shares']} cow={s['cow_copies']}")
        if s["kv_shards"] > 1:
            occ = ", ".join(f"{o:.2f}" for o in s["shard_occupancy"])
            print(f"[serve] shards={s['kv_shards']} "
                  f"occupancy=[{occ}] "
                  f"page_migrations={s['page_migrations']}")
        rec = s.get("recovery")
        if rec and rec.get("localities_killed"):
            print(f"[serve] recovery: "
                  f"killed={rec['localities_killed']} "
                  f"rebuilt={rec['pages_rebuilt']} "
                  f"lost={rec['pages_lost']} "
                  f"drained={rec['drained_slots']} "
                  f"re_prefills={rec['re_prefills']} "
                  f"(budget {rec['recovery_restarts']} restart(s))")
        if s.get("tiering"):
            print(f"[serve] tiering: resident={s['peak_resident']} "
                  f"offloads={s['offloads']} restores={s['restores']} "
                  f"offload_bytes={s['offload_bytes']} "
                  f"promote_bytes={s['promote_bytes']} "
                  f"overlap={s['copy_compute_overlap']:.2f}")
        if s.get("disagg"):
            print(f"[serve] disagg: parcels={s['prefill_parcels']} "
                  f"(owner={s['prefill_parcels_owner']} "
                  f"cold={s['prefill_parcels_cold']} "
                  f"affinity={s['prefill_parcel_affinity']:.0%}) "
                  f"handoffs={s['handoffs']} "
                  f"({s['handoff_bytes']}B, "
                  f"overlap={s['handoff_overlap']:.2f})")
        if s.get("prefix_cache_compute"):
            print(f"[serve] compute skip: "
                  f"full_skips={s['prefix_skips']} "
                  f"partial_hits={s['prefix_partial_hits']} "
                  f"prefill_tokens_skipped="
                  f"{s['prefill_tokens_skipped']}")
        if hasattr(eng, "kvc") and hasattr(eng.kvc.pool, "prefix"):
            p = eng.kvc.pool.prefix.metrics()
            print(f"[serve] radix index: nodes={p['prefix.nodes']} "
                  f"tombstones={p['prefix.tombstones']} "
                  f"walks={p['prefix.full_walks']}full/"
                  f"{p['prefix.partial_walks']}partial/"
                  f"{p['prefix.miss_walks']}miss "
                  f"pinned={p['prefix.pinned']} "
                  f"(pins={p['prefix.pins']} "
                  f"forced_unpins={p['prefix.forced_unpins']})")
        print(f"[serve] ttft_p50={s['ttft_p50_ms']:.0f}ms "
              f"ttft_p95={s['ttft_p95_ms']:.0f}ms "
              f"itl_p50={s['itl_p50_ms']:.1f}ms "
              f"itl_p95={s['itl_p95_ms']:.1f}ms")
        if s.get("slo"):
            slo = s["slo"]
            blame = " ".join(f"{k}={v}" for k, v in
                             slo["blame"].items() if v)
            print(f"[serve] slo: goodput={slo['goodput']:.0%} "
                  f"({slo['met']}/{slo['requests']} met, "
                  f"ttft_misses={slo['ttft_misses']} "
                  f"itl_misses={slo['itl_misses']})"
                  + (f" blame: {blame}" if blame else ""))
    if exporter is not None:
        exporter.snap(step=None)          # final state closes the file
        exporter.close()
        print(f"[metrics] {exporter.records} snapshots "
              f"-> {args.metrics_out}")
    if args.metrics_prom:
        from repro.obs.export import to_prometheus
        with open(args.metrics_prom, "w") as f:
            f.write(to_prometheus(eng.metrics))
        print(f"[metrics] Prometheus exposition -> {args.metrics_prom}")
    if args.slo_report:
        import json
        from repro.obs.slo import build_report
        rep = build_report(eng)
        with open(args.slo_report, "w") as f:
            json.dump(rep, f, indent=2)
        print(f"[slo] report ({rep['requests']} tracked, "
              f"goodput={rep['goodput']:.0%}) -> {args.slo_report}")
    if tracer is not None:
        from repro.obs.attribution import attribute, subsystems
        tracer.export_chrome(args.trace)
        recs = tracer.records()
        rep = attribute(recs)
        subs = ",".join(sorted(subsystems(recs)))
        print(f"[trace] {len(recs)} records ({subs}) -> {args.trace} "
              f"(open in https://ui.perfetto.dev)")
        if rep["steps"]:
            cats = " ".join(
                f"{k}={v:.1f}ms"
                for k, v in sorted(rep["categories_ms"].items())
                if v > 0)
            print(f"[trace] overhead: compute="
                  f"{rep['compute_fraction'] * 100:.0f}% "
                  f"runtime={rep['overhead_fraction'] * 100:.0f}% "
                  f"of {rep['wall_ms']:.1f}ms step wall ({cats})")


if __name__ == "__main__":
    main()
