"""Disaggregated prefill/decode serving (DESIGN.md §4f): greedy token
parity with the single-locality chunked engine, prefix-owner dispatch
affinity, prefill->decode handoff accounting, mid-prefill handoff
drills, and the parcel lowering's canonical batch sizes."""

from functools import lru_cache

import numpy as np
import pytest
import jax

import repro.configs as configs
from repro.core.parcels import (PrefillParcel, canonical_size,
                                lower_prefill_parcels)
from repro.models import transformer as T
from repro.serving.engine import (DisaggChunkedServingEngine, Request,
                                  make_engine)

RNG = np.random.default_rng(41)
PAGE = 16
CHUNK = 32
KW = dict(slots=3, max_len=96, prefill_buckets=(32,), page_size=PAGE,
          chunk_size=CHUNK, n_pages=24, kv_shards=2)


@lru_cache(maxsize=1)
def _setup():
    cfg = configs.get_reduced("yi-6b")
    return cfg, T.init_params(jax.random.PRNGKey(0), cfg)


@lru_cache(maxsize=1)
def _chunked():
    cfg, params = _setup()
    return make_engine(params, cfg, engine="chunked", **KW)


@lru_cache(maxsize=1)
def _disagg():
    cfg, params = _setup()
    return make_engine(params, cfg, engine="chunked", disagg=True,
                       **KW)


def _mixed_trace(cfg, rid0):
    """Shared-prefix warm requests + cold strays, mixed total lengths."""
    head = np.random.default_rng(5).integers(0, cfg.vocab_size,
                                             size=32)
    reqs = []
    for i, tail_len in enumerate((4, 8, 12, 16)):
        tail = np.random.default_rng(60 + i).integers(
            0, cfg.vocab_size, size=tail_len)
        reqs.append(Request(rid0 + i, np.concatenate(
            [head, tail]).astype(np.int32), max_new_tokens=6))
    cold = np.random.default_rng(99).integers(
        0, cfg.vocab_size, size=40).astype(np.int32)
    reqs.append(Request(rid0 + 9, cold, max_new_tokens=6))
    return reqs


def _serve(eng, reqs):
    futs = {r.rid: eng.submit(r) for r in reqs}
    eng.run_to_completion()
    return {rid: f.get().tokens for rid, f in futs.items()}


def test_factory_wiring_and_validation():
    cfg, params = _setup()
    assert isinstance(_disagg(), DisaggChunkedServingEngine)
    assert _disagg().prefill_workers == 2    # one per KV shard
    assert _disagg().decode_workers == 1
    with pytest.raises(ValueError, match="chunked"):
        make_engine(params, cfg, engine="paged", disagg=True, **{
            k: v for k, v in KW.items()
            if k not in ("chunk_size", "step_tokens")})


def test_greedy_parity_disagg_vs_chunked():
    """The acceptance bar: dispatching chunks as parcels and moving
    finished KV through handoffs must not change a single token."""
    cfg, _ = _setup()
    want = _serve(_chunked(), _mixed_trace(cfg, 100))
    got = _serve(_disagg(), _mixed_trace(cfg, 200))
    for (ra, a), (rb, b) in zip(sorted(want.items()),
                                sorted(got.items())):
        assert a == b, f"rid {rb} diverged from rid {ra}: {b} != {a}"
    assert _disagg().kvc.pool.used_pages == 0


def test_warm_wave_dispatches_to_prefix_owner():
    """A warm shared-prefix wave must send (nearly) every prefill
    parcel to the locality owning the prefix pages — move the work to
    the data.  Measured as a delta so earlier traces on the cached
    engine don't dilute the fraction."""
    cfg, _ = _setup()
    eng = _disagg()
    head = np.random.default_rng(17).integers(0, cfg.vocab_size,
                                              size=32)
    seed = Request(300, np.concatenate([
        head, np.random.default_rng(18).integers(
            0, cfg.vocab_size, size=8)]).astype(np.int32),
        max_new_tokens=24)
    # plant the prefix COLD and keep the seed decoding: an untiered
    # pool frees (and de-indexes) prefix pages at refcount zero, so a
    # drained seed would leave nothing for the wave to match
    sf = eng.submit(seed)
    while not eng.active or any(st["phase"] != "decode"
                                for st in eng.active.values()):
        eng.step()
    before = eng.stats()
    wave = []
    for i in range(6):
        tail = np.random.default_rng(70 + i).integers(
            0, cfg.vocab_size, size=4 + 4 * i)
        wave.append(Request(310 + i, np.concatenate(
            [head, tail]).astype(np.int32), max_new_tokens=2))
    _serve(eng, wave)
    assert len(sf.get().tokens) == 24    # the seed finished too
    after = eng.stats()
    total = after["prefill_parcels"] - before["prefill_parcels"]
    owner = after["prefill_parcels_owner"] \
        - before["prefill_parcels_owner"]
    assert total > 0
    assert owner / total >= 0.9, (owner, total)


def test_handoff_counters_and_overlap():
    cfg, _ = _setup()
    eng = _disagg()
    h0, b0 = eng.handoffs, eng.handoff_bytes
    _serve(eng, _mixed_trace(cfg, 400))
    # every completion that decoded went through exactly one handoff
    assert eng.handoffs - h0 == 5
    assert eng.handoff_bytes > b0
    s = eng.stats()
    assert 0.0 <= s["handoff_overlap"] <= 1.0
    assert s["handoffs"] == eng.handoffs
    # parcels either applied locally or crossed a locality — never lost
    assert s["parcels_sent"] + s["parcels_local"] \
        == s["prefill_parcels"]
    assert all(c == canonical_size(c) for c in s["dispatch_sizes"])


def test_mid_prefill_handoff_resumes_chunking():
    """force_handoff mid-prefill: the prompt detaches at a chunk
    boundary, restores, resumes — and still matches the uninterrupted
    engine token-for-token."""
    cfg, _ = _setup()
    prompt = np.random.default_rng(33).integers(
        0, cfg.vocab_size, size=64).astype(np.int32)
    want = _serve(_chunked(), [Request(500, prompt, max_new_tokens=5)])
    eng = _disagg()
    fut = eng.submit(Request(510, prompt, max_new_tokens=5))
    eng.step()                           # first chunk only (64 > 32)
    assert eng.force_handoff() == 1
    st = next(iter(eng.active.values()))
    assert st["phase"] == "handoff" and st["next_phase"] == "prefill"
    eng.run_to_completion()
    assert fut.get().tokens == want[500]
    assert eng.kvc.pool.used_pages == 0


def test_preempt_lands_staged_handoff_first():
    """A preemption hitting a handoff-phase slot must commit the
    snapshot before evicting — otherwise its refcounts leak and the
    pool never drains."""
    cfg, _ = _setup()
    eng = _disagg()
    prompt = np.random.default_rng(44).integers(
        0, cfg.vocab_size, size=64).astype(np.int32)
    fut = eng.submit(Request(600, prompt, max_new_tokens=3))
    eng.step()
    assert eng.force_handoff() == 1
    victim = max(eng.active, key=lambda s: eng.active[s]["seq"])
    eng._preempt(victim)                 # the fuzzer's direct call
    assert not eng.active
    eng.run_to_completion()              # re-admits and finishes
    assert len(fut.get().tokens) == 3
    assert eng.kvc.pool.used_pages == 0


def test_prefill_lowering_batches_canonically():
    """Per-destination batches at power-of-two canonical sizes — the
    same size-class rule the migration lowering compiles under."""
    parcels = [PrefillParcel(rid=i, slot=i % 3, start=0, take=32,
                             anchor=None, locality=i % 2)
               for i in range(5)]
    low = lower_prefill_parcels(parcels)
    assert low.n_parcels == 5
    assert [loc for loc, _ in low.batches] == [0, 1]
    assert [len(b) for _, b in low.batches] == [3, 2]
    assert low.sizes == (4, 2)           # canonical_size(3), (2)
