"""Percolation: pre-staged data movement between memory tiers.

The paper's answer to accelerator-equipped nodes (Sec. V) is
*percolation* — the runtime moves data and work to the fast-memory
locality AHEAD of need instead of blocking on demand misses, and AGAS
exists precisely so an object's global name survives that physical
move.  This module renders the mechanism (DESIGN.md §4d):

* **Tiers.**  Memory tiers are integer tags on AGAS localities
  (`Tier.DEVICE` = accelerator HBM, `Tier.HOST` = host DRAM).
  `tiered_domain` builds a LocalityDomain of N device localities (one
  per KV shard) plus one host locality whose pool is ~10x larger —
  demotion and promotion are ordinary `AGAS.migrate` calls, so a
  page's `GlobalAddress` is stable across the vertical move exactly as
  it is across a horizontal one (§4c).

* **Copy parcels.**  A `CopyParcel` is the percolation unit: a batch
  of same-sized payloads moving one direction between tiers.  Parcels
  are *staged* into a `PercolationQueue` — the queue is the runtime's
  visible record of copies in flight, and its counters (bytes moved
  each way, prefetch hits vs demand misses) are the Fig 9 practice of
  making the runtime's own data motion measurable.

* **The transfer engine.**  `TransferEngine` executes parcels as
  double-buffered asynchronous device<->host copies built on
  `jax.device_put`: staging a promotion issues the host->device copy
  immediately and returns without blocking, so the transfer overlaps
  whatever compiled step runs next; committing it is a donated
  scatter into the pool arrays.  Demotions issue
  ``copy_to_host_async`` before materializing, so a batch of
  offloaded pages streams out while the caller keeps scheduling.  At
  most `max_inflight` promotions are staged at once (double
  buffering): the prefetcher works one admission ahead of the
  scheduler, never unboundedly far.
"""

from __future__ import annotations

import dataclasses
import enum
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.localities import Locality, LocalityDomain
from repro.obs.trace import NULL_TRACER


class Tier(enum.IntEnum):
    """Memory tiers, fast to slow.  Values are the AGAS locality tier
    tags (`core/agas.py`), so ``agas.least_loaded(tier=Tier.DEVICE)``
    is the fast-tier allocation policy."""

    DEVICE = 0
    HOST = 1


def tiered_domain(n_device: int, n_host: int = 1) -> LocalityDomain:
    """Device localities 0..n_device-1 followed by host localities.

    The device localities are the KV shards of DESIGN.md §4c; the host
    localities are simulated (they live in process memory whatever the
    backend).  Pair with per-locality capacities and
    ``tiers=domain_tiers(...)`` when building the AGAS directory.
    """
    locs = [Locality(i, (), "sim") for i in range(n_device)]
    locs += [Locality(n_device + i, (), "host") for i in range(n_host)]
    return LocalityDomain(tuple(locs))


def domain_tiers(n_device: int, n_host: int = 1) -> List[int]:
    return [int(Tier.DEVICE)] * n_device + [int(Tier.HOST)] * n_host


@dataclasses.dataclass(frozen=True)
class CopyParcel:
    """One staged tier-crossing copy: a batch of page payloads moving
    DEMOTE (device -> host) or PROMOTE (host -> device).  `key` names
    the consumer (the request whose pages these are, or a prefix
    digest), so a later commit can find its staged payload."""

    key: Any
    gids: Tuple[int, ...]
    direction: str                    # "demote" | "promote"
    nbytes: int


class PercolationQueue:
    """FIFO of staged copy parcels + the tier-traffic counters.

    The queue holds parcels whose payloads are in flight; `pop(key)`
    removes the parcel when its copy is committed (or abandoned).
    Counters survive pops — they are cumulative for the life of the
    pool and feed the serving engine's `stats()`.
    """

    # rebindable tracer: the owning tiered pool points this at its own
    # tracer so committed copies land in the causal event stream
    trace = NULL_TRACER

    def __init__(self) -> None:
        self._q: "OrderedDict[Any, CopyParcel]" = OrderedDict()
        self.demote_parcels = 0
        self.promote_parcels = 0
        self.demote_pages = 0
        self.promote_pages = 0
        self.demote_bytes = 0
        self.promote_bytes = 0
        # promotion latency split: a prefetch hit was staged before the
        # consumer needed it (the copy ran under compute); a demand
        # promote blocked the consumer for the full copy
        self.prefetch_hits = 0
        self.demand_promotes = 0

    def __len__(self) -> int:
        return len(self._q)

    def __contains__(self, key: Any) -> bool:
        return key in self._q

    def push(self, parcel: CopyParcel) -> None:
        """Stage a parcel whose copy is in flight.  Staging does NOT
        count toward the traffic totals — a staged promotion may be
        abandoned (its consumer finished while queued); only `record`
        at commit time moves the counters, so the byte totals measure
        copies that actually landed."""
        self._q[parcel.key] = parcel

    def record(self, parcel: CopyParcel) -> None:
        """Count a completed copy (demotions at materialization,
        promotions at commit)."""
        if parcel.direction == "demote":
            self.demote_parcels += 1
            self.demote_pages += len(parcel.gids)
            self.demote_bytes += parcel.nbytes
        else:
            self.promote_parcels += 1
            self.promote_pages += len(parcel.gids)
            self.promote_bytes += parcel.nbytes
        self.trace.instant("percolation", f"{parcel.direction}_commit",
                           gids=list(parcel.gids), nbytes=parcel.nbytes)

    def pop(self, key: Any) -> Optional[CopyParcel]:
        return self._q.pop(key, None)

    def oldest_key(self) -> Optional[Any]:
        return next(iter(self._q), None)

    def record_promote_commit(self, prefetched: bool) -> None:
        if prefetched:
            self.prefetch_hits += 1
        else:
            self.demand_promotes += 1

    def overlap(self) -> float:
        """Fraction of promotions whose copy overlapped compute (was
        staged ahead of need) — the percolation win, measurably."""
        total = self.prefetch_hits + self.demand_promotes
        return self.prefetch_hits / total if total else 0.0

    # canonical `subsystem.metric` name -> legacy stats() key (the
    # serve_bench JSON / existing tests read the legacy names)
    LEGACY_KEYS = {
        "percolation.staged_parcels": "staged_parcels",
        "percolation.demote_parcels": "demote_parcels",
        "percolation.promote_parcels": "promote_parcels",
        "percolation.demote_pages": "demote_pages",
        "percolation.promote_pages": "promote_pages",
        "percolation.demote_bytes": "offload_bytes",
        "percolation.promote_bytes": "promote_bytes",
        "percolation.prefetch_hits": "prefetch_hits",
        "percolation.demand_promotes": "demand_promotes",
        "percolation.copy_compute_overlap": "copy_compute_overlap",
    }

    def metrics(self) -> Dict[str, Any]:
        """Counters under the unified ``subsystem.metric`` namespace."""
        return {
            "percolation.staged_parcels": len(self._q),
            "percolation.demote_parcels": self.demote_parcels,
            "percolation.promote_parcels": self.promote_parcels,
            "percolation.demote_pages": self.demote_pages,
            "percolation.promote_pages": self.promote_pages,
            "percolation.demote_bytes": self.demote_bytes,
            "percolation.promote_bytes": self.promote_bytes,
            "percolation.prefetch_hits": self.prefetch_hits,
            "percolation.demand_promotes": self.demand_promotes,
            "percolation.copy_compute_overlap": self.overlap(),
        }

    def stats(self) -> Dict[str, Any]:
        return {self.LEGACY_KEYS[k]: v for k, v in self.metrics().items()}


class TransferEngine:
    """Double-buffered async device<->host transfers for copy parcels.

    Promotions: `stage(key, gids, payload)` calls `jax.device_put` on
    the host payload and returns immediately — JAX's async dispatch
    runs the copy in the background, so the payload lands on device
    while the current compiled step computes.  `take(key)` hands the
    staged device arrays to the committer (a donated scatter into the
    pool).  At most `max_inflight` promotions are staged (double
    buffering); `stage` refuses further ones so the prefetcher cannot
    run away from the scheduler.

    Demotions: `to_host(arrays)` issues ``copy_to_host_async`` on
    every array before materializing any of them, so a multi-array
    offload streams out in one wave.
    """

    trace = NULL_TRACER  # rebound by the owning tiered pool

    def __init__(self, max_inflight: int = 2) -> None:
        self.max_inflight = int(max_inflight)
        self.queue = PercolationQueue()
        # key -> (gids, device arrays): gids recorded so a committer
        # can verify the staged payload still matches what it needs
        self._staged: "OrderedDict[Any, Tuple[tuple, Dict[str, Any]]]" \
            = OrderedDict()

    # -- promotion staging (host -> device, ahead of need) ------------
    def stage(self, key: Any, gids: Sequence[int],
              payload: Dict[str, np.ndarray]) -> bool:
        """Begin the host->device copy of `payload` now; False if the
        double buffer is full (or the key is already staged — staging
        is idempotent and returns True)."""
        import jax
        if key in self._staged:
            return True
        if len(self._staged) >= self.max_inflight:
            return False
        gids = tuple(int(g) for g in gids)
        with self.trace.span("percolation", "stage", kind="copy",
                             gids=list(gids)):
            self._staged[key] = (gids, {n: jax.device_put(a)
                                        for n, a in payload.items()})
        nbytes = sum(int(a.nbytes) for a in payload.values())
        self.queue.push(CopyParcel(key, gids, "promote", nbytes))
        return True

    def take(self, key: Any
             ) -> Optional[Tuple[tuple, Dict[str, Any]]]:
        """(gids, staged device arrays) for `key`, or None (demand
        miss).  Removes the parcel from the queue either way; the
        committer records hit/miss via
        `queue.record_promote_commit`."""
        self.queue.pop(key)
        return self._staged.pop(key, None)

    def drop(self, key: Any) -> None:
        """Abandon a staged promotion (its consumer left the queue)."""
        self.queue.pop(key)
        self._staged.pop(key, None)

    def staged_keys(self) -> List[Any]:
        return list(self._staged)

    # -- demotion (device -> host) ------------------------------------
    @staticmethod
    def to_host(arrays: Dict[str, Any]) -> Dict[str, np.ndarray]:
        """Materialize device arrays on host, issuing every transfer
        before blocking on any (one wave of DMA, not a chain)."""
        for a in arrays.values():
            start = getattr(a, "copy_to_host_async", None)
            if start is not None:
                start()
        return {n: np.asarray(a) for n, a in arrays.items()}
