"""AdamW with f32 moments, global-norm clipping, cosine schedule.

Moment tensors inherit the parameter sharding (ZeRO-style state
sharding falls out of GSPMD: specs are mapped through init_opt_state's
eval_shape in the launcher).  Params may be bf16; moments and the
update math are f32; the update is cast back to the param dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3.0e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1.0e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init_opt_state(params: Any) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(jnp.zeros((), jnp.int32),
                    jax.tree.map(f32, params),
                    jax.tree.map(f32, params))


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jnp.ndarray]:
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), n


def apply_updates(params: Any, grads: Any, state: OptState,
                  cfg: AdamWConfig) -> Tuple[Any, OptState, dict]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** step)
        vh = v / (1 - b2 ** step)
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:   # decoupled decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_mu, new_nu), metrics
