"""Metrics exporters: Prometheus text exposition + JSONL snapshots.

Two consumers of one ``MetricsRegistry``:

- ``to_prometheus`` renders the registry in the Prometheus text
  exposition format (version 0.0.4): counters as ``<name>_total``,
  gauges as-is, histograms as summaries (quantile-labelled samples
  plus ``_sum``/``_count``).  Dotted registry names are sanitized to
  the metric-name charset (dots → underscores) and prefixed, e.g.
  ``engine.ttft_ms`` → ``repro_engine_ttft_ms``.  ``parse_prometheus``
  inverts the rendering into a flat sample dict so tests (and the
  serve_bench round-trip assert) can verify the exposition against
  ``MetricsRegistry.snapshot()`` without a scrape stack;
  ``verify_roundtrip`` packages that comparison.

- ``JsonlExporter`` appends one JSON object per interval with the full
  ``snapshot()`` plus a ``delta`` against the previous interval, so a
  consumer can tail rates without keeping state.  The first record's
  delta is the full snapshot (everything is new); summing deltas over
  a file reconstructs the final snapshot exactly — the invariant
  ``read_jsonl`` consumers and tests lean on.

Both exporters are pull-style over ``snapshot()``: zero cost on the
serving hot path, wholly decoupled from how metrics are recorded.
"""

import json
import re
import time

from .metrics import Counter, Gauge, StreamingHistogram

__all__ = ["to_prometheus", "parse_prometheus", "verify_roundtrip",
           "prom_name", "JsonlExporter", "read_jsonl"]

_PROM_QUANTILES = ((50.0, "0.5"), (95.0, "0.95"), (99.0, "0.99"))
_BAD_CHARS = re.compile(r"[^a-zA-Z0-9_:]")
_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{([^}]*)\})?\s+(\S+)$")


def prom_name(name, prefix="repro_"):
    """Registry name -> Prometheus metric name."""
    return prefix + _BAD_CHARS.sub("_", name)


def _fmt(v):
    if isinstance(v, float):
        return repr(v)
    return str(v)


def to_prometheus(registry, prefix="repro_"):
    """Render the registry as Prometheus text exposition format."""
    lines = []
    for name in registry.names():
        m = registry.get(name)
        pname = prom_name(name, prefix)
        if isinstance(m, Counter):
            lines.append(f"# HELP {pname}_total {name}")
            lines.append(f"# TYPE {pname}_total counter")
            lines.append(f"{pname}_total {_fmt(m.value)}")
        elif isinstance(m, Gauge):
            lines.append(f"# HELP {pname} {name}")
            lines.append(f"# TYPE {pname} gauge")
            lines.append(f"{pname} {_fmt(m.value)}")
        elif isinstance(m, StreamingHistogram):
            snap = m.snapshot()
            lines.append(f"# HELP {pname} {name}")
            lines.append(f"# TYPE {pname} summary")
            for q, label in _PROM_QUANTILES:
                key = f"p{int(q)}"
                lines.append(
                    f'{pname}{{quantile="{label}"}} '
                    f"{_fmt(snap[key])}")
            lines.append(f"{pname}_sum {_fmt(m.sum)}")
            lines.append(f"{pname}_count {_fmt(m.count)}")
            lines.append(f"{pname}_min {_fmt(snap['min'])}")
            lines.append(f"{pname}_max {_fmt(snap['max'])}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text):
    """Exposition text -> {sample key: float}.

    Sample keys are the literal sample names, with the label set kept
    verbatim when present: ``repro_engine_ttft_ms{quantile="0.5"}``.
    """
    out = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"unparseable sample line: {line!r}")
        name, labels, value = m.groups()
        key = f"{name}{{{labels}}}" if labels else name
        out[key] = float(value)
    return out


def verify_roundtrip(registry, text=None, prefix="repro_"):
    """Check the exposition against ``registry.snapshot()``.

    Returns a list of problem strings (empty = faithful export).
    """
    if text is None:
        text = to_prometheus(registry, prefix)
    parsed = parse_prometheus(text)
    problems = []

    def expect(key, want):
        got = parsed.get(key)
        if got is None:
            problems.append(f"missing sample {key!r}")
        elif abs(got - float(want)) > 1e-9 * max(1.0, abs(want)):
            problems.append(f"{key}: exported {got!r} != {want!r}")

    snap = registry.snapshot()
    for name in registry.names():
        m = registry.get(name)
        pname = prom_name(name, prefix)
        if isinstance(m, Counter):
            expect(f"{pname}_total", snap[name])
        elif isinstance(m, Gauge):
            expect(pname, snap[name])
        elif isinstance(m, StreamingHistogram):
            for q, label in _PROM_QUANTILES:
                expect(f'{pname}{{quantile="{label}"}}',
                       snap[f"{name}.p{int(q)}"])
            expect(f"{pname}_count", snap[f"{name}.count"])
            expect(f"{pname}_min", snap[f"{name}.min"])
            expect(f"{pname}_max", snap[f"{name}.max"])
            expect(f"{pname}_sum", m.sum)
    return problems


class JsonlExporter:
    """Interval snapshots of a registry as JSON lines with deltas.

    Each ``snap()`` appends ``{"t", "step", "metrics", "delta"}``:
    ``metrics`` is the full ``registry.snapshot()``; ``delta`` holds
    every key whose value changed since the previous snap (first snap:
    everything).  Keys that disappear (registry reset between runs
    never removes names, so only via a fresh registry) are not
    tracked — the snapshot itself is always authoritative.
    """

    def __init__(self, registry, path, clock=None):
        self.registry = registry
        self.path = path
        self.clock = clock if clock is not None else time.time
        self._f = open(path, "w")
        self._prev = {}
        self.records = 0

    def snap(self, step=None):
        """Write one interval record; returns it as a dict."""
        metrics = self.registry.snapshot()
        delta = {k: v - self._prev[k] if k in self._prev else v
                 for k, v in metrics.items()
                 if k not in self._prev or v != self._prev[k]}
        rec = {"t": self.clock(), "step": step,
               "metrics": metrics, "delta": delta}
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        self._prev = metrics
        self.records += 1
        return rec

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def read_jsonl(path):
    """Read back a JSONL snapshot file as a list of records."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
