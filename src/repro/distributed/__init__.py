"""distributed subpackage."""
