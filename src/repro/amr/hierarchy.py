"""Berger-Oliger AMR hierarchy with tapered coarse-fine boundaries.

Paper, Sec. III: "The AMR algorithm is Berger-Oliger [30] but uses
tapering at coarse-fine interfaces [32]" (Lehner-Liebling-Reula 2006).

Tapering: at every coarse-time alignment the fine level's boundary
bands are filled by *space-only* interpolation from the parent over a
taper of T = 2 * H = 6 fine cells per interior side.  Each fine substep
consumes H = 3 cells of taper validity per side, so after the 2 fine
substeps of one parent step the valid region is exactly the fine region
proper — no interpolation in time is ever needed, which is what lets a
fine-block task's domain of dependence be expressed as plain dataflow
edges (and is why the paper pairs tapering with ParalleX).

Refinement ratio is 2 per level.  Level arrays carry either H physical
ghost cells (at r=0 / r=rmax) or T taper cells per side:

      [ phys-ghost H | proper n | taper T ]      etc.

`enumerate_window_ops` yields the canonical Berger-Oliger recursion as
a flat op list — the single source of truth consumed by BOTH the
barrier engine (executes ops lockstep) and the dataflow task-graph
builder (expands steps into per-block tasks).  Sharing it guarantees
the two engines perform identical arithmetic.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.amr.wave import (H, NFIELDS, WaveProblem, fused_rk3_block,
                            initial_data)

TAPER = 2 * H  # taper width per interior side (6 cells)


class HierarchyError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class LevelSpec:
    """Static geometry of one refinement level.

    lo/n are in level-units (dr_l = dr0 / 2**level); level-l index i is
    at radius r = i * dr_l.  Level 0 must cover the whole domain.
    """

    level: int
    lo: int
    n: int
    left_phys: bool
    right_phys: bool

    @property
    def hi(self) -> int:
        return self.lo + self.n

    @property
    def left_pad(self) -> int:
        return H if self.left_phys else TAPER

    @property
    def right_pad(self) -> int:
        return H if self.right_phys else TAPER

    @property
    def width(self) -> int:
        return self.left_pad + self.n + self.right_pad

    @property
    def arr_lo(self) -> int:
        """Level-index of array cell 0."""
        return self.lo - self.left_pad

    def a2l(self, a: int) -> int:
        return self.arr_lo + a

    def l2a(self, l: int) -> int:
        return l - self.arr_lo

    # Full valid extent right after a taper fill (array coords); physical
    # ghosts are derived data, never part of the extent.
    @property
    def full_extent(self) -> Tuple[int, int]:
        a = self.left_pad if self.left_phys else 0
        b = self.width - (self.right_pad if self.right_phys else 0)
        return (a, b)

    @property
    def proper_extent(self) -> Tuple[int, int]:
        return (self.left_pad, self.left_pad + self.n)


def validate_specs(specs: Sequence[LevelSpec], n_base: int) -> None:
    if specs[0].level != 0 or specs[0].lo != 0 or specs[0].n != n_base \
            or not (specs[0].left_phys and specs[0].right_phys):
        raise HierarchyError("level 0 must cover the whole domain")
    for parent, child in zip(specs, specs[1:]):
        if child.level != parent.level + 1:
            raise HierarchyError("levels must be consecutive")
        if child.lo % 2:
            raise HierarchyError("child lo must be even (ratio 2)")
        if not child.right_phys and child.hi % 2:
            raise HierarchyError("interior child hi must be even (ratio 2)")
        # Proper nesting: child's proper + taper must map inside the
        # parent's proper region with an H-cell margin so taper fills
        # never read the parent's own taper or ghosts.
        c_lo = child.lo - (0 if child.left_phys else TAPER)
        c_hi = child.hi + (0 if child.right_phys else TAPER)
        if child.left_phys and child.lo != 0:
            raise HierarchyError("left_phys child must start at 0")
        # Node-centred grids: parent point j sits at child point 2j, so a
        # child ending at the outer boundary has hi = 2*(parent.hi-1)+1.
        if child.right_phys and child.hi != 2 * parent.hi - 1:
            raise HierarchyError("right_phys child must end at domain edge")
        if not child.left_phys and c_lo // 2 - 1 < parent.lo + H:
            raise HierarchyError(
                f"level {child.level} breaks proper nesting on the left")
        if not child.right_phys and (c_hi + 1) // 2 + 1 > parent.hi - H:
            raise HierarchyError(
                f"level {child.level} breaks proper nesting on the right")


@dataclasses.dataclass
class LevelState:
    """Mutable per-level field data + the valid-extent cursor."""

    spec: LevelSpec
    arr: jnp.ndarray                  # (3, width)
    r: jnp.ndarray                    # (width,)
    valid: Tuple[int, int]            # current valid extent (array coords)
    dr: float

    def copy(self) -> "LevelState":
        return LevelState(self.spec, self.arr, self.r, self.valid, self.dr)


def make_hierarchy(prob: WaveProblem,
                   specs: Sequence[LevelSpec]) -> List[LevelState]:
    validate_specs(specs, prob.n_points)
    states = []
    for spec in specs:
        dr_l = prob.dr / (2 ** spec.level)
        arr = initial_data(prob, level_dr=dr_l, n=spec.width,
                           offset=spec.arr_lo)
        r = (spec.arr_lo + jnp.arange(spec.width,
                                      dtype=prob.jnp_dtype())) * dr_l
        states.append(LevelState(spec, arr, r, spec.full_extent, dr_l))
    return states


# ---------------------------------------------------------------------------
# Level operations (shared by both engines)
# ---------------------------------------------------------------------------

def step_extent_bounds(spec: LevelSpec, valid: Tuple[int, int]
                       ) -> Tuple[int, int]:
    """Output extent of one fused step given the current valid extent."""
    a, b = valid
    oa = a if spec.left_phys else a + H
    ob = b if spec.right_phys else b - H
    if ob - oa < 1:
        raise HierarchyError("valid extent exhausted (taper underflow)")
    return oa, ob


def step_level(state: LevelState, dt: float, p: int) -> None:
    """One fused RK3 step over the whole current valid extent."""
    spec = state.spec
    a, b = state.valid
    oa, ob = step_extent_bounds(spec, state.valid)
    ea, eb = oa - H, ob + H      # ext window; phys sides read ghost cells
    ue = state.arr[:, ea:eb]
    re = state.r[ea:eb]
    out = fused_rk3_block(ue, re, state.dr, dt, p,
                          left_phys=spec.left_phys and ea == 0,
                          right_phys=spec.right_phys and eb == spec.width)
    state.arr = state.arr.at[:, oa:ob].set(out)
    state.valid = (oa, ob)


def taper_source_ranges(child: LevelSpec) -> List[Tuple[int, int, int, int]]:
    """Per taper side: (child array lo, hi, parent level-lo, level-hi).

    Parent range is the inclusive-exclusive level-(l-1) index range read
    by linear interpolation onto child cells [lo, hi).
    """
    sides = []
    if not child.left_phys:
        c_a, c_b = 0, TAPER
        l_lo = child.a2l(c_a)
        l_hi = child.a2l(c_b - 1)
        sides.append((c_a, c_b, l_lo // 2, (l_hi + 1) // 2 + 1))
    if not child.right_phys:
        c_a, c_b = child.width - TAPER, child.width
        l_lo = child.a2l(c_a)
        l_hi = child.a2l(c_b - 1)
        sides.append((c_a, c_b, l_lo // 2, (l_hi + 1) // 2 + 1))
    return sides


def prolongate_band(parent: LevelState, child: LevelState,
                    c_a: int, c_b: int) -> jnp.ndarray:
    """Linear interpolation of parent data onto child cells [c_a, c_b)."""
    li = child.spec.a2l(np.arange(c_a, c_b))          # child level idx
    pa = parent.spec.l2a(li // 2)                     # parent array idx
    even = (li % 2 == 0)
    left = parent.arr[:, pa]
    right = parent.arr[:, np.minimum(pa + 1, parent.spec.width - 1)]
    vals = jnp.where(jnp.asarray(even)[None, :], left,
                     0.5 * (left + right))
    return vals


def fill_taper(parent: LevelState, child: LevelState) -> None:
    """Refill taper bands from the parent; resets valid to full extent."""
    for (c_a, c_b, _pl, _ph) in taper_source_ranges(child.spec):
        child.arr = child.arr.at[:, c_a:c_b].set(
            prolongate_band(parent, child, c_a, c_b))
    child.valid = child.spec.full_extent


def restriction_range(parent: LevelSpec, child: LevelSpec
                      ) -> Tuple[int, int]:
    """Parent level-index range [lo, hi) overwritten by injection.

    Child's last cell is child.hi - 1, so the last parent cell with a
    coincident child point is (child.hi - 1) // 2.
    """
    lo = -(-child.lo // 2)
    hi = (child.hi - 1) // 2 + 1
    return max(lo, parent.lo), min(hi, parent.hi)


def restrict(child: LevelState, parent: LevelState) -> None:
    """Injection: parent[j] <- child[2j] over the overlap."""
    lo, hi = restriction_range(parent.spec, child.spec)
    pj = parent.spec.l2a(np.arange(lo, hi))
    cj = child.spec.l2a(2 * np.arange(lo, hi))
    parent.arr = parent.arr.at[:, pj].set(child.arr[:, cj])


# ---------------------------------------------------------------------------
# The canonical op stream (Berger-Oliger recursion, flattened)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Op:
    """One op of the window program.

    kind:  "taper" (fill level `level` from its parent, sync index k)
           "step"  (advance level `level`, substep index s -> s+1)
           "restrict" (inject level `level` into its parent at parent
                       substep k)
    The `phase` field is the barrier-program phase (one barrier per op
    group in the MPI baseline).
    """

    kind: str
    level: int
    index: int      # k for taper/restrict, s (0-based pre-step) for step
    phase: int


def enumerate_window_ops(n_levels: int, n_coarse: int) -> List[Op]:
    """Flatten the BO recursion for a window of n_coarse coarse steps."""
    ops: List[Op] = []
    substep = [0] * n_levels   # completed substeps per level
    phase = 0

    def cycle(l: int) -> None:
        nonlocal phase
        if l + 1 < n_levels:
            ops.append(Op("taper", l + 1, substep[l], phase))
            phase += 1
        ops.append(Op("step", l, substep[l], phase))
        phase += 1
        substep[l] += 1
        if l + 1 < n_levels:
            cycle(l + 1)
            cycle(l + 1)
            ops.append(Op("restrict", l + 1, substep[l], phase))
            phase += 1

    for _ in range(n_coarse):
        cycle(0)
    return ops


def run_ops_lockstep(states: List[LevelState], ops: Sequence[Op],
                     prob: WaveProblem) -> List[LevelState]:
    """Execute the op stream in order on whole-level arrays.

    This IS the barrier (CSP/MPI-style) engine's numerics: one global
    barrier between consecutive ops.  Returns the mutated states.
    """
    for op in ops:
        if op.kind == "taper":
            fill_taper(states[op.level - 1], states[op.level])
        elif op.kind == "step":
            dt_l = prob.dt / (2 ** op.level)
            step_level(states[op.level], dt_l, prob.p)
        elif op.kind == "restrict":
            restrict(states[op.level], states[op.level - 1])
        else:
            raise HierarchyError(f"unknown op {op.kind}")
    return states


def default_specs(prob: WaveProblem, n_levels: int,
                  center_frac: float = 0.4,
                  width_frac: float = 0.3) -> List[LevelSpec]:
    """A pulse-centred static hierarchy (paper Fig 2 shape).

    Each finer level covers `width_frac` of its parent's proper region,
    centred on `center_frac` of the domain (the pulse at R0).
    """
    specs = [LevelSpec(0, 0, prob.n_points, True, True)]
    for l in range(1, n_levels):
        parent = specs[-1]
        center = int(2 * (parent.lo + center_frac * parent.n))
        half = int(parent.n * width_frac)
        half -= half % 2
        lo = max(center - half, 2 * parent.lo + 2 * (TAPER // 2 + H + 2))
        hi = min(center + half, 2 * parent.hi - 2 * (TAPER // 2 + H + 2))
        lo -= lo % 2
        hi -= hi % 2
        if hi - lo < 4 * TAPER:
            raise HierarchyError(f"level {l} region too small")
        specs.append(LevelSpec(l, lo, hi - lo, False, False))
    validate_specs(specs, prob.n_points)
    return specs
