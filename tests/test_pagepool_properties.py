"""Hypothesis property tests for the AGAS page allocator.

Random interleaved alloc / incref / decref / COW-fork /
prefix-register sequences must preserve the pool invariants:

* refcounts are never negative (a page with refcount 0 is freed and
  forgotten, never seen at -1);
* ``free_pages + used_pages == n_pages`` at every step;
* a prefix-shared page is never written in place — a divergent append
  COW-forks onto a fresh page and the original's content survives;
* released physical rows are reusable by later allocs.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

import repro.configs as configs
from repro.serving.kvcache import PageExhausted, PagePool

N_PAGES = 5
PAGE_SIZE = 4

# op codes: (kind, param) — param picks a held page / prefix key
OPS = st.lists(
    st.tuples(st.sampled_from(["alloc", "incref", "decref", "cow",
                               "register", "share"]),
              st.integers(0, 7)),
    min_size=1, max_size=60)


def _stamp(pool, row, value):
    """Write a recognisable constant into one physical page row."""
    shape = pool.pages["k"].shape              # (L, N, ps, KV, D)
    span = jnp.full((shape[0], 1) + shape[2:], float(value),
                    pool.pages["k"].dtype)
    pool.write_pages([row], span, span)


def _content(pool, row):
    return float(np.asarray(pool.pages["k"][0, row, 0, 0, 0]))


@settings(max_examples=25, deadline=None)
@given(ops=OPS)
def test_pool_invariants_under_random_interleaving(ops):
    cfg = configs.get_reduced("yi-6b")
    pool = PagePool(cfg, n_pages=N_PAGES, page_size=PAGE_SIZE)
    held = []                   # (addr, stamp) pairs we hold a ref on
    refs = {}                   # gid -> refcount we believe it has
    stamps = {}                 # gid -> content stamped at alloc
    next_stamp = 1
    next_key = 0

    def check_invariants():
        assert pool.free_pages + pool.used_pages == pool.capacity
        assert 0 <= pool.free_pages <= pool.capacity
        for addr, _ in held:
            assert pool.refcount(addr) >= 1
            assert pool.refcount(addr) == refs[addr.gid]
            assert 0 <= pool.row(addr) < pool.capacity

    for kind, param in ops:
        if kind == "alloc":
            try:
                addr = pool.alloc()
            except PageExhausted:
                assert pool.free_pages == 0
                continue
            _stamp(pool, pool.row(addr), next_stamp)
            stamps[addr.gid] = next_stamp
            next_stamp += 1
            held.append((addr, stamps[addr.gid]))
            refs[addr.gid] = 1
        elif kind == "incref" and held:
            addr, s = held[param % len(held)]
            pool.incref(addr)
            refs[addr.gid] += 1
            held.append((addr, s))
        elif kind == "decref" and held:
            addr, _ = held.pop(param % len(held))
            pool.decref(addr)
            refs[addr.gid] -= 1
            if refs[addr.gid] == 0:
                del refs[addr.gid]
                stamps.pop(addr.gid, None)
        elif kind == "cow" and held:
            # divergent append into a shared page: fork, never write
            # in place
            addr, s = held[param % len(held)]
            if pool.refcount(addr) > 1:
                try:
                    fresh = pool.alloc()
                except PageExhausted:
                    assert pool.free_pages == 0
                    continue
                pool.copy_page(pool.row(addr), pool.row(fresh))
                # the clone carries the stamp; the original survives
                assert _content(pool, pool.row(fresh)) == s
                assert _content(pool, pool.row(addr)) == s
                idx = next(i for i, (a, _) in enumerate(held)
                           if a.gid == addr.gid)
                held[idx] = (fresh, s)
                stamps[fresh.gid] = s
                refs[fresh.gid] = 1
                pool.decref(addr)
                refs[addr.gid] -= 1
        elif kind == "register" and held:
            addr, _ = held[param % len(held)]
            pool.register_prefix((b"k%d" % next_key, PAGE_SIZE), addr)
            next_key += 1
        elif kind == "share" and next_key:
            key = (b"k%d" % (param % next_key), PAGE_SIZE)
            addr = pool.lookup_prefix(key)
            if addr is not None:
                # a prefix hit reuses the page by refcount: its stamp
                # is exactly what the registering owner wrote (the
                # page was never rewritten)
                assert _content(pool, pool.row(addr)) \
                    == stamps[addr.gid]
                pool.incref(addr)
                refs[addr.gid] += 1
                held.append((addr, stamps[addr.gid]))
        check_invariants()

    # every page we still hold has its original content (prefix-shared
    # pages were never written in place)
    for addr, s in held:
        assert _content(pool, pool.row(addr)) == s

    # released addresses are reusable: drain and refill the pool
    for addr, _ in held:
        pool.decref(addr)
    assert pool.used_pages == 0 and pool.free_pages == pool.capacity
    again = [pool.alloc() for _ in range(pool.capacity)]
    assert len({pool.row(a) for a in again}) == pool.capacity
    with pytest.raises(PageExhausted):
        pool.alloc()
    for a in again:
        pool.decref(a)
    assert pool.free_pages == pool.capacity
