"""Step builders: train_step / prefill_step / decode_step + input specs.

These are what the dry-run lowers and the drivers execute.  Everything
here is mesh-agnostic: shardings come from distributed/sharding.py and
are attached via jit in_shardings (params/opt/cache) and the batch
specs returned by `input_specs`.

train_step uses gradient accumulation over microbatches via lax.scan
(n_accum = global_batch / (microbatch_per_device * |dp|)) so the
activation working set is one microbatch regardless of global batch —
the knob that keeps command-r-plus-104b train_4k inside 16 GB/chip.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import transformer as T
from repro.models.config import ArchConfig, ShapeConfig
from repro.optim.adamw import (AdamWConfig, OptState, apply_updates,
                               init_opt_state)


def model_tp(arch: ArchConfig, mesh: Mesh) -> int:
    """Virtual-expert split factor for MoE archs on this mesh."""
    if arch.family != "moe":
        return 1
    m = mesh.shape["model"]
    return max(m // arch.n_experts, 1)


def frontend_dim(arch: ArchConfig) -> int:
    from repro.models.transformer import _frontend_dim
    return _frontend_dim(arch)


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct; no allocation) — the dry-run diet
# ---------------------------------------------------------------------------

def input_specs(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Batch stand-ins for one (arch x shape) cell."""
    bs = shd.batch_shardings(arch, shape, mesh)
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "decode":
        out["tokens"] = sds((b, 1), jnp.int32, sharding=bs["tokens"])
    else:
        out["tokens"] = sds((b, s), jnp.int32, sharding=bs["tokens"])
    if shape.kind == "train":
        out["labels"] = sds((b, s), jnp.int32, sharding=bs["labels"])
    if arch.family == "vlm":
        out["patch_embeds"] = sds(
            (b, arch.n_frontend_tokens, frontend_dim(arch)),
            jnp.dtype(arch.dtype), sharding=bs["patch_embeds"])
    if arch.family == "audio" and shape.kind != "decode":
        out["frame_embeds"] = sds((b, s, arch.d_model),
                                  jnp.dtype(arch.dtype),
                                  sharding=bs["frame_embeds"])
    return out


def abstract_params(arch: ArchConfig, mesh: Mesh) -> Any:
    tp = model_tp(arch, mesh)
    shapes = jax.eval_shape(
        lambda k: T.init_params(k, arch, tp), jax.random.PRNGKey(0))
    fsdp = True if arch.force_fsdp else None
    shards = shd.param_shardings(shapes, arch, mesh, fsdp=fsdp)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=sh),
        shapes, shards)


def abstract_opt_state(arch: ArchConfig, mesh: Mesh, params_abs) -> Any:
    shapes = jax.eval_shape(init_opt_state, params_abs)
    def shard_like(s, path_sh):
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=path_sh)
    mu = jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=p.sharding),
        shapes.mu, params_abs)
    nu = jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=p.sharding),
        shapes.nu, params_abs)
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
    return OptState(step, mu, nu)


def abstract_cache(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh
                   ) -> Any:
    shapes = jax.eval_shape(
        lambda: T.init_cache(arch, shape.global_batch, shape.seq_len))
    shards = shd.cache_shardings(arch, shape, mesh, shapes)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=sh),
        shapes, shards)


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StepOptions:
    """Perf-hillclimb levers (EXPERIMENTS.md §Perf records each flip).

    grad_accum_dtype: f32 (baseline, exact) or bf16 (halves the
        accumulation buffer + its HBM/wire traffic; stochastic-rounding
        caveat documented).
    constrain_acts: with_sharding_constraint on the residual stream
        after every microbatch fold (stops the partitioner from
        speculatively resharding activations onto "model").
    accum_in_opt_dtype: fold the 1/n_accum scale into the loss
        (one fewer pass over the gradient tree).
    """

    grad_accum_dtype: str = ""     # "" -> the arch's configured dtype
    constrain_acts: bool = True
    scale_in_loss: bool = True


def make_train_step(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    opt_cfg: AdamWConfig = AdamWConfig(),
                    use_pallas: bool = False,
                    donate: bool = True,
                    options: StepOptions = StepOptions()) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    tp = model_tp(arch, mesh)
    dp = shd.axis_size(mesh, *shd.dp_axes(mesh))
    gb = shape.global_batch
    mb = arch.microbatch_per_device * dp
    n_accum = max(gb // max(mb, 1), 1)
    mb = gb // n_accum
    acc_dt = jnp.dtype(options.grad_accum_dtype or
                       arch.grad_accum_dtype)
    scale = 1.0 / n_accum if options.scale_in_loss else 1.0

    def loss_of(p, batch):
        return T.loss_fn(p, batch, arch, use_pallas, tp) * scale

    def train_step(params, opt_state, batch):
        def fold(i, b):
            return jax.tree.map(
                lambda x: x.reshape((n_accum, mb) + x.shape[1:])[i], b)

        def acc_step(carry, i):
            loss_acc, grads_acc = carry
            mb_batch = fold(i, batch)
            if options.constrain_acts:
                mb_batch = {k: shd.constrain(v, mesh,
                                             shd.dp_axes(mesh))
                            for k, v in mb_batch.items()}
            loss, grads = jax.value_and_grad(loss_of)(params, mb_batch)
            grads = jax.tree.map(
                lambda a, g: a + g.astype(acc_dt), grads_acc, grads)
            return (loss_acc + loss, grads), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dt), params)
        (loss_sum, grads), _ = jax.lax.scan(
            acc_step, (jnp.float32(0.0), zeros), jnp.arange(n_accum))
        if not options.scale_in_loss:
            grads = jax.tree.map(lambda g: g / n_accum, grads)
        new_params, new_opt, metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss_sum / (n_accum * scale)
        return new_params, new_opt, metrics

    return train_step, n_accum


def make_prefill_step(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                      use_pallas: bool = False) -> Callable:
    tp = model_tp(arch, mesh)

    def prefill_step(params, batch):
        hidden, cache = T.prefill(params, batch, arch, use_pallas, tp)
        logits = T.logits_fn(params, hidden)
        return logits, cache

    return prefill_step


def make_decode_step(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh
                     ) -> Callable:
    tp = model_tp(arch, mesh)

    def dstep(params, cache, batch):
        return T.decode_step(params, cache, batch, arch, tp)

    return dstep


def make_concrete_batch(arch: ArchConfig, shape: ShapeConfig,
                        key, batch_override: Optional[int] = None,
                        seq_override: Optional[int] = None
                        ) -> Dict[str, jnp.ndarray]:
    """Small concrete batch for host runs (examples/tests)."""
    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    ks = jax.random.split(key, 4)
    out = {"tokens": jax.random.randint(ks[0], (b, s if shape.kind !=
                                                 "decode" else 1), 0,
                                        arch.vocab_size)}
    if shape.kind == "train":
        out["labels"] = jax.random.randint(ks[1], (b, s), 0,
                                           arch.vocab_size)
    if arch.family == "vlm":
        out["patch_embeds"] = jax.random.normal(
            ks[2], (b, arch.n_frontend_tokens, frontend_dim(arch)),
            jnp.dtype(arch.dtype))
    if arch.family == "audio" and shape.kind != "decode":
        out["frame_embeds"] = 0.1 * jax.random.normal(
            ks[3], (b, s, arch.d_model), jnp.dtype(arch.dtype))
    return out
