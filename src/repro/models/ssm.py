"""State-space blocks: Mamba-1 (falcon-mamba) and Mamba-2/SSD (zamba2).

Three execution paths per version, all agreeing numerically (tested):

* `*_scan_ref`   — sequential `lax.scan` over time: the oracle, and the
  decode path (one step == one scan iteration with carried state).
* `*_chunked`    — the production train/prefill path: sequential scan
  over chunks with parallel work inside a chunk.  Mamba-1 (per-channel
  diagonal decay) uses an associative scan within the chunk; Mamba-2
  (scalar decay per head) uses the SSD quadratic-within-chunk form.
  Peak memory is O(chunk) not O(seq), which is what makes the
  `long_500k` cell feasible.  kernels/scan is the Pallas twin of the
  Mamba-1 chunk body.
* decode steps carry (ssm_state, conv_state) explicitly.

An SSM layer's sequential dependence is the purest dataflow chain in
the framework — the chunk carry is literally a future passed between
chunk tasks (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import Params, _init_dense


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv.  x: (B, S, D), w: (D, K).

    Returns (y, new_state) where state is the trailing K-1 inputs.
    """
    b, s, d = x.shape
    k = w.shape[1]
    if state is None:
        state = jnp.zeros((b, k - 1, d), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(k):
        y = y + xp[:, i:i + s, :] * w[:, i]
    new_state = xp[:, s:, :] if k > 1 else state
    return y, new_state


def _softplus(x):
    return jax.nn.softplus(x)


# ---------------------------------------------------------------------------
# Mamba-1 (diagonal per-channel decay; falcon-mamba-7b)
# ---------------------------------------------------------------------------

def mamba1_init(key, cfg: ArchConfig) -> Params:
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    a = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :],
                 (di, 1))
    return {
        "in_proj": _init_dense(ks[0], d, 2 * di, dt),
        "conv_w": (jax.random.normal(ks[1], (di, cfg.ssm_conv),
                                     jnp.float32) * 0.2).astype(dt),
        "x_proj": _init_dense(ks[2], di, dt_rank + 2 * st, dt),
        "dt_proj": _init_dense(ks[3], dt_rank, di, dt),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(a),                       # f32 always
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": _init_dense(ks[5], di, d, dt),
    }


def _mamba1_inputs(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                   conv_state: Optional[jnp.ndarray]):
    """Shared pre-scan computation: projections + conv + discretization."""
    di, st = cfg.d_inner, cfg.ssm_state
    dt_rank = max(cfg.d_model // 16, 1)
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, new_conv = causal_conv1d(xin, params["conv_w"], conv_state)
    xc = jax.nn.silu(xc)
    proj = xc @ params["x_proj"]
    dt_in = proj[..., :dt_rank]
    b_in = proj[..., dt_rank:dt_rank + st].astype(jnp.float32)
    c_in = proj[..., dt_rank + st:].astype(jnp.float32)
    dt = _softplus((dt_in @ params["dt_proj"]).astype(jnp.float32)
                   + params["dt_bias"])            # (B,S,di)
    a = -jnp.exp(params["a_log"])                  # (di, st)
    da = jnp.exp(dt[..., None] * a)                # (B,S,di,st)
    dbx = (dt * xc.astype(jnp.float32))[..., None] * b_in[..., None, :]
    return xc, z, da, dbx, c_in, new_conv


def mamba1_scan_ref(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                    ssm_state: Optional[jnp.ndarray] = None,
                    conv_state: Optional[jnp.ndarray] = None):
    """Sequential oracle / decode path.  x: (B, S, d_model)."""
    di, st = cfg.d_inner, cfg.ssm_state
    b = x.shape[0]
    xc, z, da, dbx, c_in, new_conv = _mamba1_inputs(
        params, x, cfg, conv_state)
    h0 = ssm_state if ssm_state is not None else \
        jnp.zeros((b, di, st), jnp.float32)

    def step(h, t):
        da_t, dbx_t, c_t = t
        h = da_t * h + dbx_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    hT, ys = jax.lax.scan(
        step, h0,
        (da.swapaxes(0, 1), dbx.swapaxes(0, 1), c_in.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + params["d_skip"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"], hT, new_conv


def mamba1_chunked(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                   chunk: int = 256,
                   ssm_state: Optional[jnp.ndarray] = None,
                   conv_state: Optional[jnp.ndarray] = None):
    """Chunked scan: associative scan inside chunks, carry across.

    Peak intermediate: (B, chunk, d_inner, state) — O(chunk), not O(S):
    the discretization (da = exp(dt*A), dbx = dt*x*B) is computed
    INSIDE the chunk step from (B, chunk, ...) slices.  Materializing
    it full-sequence costs (B, S, d_inner, state) f32 — 16.5 GiB/device
    for falcon-mamba train_4k (§Perf fix F8).
    """
    di, st = cfg.d_inner, cfg.ssm_state
    dt_rank = max(cfg.d_model // 16, 1)
    b, s, _ = x.shape
    # conv + projections (O(S*d) tensors only)
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, new_conv = causal_conv1d(xin, params["conv_w"], conv_state)
    xc = jax.nn.silu(xc)
    proj = xc @ params["x_proj"]
    dt_in = proj[..., :dt_rank]
    b_in = proj[..., dt_rank:dt_rank + st].astype(jnp.float32)
    c_in = proj[..., dt_rank + st:].astype(jnp.float32)
    dt = _softplus((dt_in @ params["dt_proj"]).astype(jnp.float32)
                   + params["dt_bias"])            # (B,S,di)
    a = -jnp.exp(params["a_log"])                  # (di, st)

    nch = max(s // chunk, 1)
    ch = s // nch

    def r(t, tail):
        return t.reshape((b, nch, ch) + tail).swapaxes(0, 1)

    dt_c = r(dt, (di,))
    xc_c = r(xc.astype(jnp.float32), (di,))
    b_c = r(b_in, (st,))
    c_c = r(c_in, (st,))
    h0 = ssm_state if ssm_state is not None else \
        jnp.zeros((b, di, st), jnp.float32)

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    @jax.checkpoint
    def chunk_step(h, t):
        # checkpointed: the scan backward otherwise SAVES the four
        # (B, chunk, d_inner, state) intermediates of every chunk —
        # re-materializing the full-sequence tensor F8 just removed
        dt_t, xc_t, b_t, c_t = t                 # (b,ch,di)/(b,ch,st)
        da_t = jnp.exp(dt_t[..., None] * a)      # (b,ch,di,st)
        dbx_t = (dt_t * xc_t)[..., None] * b_t[..., None, :]
        pa, pb = jax.lax.associative_scan(assoc, (da_t, dbx_t), axis=1)
        h_all = pa * h[:, None] + pb             # (b,ch,di,st)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, c_t)
        return h_all[:, -1], y

    hT, ys = jax.lax.scan(chunk_step, h0, (dt_c, xc_c, b_c, c_c))
    y = ys.swapaxes(0, 1).reshape(b, s, di) \
        + params["d_skip"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"], hT, new_conv


# ---------------------------------------------------------------------------
# Mamba-2 / SSD (scalar decay per head; zamba2)
# ---------------------------------------------------------------------------

def mamba2_init(key, cfg: ArchConfig) -> Params:
    d, di, st = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)
    return {
        "in_proj": _init_dense(ks[0], d, 2 * di + 2 * st + nh, dt),
        "conv_w": (jax.random.normal(ks[1], (di + 2 * st, cfg.ssm_conv),
                                     jnp.float32) * 0.2).astype(dt),
        "a_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "out_proj": _init_dense(ks[4], di, d, dt),
    }


def _mamba2_inputs(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                   conv_state):
    di, st = cfg.d_inner, cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = di // hd
    b, s, _ = x.shape
    proj = x @ params["in_proj"]
    z = proj[..., :di]
    xbc = proj[..., di:2 * di + 2 * st]
    dt = proj[..., 2 * di + 2 * st:]
    xbc, new_conv = causal_conv1d(xbc, params["conv_w"], conv_state)
    xbc = jax.nn.silu(xbc)
    xin = xbc[..., :di].reshape(b, s, nh, hd)
    b_in = xbc[..., di:di + st].astype(jnp.float32)     # (b,s,st)
    c_in = xbc[..., di + st:].astype(jnp.float32)
    dt = _softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (b,s,nh)
    a = -jnp.exp(params["a_log"])                        # (nh,)
    la = dt * a                                          # log-decay
    return xin, z, b_in, c_in, dt, la, new_conv


def mamba2_scan_ref(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                    ssm_state: Optional[jnp.ndarray] = None,
                    conv_state: Optional[jnp.ndarray] = None):
    """Sequential oracle / decode.  State: (B, nh, hd, st)."""
    di, st, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = di // hd
    b, s, _ = x.shape
    xin, z, b_in, c_in, dt, la, new_conv = _mamba2_inputs(
        params, x, cfg, conv_state)
    h0 = ssm_state if ssm_state is not None else \
        jnp.zeros((b, nh, hd, st), jnp.float32)

    def step(h, t):
        x_t, b_t, c_t, dt_t, la_t = t
        h = jnp.exp(la_t)[:, :, None, None] * h + \
            (dt_t[:, :, None] * x_t.astype(jnp.float32))[..., None] * \
            b_t[:, None, None, :]
        y = jnp.einsum("bhdn,bn->bhd", h, c_t)
        return h, y

    hT, ys = jax.lax.scan(
        step, h0,
        (xin.swapaxes(0, 1), b_in.swapaxes(0, 1), c_in.swapaxes(0, 1),
         dt.swapaxes(0, 1), la.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1)                                # (b,s,nh,hd)
    y = y + params["d_skip"][:, None] * xin.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"], hT, new_conv


def _segsum(la: jnp.ndarray) -> jnp.ndarray:
    """(..., c) log-decays -> (..., c, c) pairwise sums, causal-masked."""
    c = la.shape[-1]
    cs = jnp.cumsum(la, axis=-1)
    # decay from tau (exclusive) to t (inclusive): cs[t] - cs[tau]
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_chunked(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                   chunk: int = 256,
                   ssm_state: Optional[jnp.ndarray] = None,
                   conv_state: Optional[jnp.ndarray] = None):
    """SSD: quadratic within chunks, linear across (Mamba-2 paper)."""
    di, st, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = di // hd
    b, s, _ = x.shape
    xin, z, b_in, c_in, dt, la, new_conv = _mamba2_inputs(
        params, x, cfg, conv_state)
    nch = max(s // chunk, 1)
    ch = s // nch

    def r(t, tail):  # (b, s, ...) -> (nch, b, ch, ...)
        return t.reshape((b, nch, ch) + tail).swapaxes(0, 1)

    xin_c = r(xin, (nh, hd))
    b_c = r(b_in, (st,))
    c_c = r(c_in, (st,))
    dt_c = r(dt, (nh,))
    la_c = r(la, (nh,))
    h0 = ssm_state if ssm_state is not None else \
        jnp.zeros((b, nh, hd, st), jnp.float32)

    @jax.checkpoint
    def chunk_step(h, t):
        x_t, b_t, c_t, dt_t, la_t = t
        xw = x_t.astype(jnp.float32) * dt_t[..., None]   # (b,ch,nh,hd)
        lah = la_t.swapaxes(1, 2)                        # (b,nh,ch)
        seg = _segsum(lah)                               # (b,nh,ch,ch)
        gcb = jnp.einsum("bqn,bkn->bqk", c_t, b_t)       # (b,ch,ch)
        w = gcb[:, None] * jnp.exp(seg)                  # (b,nh,q,k)
        y_intra = jnp.einsum("bhqk,bkhd->bqhd", w, xw)
        # inter-chunk: contribution of incoming state
        cs = jnp.cumsum(lah, axis=-1)                    # log-decays
        dec_to_t = jnp.exp(cs)                           # (b,nh,ch)
        y_inter = jnp.einsum("bqn,bhdn,bhq->bqhd", c_t, h, dec_to_t)
        # state update: h' = decay_all * h + sum_k decay_from_k Bk xk
        dec_all = dec_to_t[..., -1]                      # (b,nh)
        dec_from = jnp.exp(cs[..., -1:] - cs)            # (b,nh,ch)
        h_new = dec_all[..., None, None] * h + jnp.einsum(
            "bkhd,bkn,bhk->bhdn", xw, b_t, dec_from)
        return h_new, y_intra + y_inter

    hT, ys = jax.lax.scan(chunk_step, h0,
                          (xin_c, b_c, c_c, dt_c, la_c))
    y = ys.swapaxes(0, 1).reshape(b, s, nh, hd)
    y = y + params["d_skip"][:, None] * xin.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"], hT, new_conv


def ssm_block_apply(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                    mode: str = "chunked", chunk: int = 256,
                    state: Optional[Dict] = None):
    """Uniform entry: returns (y, new_state dict)."""
    ver = cfg.mamba_version
    ssm_s = state["ssm"] if state else None
    conv_s = state["conv"] if state else None
    if ver == 1:
        fn = mamba1_scan_ref if mode == "ref" else mamba1_chunked
        if mode == "ref" or mode == "decode":
            y, h, c = mamba1_scan_ref(params, x, cfg, ssm_s, conv_s)
        else:
            y, h, c = mamba1_chunked(params, x, cfg, chunk, ssm_s, conv_s)
    else:
        if mode == "ref" or mode == "decode":
            y, h, c = mamba2_scan_ref(params, x, cfg, ssm_s, conv_s)
        else:
            y, h, c = mamba2_chunked(params, x, cfg, chunk, ssm_s, conv_s)
    return y, {"ssm": h, "conv": c}
