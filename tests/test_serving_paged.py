"""AGAS paged KV-cache subsystem: allocator, paged attention op,
paged-vs-dense decode parity, preemption, and the completion LCO."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import attention as att
from repro.models import transformer as T
from repro.serving.engine import (DenseServingEngine,
                                  PagedServingEngine, Request,
                                  make_engine)
from repro.serving.kvcache import (PagedKVCache, PageExhausted,
                                   PagePool, page_keys)

RNG = np.random.default_rng(7)


def _cfg(name="yi-6b"):
    return configs.get_reduced(name)


# -- page allocator ----------------------------------------------------

def test_pool_alloc_free_refcount_oom():
    pool = PagePool(_cfg(), n_pages=4, page_size=8)
    addrs = [pool.alloc() for _ in range(4)]
    assert pool.free_pages == 0 and pool.occupancy() == 1.0
    with pytest.raises(PageExhausted):
        pool.alloc()
    pool.incref(addrs[0])
    assert pool.refcount(addrs[0]) == 2
    pool.decref(addrs[0])
    assert pool.free_pages == 0          # still held once
    pool.decref(addrs[0])
    assert pool.free_pages == 1          # really freed
    a = pool.alloc()                     # reuses the freed slot
    assert 0 <= pool.row(a) <= 4
    for x in addrs[1:] + [a]:
        pool.decref(x)
    assert pool.free_pages == 4 and pool.used_pages == 0


def test_page_keys_chain_includes_prefix():
    a = np.arange(24, dtype=np.int32)
    b = np.arange(24, dtype=np.int32)
    b[2] = 99                            # diverge inside page 0
    ka, kb = page_keys(a, 8), page_keys(b, 8)
    assert len(ka) == 3
    # all pages differ: the chain commits to the full prefix
    assert all(x != y for x, y in zip(ka, kb))
    # identical prompts share every key; fill counts match
    assert page_keys(a, 8) == ka
    assert ka[-1][1] == 8
    assert page_keys(a[:20], 8)[-1][1] == 4
    # position normalization: a shorter prompt sharing the real-token
    # head shares the leading keys — total length is not in the name
    assert page_keys(a[:20], 8)[:2] == ka[:2]
    # ... but the pad count IS (RoPE positions differ across layouts)
    assert page_keys(a, 8, pad=4) != ka
    assert page_keys(a, 8, pad=4) == page_keys(a, 8, pad=4)
    # pad rows hash by position, not value: two layouts differing only
    # inside the pad region share every key
    c = a.copy()
    c[:4] = 77
    assert page_keys(c, 8, pad=4) == page_keys(a, 8, pad=4)


def test_prefix_sharing_and_cow():
    cfg = _cfg()
    kvc = PagedKVCache(cfg, slots=2, max_len=64, n_pages=8,
                       page_size=16)
    padded = RNG.integers(0, 100, size=24).astype(np.int32)
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    k = jnp.asarray(RNG.normal(size=(L, 24, kvh, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(L, 24, kvh, hd)), jnp.float32)
    kvc.attach(0, padded, k, v)
    used0 = kvc.pool.used_pages
    assert used0 == 2                    # one full + one partial page
    kvc.attach(1, padded, k, v)          # identical prompt: all shared
    assert kvc.pool.used_pages == used0
    assert kvc.pool.shares == 2
    assert np.array_equal(kvc.tables[0][:2], kvc.tables[1][:2])
    # first divergent append: slot 1 must COW the shared partial page
    kvc.prepare_decode(1)
    assert kvc.pool.cow_copies == 1
    assert kvc.tables[1][1] != kvc.tables[0][1]
    # shared content was cloned bit-for-bit
    r0, r1 = int(kvc.tables[0][1]), int(kvc.tables[1][1])
    np.testing.assert_array_equal(
        np.asarray(kvc.pool.pages["k"][:, r0, :8]),
        np.asarray(kvc.pool.pages["k"][:, r1, :8]))
    # slot 0 appends into its own page: refcount is 1 now, no COW
    kvc.prepare_decode(0)
    assert kvc.pool.cow_copies == 1
    kvc.release(0)
    kvc.release(1)
    assert kvc.pool.used_pages == 0      # no leaked refcounts


# -- paged attention op ------------------------------------------------

def _rand_pages(n, ps, kvh, d):
    k = jnp.asarray(RNG.normal(size=(n, ps, kvh, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(n, ps, kvh, d)), jnp.float32)
    return k, v


def test_paged_ref_matches_dense_decode_attention():
    """Gathering pages laid out contiguously must reproduce
    att.decode_attention over the equivalent dense cache."""
    from repro.kernels.attention.ref import paged_attention_ref
    cfg = _cfg("yi-6b")
    b, h, kvh, d, ps, npages = 2, cfg.n_heads, cfg.n_kv_heads, \
        cfg.head_dim, 8, 6
    q = jnp.asarray(RNG.normal(size=(b, 1, h, d)), jnp.float32)
    kp, vp = _rand_pages(npages + 1, ps, kvh, d)
    # both slots at the same position => dense semantics apply
    pos = 20
    tables = jnp.asarray(
        np.stack([[0, 1, 2, npages], [3, 4, 5, npages]]), jnp.int32)
    positions = jnp.full((b,), pos, jnp.int32)
    got = paged_attention_ref(q, kp, vp, tables, positions)
    # dense equivalent: contiguous cache rows from the same pages;
    # the null-page entries are masked on both sides (pos < len)
    k_dense = kp[tables].reshape(b, 4 * ps, kvh, d)
    v_dense = vp[tables].reshape(b, 4 * ps, kvh, d)
    ref = att.decode_attention(q, k_dense, v_dense,
                               jnp.int32(pos + 1), cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5)


@pytest.mark.parametrize("window", [0, 6])
@pytest.mark.parametrize("kvh", [1, 2])
def test_paged_pallas_kernel_matches_ref(window, kvh):
    from repro.kernels.attention.ops import paged_attention
    from repro.kernels.attention.ref import paged_attention_ref
    b, h, d, ps, npages, ptab = 3, 4, 16, 8, 9, 4
    q = jnp.asarray(RNG.normal(size=(b, 1, h, d)), jnp.float32)
    kp, vp = _rand_pages(npages + 1, ps, kvh, d)
    tables = jnp.asarray(RNG.integers(0, npages, size=(b, ptab)),
                         jnp.int32)
    positions = jnp.asarray([3, 17, 30], jnp.int32)
    ref = paged_attention_ref(q, kp, vp, tables, positions,
                              window=window)
    got = paged_attention(q, kp, vp, tables, positions, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5)


# -- decode parity: paged engine == dense engine, greedy ---------------

def _mixed_requests(cfg, n, lo=8, hi=30, max_new=8, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid, rng.integers(
        0, cfg.vocab_size, size=int(rng.integers(lo, hi)))
        .astype(np.int32), max_new_tokens=max_new)
        for rid in range(n)]


def _prepad(reqs, bucket):
    """Make the dense engine's left-padded stream the LITERAL prompt:
    the paged engines run prompts pad-free (tokens at positions
    0..len-1) while the dense baseline left-pads to its bucket, so
    cross-engine parity is only meaningful when the pad is explicit in
    the prompt itself — every engine then computes the identical
    layout."""
    out = []
    for r in reqs:
        p = np.zeros(bucket, np.int32)
        p[bucket - len(r.prompt):] = r.prompt
        out.append(Request(r.rid, p, max_new_tokens=r.max_new_tokens,
                           temperature=r.temperature, eos_id=r.eos_id))
    return out


@pytest.mark.parametrize("arch", ["yi-6b", "mixtral-8x7b"])
def test_paged_engine_token_parity_with_dense(arch):
    """Greedy decode over block tables is token-identical to the dense
    slot-pool cache (same bucket, simultaneous admission).

    Caveat: the two engines compile separate executables, so a logit
    near-tie could in principle resolve differently; this seed has no
    such ties (stable across many runs)."""
    cfg = _cfg(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _prepad(_mixed_requests(cfg, 4, seed=3), 32)
    kw = dict(slots=4, max_len=96, prefill_buckets=(32,))
    pe = PagedServingEngine(params, cfg, page_size=16, **kw)
    de = DenseServingEngine(params, cfg, **kw)
    for r in reqs:
        pe.submit(r)
        de.submit(r)
    pe.run_to_completion()
    de.run_to_completion()
    ptoks = {c.rid: c.tokens for c in pe.completions}
    dtoks = {c.rid: c.tokens for c in de.completions}
    assert set(ptoks) == {r.rid for r in reqs}
    assert ptoks == dtoks


# -- page pressure: preemption, completion LCO, counters ---------------

def test_preemption_under_page_pressure_completes_all():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    reqs = [Request(rid, rng.integers(0, cfg.vocab_size, size=24)
                    .astype(np.int32), max_new_tokens=20)
            for rid in range(5)]
    # 14 pages of 8 cannot hold 5 requests' worst case (6 pages each):
    # the engine must preempt and still finish everything
    eng = PagedServingEngine(params, cfg, slots=5, max_len=80,
                             prefill_buckets=(32,), page_size=8,
                             n_pages=14)
    attached = []                        # every prefill cache layout
    orig_attach = eng.kvc.attach

    def logging_attach(slot, layout, k, v):
        attached.append(np.array(layout))
        orig_attach(slot, layout, k, v)
    eng.kvc.attach = logging_attach
    futs = [eng.submit(r) for r in reqs]
    eng.run_to_completion()
    assert len(eng.completions) == 5
    assert all(len(c.tokens) == 20 for c in eng.completions)
    assert eng.preemptions > 0
    assert eng.kvc.pool.used_pages == 0              # nothing leaked
    # preemption is seamless at the layout level: every re-admission
    # reconstructed [prompt | generated] exactly — pad-free, tokens at
    # positions 0..len-1 — so positions and context match what the
    # request saw before eviction.  (End-to-end greedy token equality
    # across two engine instances is NOT asserted: each engine
    # jit-compiles its own executables, and XLA may resolve float
    # near-ties differently between compilations.)
    n0 = 24                              # all prompts are 24 tokens
    resumed = [p for p in attached if len(p) > n0]
    assert len(resumed) == eng.preemptions
    prompts = {tuple(r.prompt.tolist()): r for r in reqs}
    comps = {c.rid: c for c in eng.completions}
    for layout in resumed:
        req = prompts[tuple(layout[:n0].tolist())]
        gen = list(layout[n0:])
        # the carried tokens are a verbatim prefix of the completion
        assert comps[req.rid].tokens[:len(gen)] == gen
    # completion LCOs fired exactly once, with the right payloads
    for r, f in zip(reqs, futs):
        assert f.done() and f.get().rid == r.rid
    # per-step telemetry recorded the pressure
    s = eng.stats()
    assert s["steps"] == len(eng.counters) > 0
    assert 0.0 < s["peak_page_occupancy"] <= 1.0
    assert s["preemptions"] == eng.preemptions


def test_admission_gated_on_pages_not_slots():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = PagedServingEngine(params, cfg, slots=4, max_len=64,
                             prefill_buckets=(32,), page_size=16,
                             n_pages=5)
    rng = np.random.default_rng(4)
    for rid in range(3):
        eng.submit(Request(rid, rng.integers(
            0, cfg.vocab_size, size=20).astype(np.int32),
            max_new_tokens=4))
    eng._admit()
    # 5 pages admit at most one 32-token prompt (2 pages + headroom)
    # at a time even though 4 slots are free
    assert len(eng.active) < 3
    assert len(eng.free_slots) > 0
    eng.run_to_completion()
    assert len(eng.completions) == 3


def test_oversized_prompt_rejected_without_killing_engine():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = PagedServingEngine(params, cfg, slots=2, max_len=96,
                             prefill_buckets=(64, 128), page_size=16)
    # 100 real tokens exceed max_len (the cache layout is pad-free, so
    # the limit is on REAL length; the 128-wide compute bucket is fine)
    f_big = eng.submit(Request(0, np.arange(100, dtype=np.int32) % 250,
                               max_new_tokens=4))
    f_ok = eng.submit(Request(1, np.arange(10, dtype=np.int32),
                              max_new_tokens=4))
    eng.run_to_completion()
    with pytest.raises(ValueError, match="exceeds max_len"):
        f_big.get()
    assert len(f_ok.get().tokens) == 4       # the valid request lived


def test_generation_truncates_at_max_len_instead_of_overflowing():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = PagedServingEngine(params, cfg, slots=2, max_len=64,
                             prefill_buckets=(32,), page_size=16)
    f1 = eng.submit(Request(0, np.arange(10, dtype=np.int32),
                            max_new_tokens=80))
    f2 = eng.submit(Request(1, np.arange(8, dtype=np.int32),
                            max_new_tokens=4))
    eng.run_to_completion()
    # 10 prompt tokens + 54 decode writes fill max_len 64; prefill's
    # first token needs no cache row, so 55 tokens come back
    assert len(f1.get().tokens) == 55
    assert len(f2.get().tokens) == 4
    assert eng.kvc.pool.used_pages == 0


def test_make_engine_falls_back_for_recurrent_families():
    cfg = _cfg("falcon-mamba-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = make_engine(params, cfg, slots=2, max_len=64,
                      prefill_buckets=(32,))
    assert isinstance(eng, DenseServingEngine)
    eng.submit(Request(0, np.arange(10, dtype=np.int32),
                       max_new_tokens=4))
    eng.run_to_completion()
    assert len(eng.completions) == 1
