"""optim subpackage."""
