"""checkpoint subpackage."""
