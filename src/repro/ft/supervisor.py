"""Checkpoint-restart supervisor: the outer fault-tolerance loop.

Runs a step function under a failure budget: on any failure (injected
or real) it restores the last checkpoint and replays.  Data-order
determinism (data/pipeline.py) makes replay exact: the loss trace
after recovery bitwise-matches an uninterrupted run (tested).

At real scale this loop runs on the coordinator; workers re-join via
jax.distributed re-initialization and the elastic restore path
(checkpoint/checkpoint.py re-shards onto the surviving mesh — losing a
pod halves the mesh, restore still proceeds).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.checkpoint.checkpoint import Checkpointer
from repro.ft.failures import FailurePlan, InjectedFailure


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_every: int = 10
    max_restarts: int = 8
    total_steps: int = 100


@dataclasses.dataclass
class RecoveryBudget:
    """The supervisor's restart budget, factored out so the serving
    engine's locality-loss recovery (DESIGN.md §4g) spends from the
    same ledger: each recovered failure costs one restart; exceeding
    the budget re-raises, exactly like `run_supervised` — a fleet that
    keeps losing localities should crash loudly, not thrash forever."""

    max_restarts: int = SupervisorConfig.max_restarts
    restarts: int = 0

    def spend(self, what: str = "failure") -> None:
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise InjectedFailure(
                f"recovery budget exhausted: {self.restarts} restarts "
                f"(max {self.max_restarts}) after {what}")


@dataclasses.dataclass
class RunTrace:
    losses: List[float]
    restarts: int
    steps_replayed: int
    wallclock_s: float


def run_supervised(
    cfg: SupervisorConfig,
    ckpt: Checkpointer,
    init_state: Callable[[], Any],
    step_fn: Callable[[Any, int], Tuple[Any, float]],
    failure_plan: FailurePlan = FailurePlan(),
) -> RunTrace:
    """Drive `step_fn` to cfg.total_steps surviving failures.

    state must be a checkpointable pytree; step_fn(state, step) ->
    (state, loss).  The loss trace is indexed by step (replayed steps
    overwrite — final trace equals the failure-free one).
    """
    t0 = time.perf_counter()
    losses: Dict[int, float] = {}
    restarts = 0
    replayed = 0
    already_failed: set = set()

    state = init_state()
    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        state, extra = ckpt.restore(latest, state)
        start = int(extra.get("next_step", latest))

    step = start
    while step < cfg.total_steps:
        try:
            failure_plan.check(step, already_failed)
            state, loss = step_fn(state, step)
            losses[step] = float(loss)
            step += 1
            if step % cfg.ckpt_every == 0:
                ckpt.save_async(step, state,
                                extra={"next_step": step})
        except InjectedFailure:
            restarts += 1
            if restarts > cfg.max_restarts:
                raise
            ckpt.wait()
            latest = ckpt.latest_step()
            state = init_state()
            if latest is not None:
                state, extra = ckpt.restore(latest, state)
                resume = int(extra.get("next_step", latest))
            else:
                resume = 0
            replayed += step - resume
            step = resume
    ckpt.wait()
    trace = [losses[i] for i in sorted(losses)]
    return RunTrace(trace, restarts, replayed,
                    time.perf_counter() - t0)
