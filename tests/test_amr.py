"""AMR application: physics convergence, engine equivalence, cone."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro import amr
from repro.amr import hierarchy as hi
from repro.amr import taskgraph as tg
from repro.core.scheduler import barrier_schedule, list_schedule


@pytest.fixture(scope="module")
def prob():
    return amr.WaveProblem(n_points=128, rmax=20.0, amplitude=0.005)


def test_initial_data_shapes(prob):
    u = amr.initial_data(prob)
    assert u.shape == (3, prob.n_points)
    assert float(amr.linf(u)) > 0


def test_uniform_evolution_stable(prob):
    u = amr.initial_data(prob)
    r = amr.grid(prob)
    for _ in range(100):
        u = amr.global_step(u, r, prob.dr, prob.dt, prob.p)
    assert np.all(np.isfinite(np.asarray(u)))
    # the pulse disperses/propagates; energy stays bounded
    assert float(amr.energy(u, r, prob.dr)) < 10.0


def test_spatial_convergence_second_order():
    """RK3+FD2 at fixed CFL -> observed order ~2 as dr -> 0."""
    import jax
    errs = []
    for n in (129, 257, 513):
        p = amr.WaveProblem(n_points=n, rmax=16.0, amplitude=0.003,
                            dtype="float64", cfl=0.2)
        with jax.experimental.enable_x64():
            u = amr.initial_data(p)
            r = amr.grid(p)
            t_target = 0.5
            n_steps = int(round(t_target / p.dt))
            for _ in range(n_steps):
                u = amr.global_step(u, r, p.dr, p.dt, p.p)
            errs.append((p.dr, np.asarray(u)))
    # Richardson: compare coarse vs fine restricted
    e1 = np.abs(errs[0][1][0] - errs[1][1][0][::2]).max()
    e2 = np.abs(errs[1][1][0] - errs[2][1][0][::2]).max()
    order = np.log2(e1 / max(e2, 1e-300))
    assert order > 1.6, f"observed order {order}"


@pytest.mark.parametrize("grain", [4, 16, 64])
@pytest.mark.parametrize("levels", [1, 2, 3])
def test_dataflow_equals_lockstep(prob, grain, levels):
    specs = amr.default_specs(prob, levels)
    ref = hi.run_ops_lockstep(
        amr.make_hierarchy(prob, specs),
        hi.enumerate_window_ops(levels, 2), prob)
    wg = tg.build_window_graph(specs, 2, grain)
    out = tg.run_window(wg, amr.make_hierarchy(prob, specs), prob)
    for l in range(levels):
        a, b = specs[l].proper_extent
        np.testing.assert_allclose(
            np.asarray(out[l].arr[:, a:b]),
            np.asarray(ref[l].arr[:, a:b]), atol=1e-6)


def test_random_topological_order_determinism(prob):
    specs = amr.default_specs(prob, 2)
    wg = tg.build_window_graph(specs, 2, 16)
    g = wg.graph
    rng = np.random.default_rng(7)

    def random_order():
        indeg = [len(t.deps) for t in g.tasks]
        ready = [t.tid for t in g.tasks if not t.deps]
        order = []
        while ready:
            i = rng.integers(len(ready))
            tid = ready.pop(i)
            order.append(tid)
            for s in g.tasks[tid].succs:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        return order

    outs = []
    for _ in range(3):
        st = amr.make_hierarchy(prob, specs)
        res = tg.run_window(wg, st, prob, order=random_order())
        outs.append(np.concatenate(
            [np.asarray(s.arr) for s in res], axis=-1))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_engines_agree_and_dataflow_wins(prob):
    specs = amr.default_specs(prob, 3)
    cfg = amr.EngineConfig(grain=8, n_workers=8)
    df, ba = amr.compare_engines(prob, specs, 3, cfg)
    assert df.makespan <= ba.makespan
    # with multiple levels + workers the win should be substantial
    assert ba.makespan / df.makespan > 1.5


def test_single_worker_no_benefit(prob):
    """Paper: 'When computing on just one processor, removing the
    timestep barrier has no performance impact'."""
    specs = amr.default_specs(prob, 2)
    cfg = amr.EngineConfig(grain=16, n_workers=1, barrier_cost=0.0)
    df, ba = amr.compare_engines(prob, specs, 2, cfg)
    assert df.makespan == pytest.approx(ba.makespan, rel=1e-6)


def test_cone_shape(prob):
    """Fig 5: the timestep front dips at the refined region.

    Uses FIFO queue priority (the paper's HPX scheduler); the default
    critical-path priority deliberately inverts the cone by racing the
    fine region ahead — that is the beyond-paper scheduler, compared in
    benchmarks/fig5_cone.py.
    """
    specs = amr.default_specs(prob, 3)
    wg = tg.build_window_graph(specs, 4, 8)
    tg.assign_owners(wg, 4)
    r = list_schedule(wg.graph, 4, overhead=4e-6,
                      priority=lambda t: t.tid)
    front = tg.timestep_front(wg, r.finish, r.makespan * 0.5,
                              prob.n_points)
    assert front.min() >= 0 and front.max() <= 4 + 1e-9
    fine = specs[2]
    fine_pts = slice(fine.lo // 4 + 2, fine.hi // 4 - 2)
    coarse_only = np.r_[front[:specs[1].lo // 2 - 2]]
    if len(coarse_only) and front[fine_pts].size:
        assert front[fine_pts].mean() <= coarse_only.mean() + 1e-9


def test_regrid_tracks_pulse(prob):
    from repro.amr import regrid as rg
    specs = [hi.LevelSpec(0, 0, prob.n_points, True, True)]
    states = amr.make_hierarchy(prob, specs)
    new_specs = rg.propose_specs(states, prob, 1e-4, 3)
    assert len(new_specs) >= 2
    lvl1 = new_specs[1]
    pulse_idx = 2 * int(prob.r0 / prob.dr)
    assert lvl1.lo <= pulse_idx <= lvl1.hi
    states2 = rg.transfer(states, new_specs, prob)
    for s in states2:
        assert np.all(np.isfinite(np.asarray(s.arr)))


def test_barrier_phases_respect_deps(prob):
    specs = amr.default_specs(prob, 2)
    wg = tg.build_window_graph(specs, 2, 16)
    tg.assign_owners(wg, 4)
    barrier_schedule(wg.graph, 4)   # raises on phase violations
