"""Sharding rules: parameter/activation PartitionSpecs by path name.

The production mesh is ("data", "model") single-pod or
("pod", "data", "model") multi-pod (launch/mesh.py).  Parallelism plan
(DESIGN.md §6):

  DP    batch over ("pod", "data")
  TP    heads / d_ff / vocab over "model"
  EP    (virtual) experts over "model"
  SP    decode KV caches: sequence over "model" (context parallelism)
  FSDP  for archs >= fsdp_threshold params: the non-"model" weight dim
        additionally sharded over "data"; optimizer states always
        follow the param spec (ZeRO via GSPMD).

Rules match on the last path segments of each parameter, so the same
table covers flat stacks (dense "layers/attn/wq") and nested stacks
(vlm "groups_self/attn/wq"); leading stack dims are unsharded (scan
slices them per layer).
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig, ShapeConfig


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: Mesh, *names: str) -> int:
    return int(np.prod([mesh.shape[n] for n in names]))


# Rule table: (path regex) -> spec for the TRAILING dims of the leaf.
# "F" is replaced by the fsdp axis ("data") or None.
_RULES = [
    # embeddings: input D-sharded (local lookup); output vocab-sharded.
    (r"out_embed/embedding$", ("model", "F")),
    (r"(^|/)embed/embedding$", ("VOCAB_OR_D",)),   # special-cased below
    (r"patch_proj/w$", (None, "model")),
    # attention
    (r"attn/wq$", ("F", "model")),
    (r"attn/wk$", ("F", "KV")),
    (r"attn/wv$", ("F", "KV")),
    (r"attn/wo$", ("model", "F")),
    # mlp
    (r"mlp/wi$", ("F", "model")),
    (r"mlp/wg$", ("F", "model")),
    (r"mlp/wdown$", ("model", "F")),
    # moe (virtual-expert stacked)
    (r"moe/router$", ("F", None)),
    (r"moe/wi$", ("model", "F", None)),
    (r"moe/wg$", ("model", "F", None)),
    (r"moe/wdown$", ("model", None, "F")),
    # ssm
    (r"ssm/in_proj$", ("F", "model")),
    (r"ssm/out_proj$", ("model", "F")),
    (r"ssm/conv_w$", ("model", None)),
    (r"ssm/x_proj$", ("model", None)),
    (r"ssm/dt_proj$", (None, "model")),
    (r"ssm/dt_bias$", ("model",)),
    (r"ssm/a_log$", ("model", None)),
    (r"ssm/d_skip$", ("model",)),
    # zamba2 per-group adapters
    (r"adapters/w$", ("F", "model")),
    # norms / scalars: replicated
    (r"norm/scale$", (None,)),
    (r"gate$", ()),
]


def _leaf_spec(path: str, ndim: int, arch: ArchConfig, mesh: Mesh,
               fsdp: bool) -> P:
    f = "data" if fsdp else None
    m = mesh.shape["model"]
    for pat, trailing in _RULES:
        if re.search(pat, path):
            if trailing == ("VOCAB_OR_D",):
                # tied embeddings serve as the output head too ->
                # vocab-parallel; untied input tables shard D (local
                # lookup, no gather).
                trailing = ("model", "F") if arch.tie_embeddings \
                    else (None, "model")
            spec = []
            for t in trailing:
                if t == "F":
                    spec.append(f)
                elif t == "KV":
                    # GQA: shard kv projections only when they divide
                    # the model axis; otherwise replicate kv heads.
                    kvdim = arch.n_kv_heads * arch.head_dim
                    spec.append("model" if kvdim % m == 0 else None)
                else:
                    spec.append(t)
            lead = [None] * (ndim - len(spec))
            return P(*lead, *spec)
    return P()   # replicate by default (safe fallback)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(params_shape: Any, arch: ArchConfig, mesh: Mesh,
                    fsdp: Optional[bool] = None) -> Any:
    """NamedSharding pytree matching a params (shape-)pytree."""
    if fsdp is None:
        fsdp = arch.param_count() >= 20e9
    def one(path, leaf):
        spec = _leaf_spec(_path_str(path), len(leaf.shape), arch, mesh,
                          fsdp)
        # Never shard a dim the leaf can't divide.
        fixed = []
        for d, ax in zip(leaf.shape,
                         list(spec) + [None] * (len(leaf.shape) -
                                                len(spec))):
            if ax is None:
                fixed.append(None)
            elif d % axis_size(mesh, ax) == 0:
                fixed.append(ax)
            else:
                fixed.append(None)
        return NamedSharding(mesh, P(*fixed))
    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_shardings(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh
                    ) -> Dict[str, NamedSharding]:
    """Input-batch shardings per shape kind."""
    dp = dp_axes(mesh)
    ns = lambda *spec: NamedSharding(mesh, P(*spec))
    out: Dict[str, NamedSharding] = {}
    b = shape.global_batch
    shard_b = b % axis_size(mesh, *dp) == 0
    bspec = dp if shard_b else None
    out["tokens"] = ns(bspec, None)
    if shape.kind == "train":
        out["labels"] = ns(bspec, None)
    if arch.family == "vlm":
        out["patch_embeds"] = ns(bspec, None, None)
    if arch.family == "audio":
        out["frame_embeds"] = ns(bspec, None, None)
    return out


def cache_shardings(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    cache_shape: Any) -> Any:
    """Decode-cache shardings (context parallelism).

    KV caches (..., B, S, KV, D): S over "model"; B over dp when it
    divides, else KV heads over "data" (the long_500k B=1 case); SSM
    states shard their channel dim over whatever divides.
    """
    dp = dp_axes(mesh)
    dpsz = axis_size(mesh, *dp)
    m = mesh.shape["model"]

    def one(path, leaf):
        p = _path_str(path)
        shp = leaf.shape
        if p in ("len", "cursor", "abs"):
            return NamedSharding(mesh, P())
        if p in ("k", "v"):
            nd = len(shp)
            b_i, s_i, kv_i = nd - 4, nd - 3, nd - 2
            spec = [None] * nd
            if shp[s_i] % m == 0:
                spec[s_i] = "model"
            if shp[b_i] % dpsz == 0:
                spec[b_i] = dp
            elif shp[kv_i] % dpsz == 0:
                spec[kv_i] = dp
            return NamedSharding(mesh, P(*spec))
        if p in ("ssm", "tail_ssm"):
            nd = len(shp)
            spec = [None] * nd
            # (..., B, di, st) mamba1 or (..., B, nh, hd, st) mamba2
            ch_i = nd - 2 if arch.mamba_version == 1 else nd - 3
            b_i = ch_i - 1
            if shp[ch_i] % m == 0:
                spec[ch_i] = "model"
            if shp[b_i] % dpsz == 0:
                spec[b_i] = dp
            elif shp[ch_i] % (dpsz * m) == 0:
                spec[ch_i] = (*dp, "model")
            return NamedSharding(mesh, P(*spec))
        if p in ("conv", "tail_conv"):
            nd = len(shp)
            spec = [None] * nd
            if shp[-1] % m == 0:
                spec[-1] = "model"
            b_i = nd - 3
            if shp[b_i] % dpsz == 0:
                spec[b_i] = dp
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def page_pool_pspec(kv_axis: str = "kv") -> P:
    """PartitionSpec for the sharded KV page arrays (DESIGN.md §4c):
    (L, n_shards, rows_per_shard, ps, KV, D) with the locality axis
    over the `kv_axis` mesh axis, everything else replicated."""
    return P(None, kv_axis, None, None, None, None)


def page_pool_shardings(mesh: Mesh, kv_axis: str = "kv"
                        ) -> NamedSharding:
    """NamedSharding placing one page-pool locality per device along
    the `kv_axis` mesh axis — the device-backed rendering of the AGAS
    LocalityDomain the serving allocator speaks."""
    return NamedSharding(mesh, page_pool_pspec(kv_axis))


def kv_pool_mesh(n_shards: int, kv_axis: str = "kv"):
    """Mesh with a trailing `kv_axis` of size n_shards, or None.

    Returns None when the runtime cannot back one locality per device
    (single shard, or the device count does not divide) — the pool
    then falls back to simulated localities on one device, which is
    bit-identical in results and lets the same engine config run in
    unit tests and on real meshes.
    """
    import jax
    nd = jax.device_count()
    if n_shards <= 1 or nd < n_shards or nd % n_shards:
        return None
    from repro.distributed.compat import make_mesh
    return make_mesh((nd // n_shards, n_shards), ("data", kv_axis))


def constrain(x, mesh: Mesh, *spec):
    """with_sharding_constraint helper tolerant of absent axes."""
    spec = tuple(s if (s is None or
                       all(a in mesh.axis_names
                           for a in ((s,) if isinstance(s, str) else s)))
                 else None for s in spec)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
