"""Sharded, asynchronous, elastic checkpointing.

Layout on disk (one directory per step):

    ckpt_dir/step_000123/
        manifest.json      tree structure, shapes, dtypes, specs
        arrays/<idx>.npy   one file per leaf (np.save)

Properties required at scale and provided here:

* ASYNC: `save_async` snapshots leaves to host memory (device->host is
  the only synchronous part) and writes files on a daemon thread — the
  training loop is blocked for the copy, not the I/O.
* SHARDED METADATA: the manifest stores each leaf's logical
  PartitionSpec, NOT its device layout, so...
* ELASTIC RESTORE: `restore` re-shards onto ANY mesh via device_put
  with the target sharding — a checkpoint from 256 chips restores on
  512, 8, or 1 (tests/test_checkpoint.py round-trips across meshes).
* ATOMICITY: the step directory is written under a tmp name and
  renamed; `latest_step` only sees complete checkpoints.
* RETENTION: keep the newest `keep` checkpoints.

On a real multi-host pod each host writes only its addressable shards;
in this container there is one process, so the snapshot is the full
array — the code path is identical, the shard filter is just trivial.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------
    def save_async(self, step: int, tree: Any,
                   extra: Optional[dict] = None) -> None:
        self.wait()
        paths, leaves, _ = _flatten_with_paths(tree)
        # Synchronous device->host snapshot (consistent cut).  Dtypes
        # numpy can't serialize natively (bfloat16 etc.) are stored as
        # raw bytes of the right width; the manifest keeps the logical
        # dtype for the restore-side view.
        host = [np.asarray(x) for x in leaves]
        logical_dtypes = [str(a.dtype) for a in host]
        host = [a.view(np.uint16) if a.dtype.name == "bfloat16" else a
                for a in host]
        manifest = {
            "step": step,
            "paths": paths,
            "shapes": [list(a.shape) for a in host],
            "dtypes": logical_dtypes,
            "extra": extra or {},
            "time": time.time(),
        }

        def write():
            try:
                final = os.path.join(self.dir, f"step_{step:09d}")
                tmp = final + ".tmp"
                os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
                for i, a in enumerate(host):
                    np.save(os.path.join(tmp, "arrays", f"{i}.npy"), a)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()

    def save(self, step: int, tree: Any,
             extra: Optional[dict] = None) -> None:
        self.save_async(step, tree, extra)
        self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree: Any,
                shardings: Optional[Any] = None
                ) -> Tuple[Any, dict]:
        """Restore onto `target_tree`'s structure; re-shard if given.

        `shardings` (a matching pytree of NamedSharding, e.g. from
        distributed/sharding.py on the NEW mesh) enables elastic
        restore onto a different mesh than the one that saved.
        """
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        paths, _, treedef = _flatten_with_paths(target_tree)
        if paths != manifest["paths"]:
            raise ValueError(
                "checkpoint/target tree mismatch:\n"
                f"  ckpt: {manifest['paths'][:5]}...\n"
                f"  tgt : {paths[:5]}...")
        arrays = [np.load(os.path.join(d, "arrays", f"{i}.npy"))
                  for i in range(len(paths))]
        import ml_dtypes
        arrays = [a.view(ml_dtypes.bfloat16)
                  if dt == "bfloat16" else a
                  for a, dt in zip(arrays, manifest["dtypes"])]
        if shardings is not None:
            shard_leaves = jax.tree_util.tree_leaves(shardings)
            arrays = [jax.device_put(a, s)
                      for a, s in zip(arrays, shard_leaves)]
        else:
            arrays = [jax.numpy.asarray(a) for a in arrays]
        return jax.tree_util.tree_unflatten(treedef, arrays), \
            manifest["extra"]
