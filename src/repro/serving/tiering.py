"""Two-tier AGAS page pool: device HBM + host DRAM (DESIGN.md §4d).

`TieredPagePool` extends the sharded pool of §4c with the vertical
memory axis the paper calls *percolation*: device HBM is the scarce
tier, host DRAM is ~10x larger, and pages move between them without
their `GlobalAddress` changing — demotion and promotion are ordinary
`AGAS.migrate` calls onto a host locality appended to the directory's
device shards (`core/percolation.tiered_domain`).  Block tables only
ever resolve device-resident rows; the pool's contract is that every
page referenced by an *active* decode slot is device-resident, and
everything else is fair game for the slow tier.

Three mechanisms live here:

* **LRU eviction with refcount pinning.**  A page whose refcount
  drops to 0 while it is still the prefix index's owner is *retained*
  cold instead of freed (prefix-cache spill): a later request with
  the same prefix shares it by refcount revival, skipping both the
  page write and — with compute skip on (DESIGN.md §4e) — the prefill
  work itself: the page's activation checkpoint is retained, spilled,
  and dropped in lockstep with the page (checkpoint bytes ride the
  demote/promote parcel counters), so a host-resident prefix hit
  restores KV and activation together.  Cold
  pages form an LRU list; when allocation finds no free device row,
  the least-recently-used cold device page is demoted to host (or
  dropped outright when the host tier is full too).  Pages with
  refcount > 0 are pinned: eviction never touches them.  Cold pages
  the radix prefix index marked *hot* (hit statistics crossed the pin
  threshold, serving/radix.py) are advisory-pinned: eviction passes
  over them while any other candidate exists, so hot shared prefixes
  ride out pressure on device while one-off tails percolate out.

* **Write-back offload.**  A preempted request's exclusively-owned
  pages (`refcount == 1`) demote to host as one batched copy parcel;
  the request's queue item keeps the refcounts through a `KVSnapshot`
  (serving/kvcache.py), so re-admission *restores* the KV byte-for-
  byte instead of re-running prefill.  Prefix pages it shared with
  still-active requests stay on device, pinned by their refcounts.

* **Staged promotion.**  `stage_promote` gathers a snapshot's
  host-resident payloads and hands them to the percolation
  `TransferEngine`, whose `jax.device_put` begins the host->device
  copy immediately; the engine's step scheduler stages the next
  admission's pages while the current decode batch runs, and
  `promote_pages` commits the staged payload with a donated scatter —
  a prefetch hit means the copy ran entirely under compute.

Transfers are padded to canonical power-of-two batch sizes (extra
gather rows read the null page, extra scatter rows write it), so the
compiled transfer programs are reused across arbitrary batch sizes
instead of recompiling per count — the same trick
`PagePool.migrate_pages` uses for its permutation programs.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agas import AGAS, GlobalAddress
from repro.core.parcels import canonical_size as canon_batch
from repro.core.percolation import (CopyParcel, Tier, TransferEngine,
                                    domain_tiers, tiered_domain)
from repro.models.config import ArchConfig
from repro.serving.kvcache import (PageExhausted, PagePool,
                                   _scatter_rows, _scatter_rows_sharded)


@jax.jit
def _gather_rows(arr, idx):
    return arr[:, idx]


@jax.jit
def _gather_rows_sharded(arr, loc, slot):
    return arr[:, loc, slot]


class TieredPagePool(PagePool):
    """PagePool with a host DRAM tier behind the device shards.

    ``host_pages`` sizes the slow tier.  The AGAS directory gains one
    host locality (id ``n_shards``, tier `Tier.HOST`) with its own
    capacity; `alloc` still places fresh pages on the least-loaded
    DEVICE shard — the host tier is reached only by explicit
    percolation (demote/promote), never by allocation.
    """

    tiered = True

    def __init__(self, cfg: ArchConfig, n_pages: int, page_size: int,
                 dtype=None, *, n_shards: int = 1, mesh=None,
                 kv_axis: str = "kv", host_pages: int = 0, tracer=None,
                 pin_threshold: int = 4, pin_capacity: int = 0):
        super().__init__(cfg, n_pages, page_size, dtype,
                         n_shards=n_shards, mesh=mesh, kv_axis=kv_axis,
                         tracer=tracer, pin_threshold=pin_threshold,
                         pin_capacity=pin_capacity)
        if host_pages <= 0:
            raise ValueError(
                f"host_pages {host_pages} must be positive "
                "(use PagePool for a single-tier pool)")
        self.host_pages = int(host_pages)
        self.host_locality = self.n_shards
        # rebuild the directory tiered: device shards 0..n_shards-1
        # keep their per-shard capacity, locality n_shards is the host
        # pool (nothing is allocated yet, so the swap is safe)
        self.agas = AGAS(
            tiered_domain(self.n_shards),
            [self.pages_per_shard] * self.n_shards + [self.host_pages],
            space="kvpage", tiers=domain_tiers(self.n_shards))
        dt = self.pages["k"].dtype
        shape = (cfg.n_layers, self.host_pages, self.page_size,
                 cfg.n_kv_heads, cfg.head_dim)
        # the host tier's payload store: plain process memory, written
        # by demotions and read by (staged) promotions
        self.host: Dict[str, np.ndarray] = {
            "k": np.zeros(shape, dt), "v": np.zeros(shape, dt)}
        self.xfer = TransferEngine(max_inflight=2)
        self.xfer.trace = self.trace
        self.xfer.queue.trace = self.trace
        # LRU of retained refcount-0 pages (gid -> None, oldest first);
        # residency (device vs host) is the directory's to answer
        self._cold: Dict[int, None] = {}
        # gid -> {"k","v"} host copies of device-resident pages that
        # percolated through the host tier (DESIGN.md §4g): captured at
        # promotion commit, invalidated by the page's next in-place
        # decode write (`note_page_write`) and dropped with the page
        # (`_purge_index`) — so a surviving shadow always equals the
        # device bytes, and a locality kill rebuilds from it
        self._host_shadow: Dict[int, Dict[str, np.ndarray]] = {}
        self.evictions = 0       # cold pages demoted under pressure
        self.cold_drops = 0      # retained pages dropped entirely
        self.offloaded = 0       # pages written back at preemption
        self.promoted = 0        # pages brought back to device

    # -- residency ----------------------------------------------------
    def tier_of(self, addr: GlobalAddress) -> Tier:
        return Tier(self.agas.tier_of(self.agas.locality_of(addr)))

    def on_device(self, addr: GlobalAddress) -> bool:
        return self.agas.locality_of(addr) < self.n_shards

    def host_slot(self, addr: GlobalAddress) -> int:
        loc, slot = self.agas.lookup(addr)
        assert loc == self.host_locality, \
            f"gid {addr.gid} is not host-resident"
        return slot

    # -- accounting (per tier) ----------------------------------------
    @property
    def device_free_rows(self) -> int:
        # active shards only: a dead shard's freed rows are not
        # allocatable, so they must not inflate the admission signal
        return sum(self.agas.free_count(l)
                   for l in self.active_shards())

    @property
    def host_free_rows(self) -> int:
        return self.agas.free_count(self.host_locality)

    @property
    def host_used(self) -> int:
        return len(self.agas.residents(self.host_locality))

    def cold_count(self, tier: Optional[Tier] = None) -> int:
        if tier is None:
            return len(self._cold)
        return sum(1 for g in self._cold
                   if self.tier_of(GlobalAddress(g, self.agas.space))
                   == tier)

    @property
    def free_pages(self) -> int:
        """The admission signal: device rows available now plus cold
        device pages an allocation may evict (refcount-0, unpinned)."""
        return self.device_free_rows + self.cold_count(Tier.DEVICE)

    def occupancy(self) -> float:
        """Fraction of DEVICE rows in use (live or cold) — the HBM
        pressure gauge; host-resident pages do not count."""
        return (self.capacity - self.device_free_rows) \
            / max(self.capacity, 1)

    def shard_used(self) -> List[int]:
        # device shards only: the host locality is not a load-balance
        # target (plan_rebalance/plan_rotation iterate this)
        return [int(n) for n in self.agas.load()[:self.n_shards]]

    # page_bytes comes from PagePool (handoffs need it untiered too)

    # -- refcount lifecycle: retention + revival ----------------------
    def _purge_index(self, gid: int) -> None:
        # a departing page's host shadow dies with its index entry —
        # same funnel, same atomicity guarantee (§4g)
        self._host_shadow.pop(gid, None)
        super()._purge_index(gid)

    def refcount(self, addr: GlobalAddress) -> int:
        return self._refs.get(addr.gid, 0)      # cold pages answer 0

    def incref(self, addr: GlobalAddress) -> None:
        if addr.gid in self._cold:              # revive a cold page
            del self._cold[addr.gid]
            self._refs[addr.gid] = 1
        else:
            self._refs[addr.gid] += 1

    def decref(self, addr: GlobalAddress) -> None:
        self._refs[addr.gid] -= 1
        if self._refs[addr.gid] > 0:
            return
        del self._refs[addr.gid]
        if self.prefix.owns_gid(addr.gid):
            # prefix-cache spill: the radix index still owns this page
            # — retain it cold (LRU tail = most recently used) instead
            # of freeing, activation checkpoint included; a later
            # identical prefix revives both
            self._cold[addr.gid] = None
            return
        self._purge_index(addr.gid)
        self.agas.free(addr)
        self.trace.instant("kvcache", "page_free", gid=addr.gid)

    def discard(self, addr: GlobalAddress) -> None:
        """Rollback decref: never retain (the page's content may not
        have been written — attach/begin_chunk register the prefix key
        before the batched page write lands)."""
        self._refs[addr.gid] -= 1
        if self._refs[addr.gid] > 0:
            return
        del self._refs[addr.gid]
        self._purge_index(addr.gid)
        self.agas.free(addr)
        self.trace.instant("kvcache", "page_free", gid=addr.gid)

    def _drop_cold(self, gid: int) -> None:
        """Drop a retained page entirely (either tier) — its radix
        node and activation checkpoint die with it, atomically
        (`_purge_index`), so a cover computed before the drop can
        never attach the freed address: `attach_covered` re-probes
        every key and raises instead."""
        addr = GlobalAddress(gid, self.agas.space)
        self.xfer.drop(("page", gid))    # gids never recycle: a
        del self._cold[gid]              # staged copy can't be claimed
        self._purge_index(gid)
        self.agas.free(addr)
        self.cold_drops += 1
        self.trace.instant("kvcache", "page_free", gid=gid)

    # -- allocation with eviction -------------------------------------
    def alloc(self, locality: Optional[int] = None) -> GlobalAddress:
        """Allocate a fresh device page, evicting LRU cold pages when
        every device row is taken.  Pages with refcount > 0 are never
        evicted, so exhaustion with no cold pages still raises
        `PageExhausted` (the engine's preemption signal)."""
        while True:
            try:
                return super().alloc(locality)
            except PageExhausted:
                if not self._evict_one():
                    raise

    def _evict_one(self) -> bool:
        """Demote (or drop) the LRU cold DEVICE page; False if no
        device page is evictable.

        Pin-aware: pages the radix index pinned as hot prefixes
        (DESIGN.md §4e — hit statistics cross the pin threshold) are
        passed over while any unpinned cold device page exists, so hot
        shared prefixes stay in HBM under pressure.  Pins are advisory,
        never load-bearing: when every candidate is pinned, the LRU
        pinned page is force-unpinned and evicted — correctness (and
        liveness) first."""
        fallback = None
        for gid in self._cold:                  # oldest first
            addr = GlobalAddress(gid, self.agas.space)
            if not self.on_device(addr):
                continue
            if self.prefix.is_pinned(gid):
                if fallback is None:
                    fallback = gid
                continue
            return self._evict_gid(gid)
        if fallback is not None:
            self.prefix.unpin_gid(fallback, forced=True)
            return self._evict_gid(fallback)
        return False

    def _evict_gid(self, gid: int) -> bool:
        if self.host_free_rows > 0:
            self._demote([GlobalAddress(gid, self.agas.space)],
                         key=("evict", gid))
            self.evictions += 1
        else:
            self._drop_cold(gid)
        return True

    # -- demote: device -> host ---------------------------------------
    def _demote(self, addrs: Sequence[GlobalAddress], key: Any) -> None:
        """One batched copy parcel device->host; directory moves are
        `AGAS.migrate`, so every global name survives.  All `addrs`
        must be device-resident and the host tier must have room."""
        if not addrs:
            return
        if self.trace.enabled:
            with self.trace.span("percolation", "demote", kind="copy",
                                 gids=[a.gid for a in addrs]):
                self._demote_impl(addrs, key)
            return
        self._demote_impl(addrs, key)

    def _demote_impl(self, addrs: Sequence[GlobalAddress],
                     key: Any) -> None:
        n = len(addrs)
        rows = [self.row(a) for a in addrs]
        pad = canon_batch(n)
        if self.sharded:
            loc, slot = self._split_rows(
                rows + [self.null_row] * (pad - n))
            loc, slot = jnp.asarray(loc), jnp.asarray(slot)
            spans = {nm: _gather_rows_sharded(self.pages[nm], loc, slot)
                     for nm in ("k", "v")}
        else:
            idx = jnp.asarray(rows + [self.null_row] * (pad - n),
                              jnp.int32)
            spans = {nm: _gather_rows(self.pages[nm], idx)
                     for nm in ("k", "v")}
        payload = self.xfer.to_host(spans)      # one DMA wave out
        # activation checkpoints spill with their page chain: their
        # bytes ride the same parcel (§4e)
        self.xfer.queue.record(CopyParcel(
            key, tuple(a.gid for a in addrs), "demote",
            n * self.page_bytes() + self.hidden_nbytes(addrs)))
        for i, a in enumerate(addrs):
            self.agas.migrate(a, self.host_locality)
            hs = self.host_slot(a)
            self.host["k"][:, hs] = payload["k"][:, i]
            self.host["v"][:, hs] = payload["v"][:, i]

    def _make_host_room(self, n: int) -> bool:
        """Free host rows by dropping LRU cold HOST pages (unpinned
        first — a pinned host page is still a hot prefix awaiting
        promotion); False if even that cannot make room for `n`
        demotions."""
        while self.host_free_rows < n:
            host_cold = [g for g in self._cold
                         if not self.on_device(
                             GlobalAddress(g, self.agas.space))]
            victim = next((g for g in host_cold
                           if not self.prefix.is_pinned(g)), None)
            if victim is None:
                if not host_cold:
                    return False
                victim = host_cold[0]
                self.prefix.unpin_gid(victim, forced=True)
            self._drop_cold(victim)
        return True

    # -- write-back offload (preemption path) -------------------------
    def offloadable(self, addrs: Sequence[GlobalAddress]
                    ) -> List[GlobalAddress]:
        """The subset of a slot's pages write-back would demote:
        exclusively owned (refcount 1) and device-resident.  Shared
        pages stay put, pinned by their other holders."""
        return [a for a in addrs
                if self._refs.get(a.gid, 0) == 1 and self.on_device(a)]

    def offload_pages(self, addrs: Sequence[GlobalAddress],
                      key: Any) -> Optional[int]:
        """Write back a preempted slot's exclusive pages to host as
        one copy parcel; returns pages demoted, or None when the host
        tier cannot hold them (the caller falls back to freeing)."""
        demote = self.offloadable(addrs)
        if not self._make_host_room(len(demote)):
            return None
        self._demote(demote, key=key)
        self.offloaded += len(demote)
        return len(demote)

    # -- promote: host -> device --------------------------------------
    def _host_payload(self, addrs: Sequence[GlobalAddress], pad: int
                      ) -> Dict[str, np.ndarray]:
        slots = [self.host_slot(a) for a in addrs]
        out = {}
        for nm in ("k", "v"):
            span = self.host[nm][:, slots]
            if pad > len(slots):
                w = [(0, 0)] * span.ndim
                w[1] = (0, pad - len(slots))
                span = np.pad(span, w)
            out[nm] = span
        return out

    def stage_promote(self, key: Any,
                      addrs: Sequence[GlobalAddress]) -> bool:
        """Begin the host->device copy of every host-resident page in
        `addrs` now (double-buffered; the copy overlaps whatever runs
        next).  True if staged (or nothing needs promoting)."""
        todo = [a for a in addrs if not self.on_device(a)]
        if not todo:
            return True
        pad = canon_batch(len(todo))
        return self.xfer.stage(key, [a.gid for a in todo],
                               self._host_payload(todo, pad))

    def _device_row_for(self, addr: GlobalAddress) -> None:
        """Migrate one host page onto the least-loaded device shard,
        evicting cold device pages as needed."""
        while True:
            loc = self.agas.least_loaded(tier=int(Tier.DEVICE))
            if self.agas.free_count(loc) > 0:
                self.agas.migrate(addr, loc)
                return
            if not self._evict_one():
                raise PageExhausted(
                    f"device tier full promoting gid {addr.gid} "
                    f"({self.capacity} device pages, none evictable)")

    def promote_pages(self, addrs: Sequence[GlobalAddress],
                      staged_key: Any = None) -> int:
        if not self.trace.enabled:
            return self._promote_pages(addrs, staged_key)
        todo = [a.gid for a in addrs if not self.on_device(a)]
        if not todo:
            return self._promote_pages(addrs, staged_key)
        with self.trace.span("percolation", "promote", kind="copy",
                             gids=todo) as sp:
            n = self._promote_pages(addrs, staged_key)
            sp.args["promoted"] = n
            return n

    def _promote_pages(self, addrs: Sequence[GlobalAddress],
                       staged_key: Any = None) -> int:
        """Ensure every page in `addrs` is device-resident.

        Uses the staged payload under `staged_key` when it matches
        (prefetch hit: the copy already ran under compute); otherwise
        issues the copy on demand.  Returns pages promoted.  Raises
        `PageExhausted` when the device tier cannot hold them even
        after evicting every cold page — already-promoted pages stay
        promoted (the snapshot remains consistent; a retry finishes
        the rest).
        """
        todo = [a for a in addrs if not self.on_device(a)]
        if not todo:
            if staged_key is not None:
                self.xfer.drop(staged_key)
            self._drop_page_staging(addrs)
            return 0
        pad = canon_batch(len(todo))
        staged = self.xfer.take(staged_key) \
            if staged_key is not None else None
        prefetched = staged is not None and \
            staged[0] == tuple(a.gid for a in todo)
        if prefetched:
            payload = staged[1]
        else:
            payload = {nm: jax.device_put(a) for nm, a in
                       self._host_payload(todo, pad).items()}
        # §4g: retain each promoted page's host bytes as its shadow —
        # the copy a later locality kill rebuilds from.  Captured from
        # the host rows (byte-identical to any staged payload) BEFORE
        # the directory migrates the pages off the host tier.
        for a in todo:
            hs = self.host_slot(a)
            self._host_shadow[a.gid] = {
                nm: self.host[nm][:, hs].copy() for nm in ("k", "v")}
        for a in todo:
            self._device_row_for(a)
        rows = [self.row(a) for a in todo]
        if self.sharded:
            loc, slot = self._split_rows(
                rows + [self.null_row] * (pad - len(rows)))
            loc, slot = jnp.asarray(loc), jnp.asarray(slot)
            self.pages["k"] = _scatter_rows_sharded(
                self.pages["k"], loc, slot, payload["k"])
            self.pages["v"] = _scatter_rows_sharded(
                self.pages["v"], loc, slot, payload["v"])
        else:
            idx = jnp.asarray(rows + [self.null_row] * (pad - len(rows)),
                              jnp.int32)
            self.pages["k"] = _scatter_rows(self.pages["k"], idx,
                                            payload["k"])
            self.pages["v"] = _scatter_rows(self.pages["v"], idx,
                                            payload["v"])
        self.xfer.queue.record_promote_commit(prefetched)
        # traffic counted at COMMIT with the unpadded payload size
        # (checkpoints promote with their chain, §4e), so the totals
        # measure copies that landed, demand or staged
        self.xfer.queue.record(CopyParcel(
            staged_key, tuple(a.gid for a in todo), "promote",
            len(todo) * self.page_bytes() + self.hidden_nbytes(todo)))
        self.promoted += len(todo)
        # every page in `addrs` is device-resident now: retire any
        # per-page staging that arrived by another path, or the stale
        # entries would clog the double buffer forever
        self._drop_page_staging(addrs)
        return len(todo)

    def _drop_page_staging(self, addrs: Sequence[GlobalAddress]
                           ) -> None:
        for a in addrs:
            self.xfer.drop(("page", a.gid))

    def ensure_device(self, addr: GlobalAddress) -> None:
        """Demand path for a single page (a prefix hit on a spilled
        page): promote it before anything resolves its row.  Checks
        the per-page staging key the chunk prefetcher uses."""
        if not self.on_device(addr):
            self.promote_pages([addr], staged_key=("page", addr.gid))
        else:
            self.xfer.drop(("page", addr.gid))

    # -- locality failure: host shadows + rebuild (DESIGN.md §4g) -----
    def note_page_write(self, addr: GlobalAddress) -> None:
        """An in-place decode write is landing on `addr`: its host
        shadow (if any) is stale from here on.  The page can only be
        re-shadowed by percolating through the host tier again (the
        next demote writes fresh host bytes; the next promote
        recaptures them)."""
        self._host_shadow.pop(addr.gid, None)

    def _forget_dead_page(self, gid: int) -> None:
        # gids never recycle, but a stale per-page staging entry would
        # clog the transfer double buffer forever
        self.xfer.drop(("page", gid))

    def _rebuild_page(self, addr: GlobalAddress) -> bool:
        """Rebuild a dead shard's page from its host-tier shadow.

        The AGAS name migrates to a surviving device shard (evicting
        cold pages if needed) and the shadow bytes are scattered into
        the new row — every block table referencing the page is one
        `refresh_tables` away from consistency, and the content is
        byte-identical because shadows are invalidated on in-place
        writes.  False when no shadow exists (the content died with
        the shard) or no surviving device row can be made.
        """
        shadow = self._host_shadow.get(addr.gid)
        if shadow is None:
            return False
        try:
            self._device_row_for(addr)
        except PageExhausted:
            return False
        pad = canon_batch(1)
        rows = [self.row(addr)] + [self.null_row] * (pad - 1)
        payload = {}
        for nm in ("k", "v"):
            span = shadow[nm][:, None]
            if pad > 1:
                w = [(0, 0)] * span.ndim
                w[1] = (0, pad - 1)
                span = np.pad(span, w)
            payload[nm] = jax.device_put(span)
        if self.sharded:
            loc, slot = self._split_rows(rows)
            loc, slot = jnp.asarray(loc), jnp.asarray(slot)
            self.pages["k"] = _scatter_rows_sharded(
                self.pages["k"], loc, slot, payload["k"])
            self.pages["v"] = _scatter_rows_sharded(
                self.pages["v"], loc, slot, payload["v"])
        else:
            idx = jnp.asarray(rows, jnp.int32)
            self.pages["k"] = _scatter_rows(self.pages["k"], idx,
                                            payload["k"])
            self.pages["v"] = _scatter_rows(self.pages["v"], idx,
                                            payload["v"])
        self.trace.instant("kvcache", "page_rebuilt", gid=addr.gid,
                           dst=self.agas.locality_of(addr))
        return True

    # -- cost model for admission -------------------------------------
    def page_cost(self, key: Tuple[bytes, int]) -> int:
        """Device rows one prefix key will consume: 0 for a device-
        resident hit, 1 for a miss OR a host-resident hit (promotion
        needs a device row too)."""
        addr = self.lookup_prefix(key)
        if addr is None:
            return 1
        return 0 if self.on_device(addr) else 1

    # -- drills and telemetry -----------------------------------------
    def demote_all_cold(self) -> int:
        """Forced-eviction drill: demote every evictable (cold,
        device-resident) page to host in one sweep; returns pages
        moved.  Outputs of everything still decoding must be unchanged
        — cold pages are refcount-0 by construction."""
        addrs = [GlobalAddress(g, self.agas.space) for g in self._cold]
        addrs = [a for a in addrs if self.on_device(a)]
        addrs = addrs[:self.host_free_rows]
        if addrs:
            self._demote(addrs, key=("drill", self.evictions))
            self.evictions += len(addrs)
        return len(addrs)

    def drop_all_cold(self) -> int:
        """Drop every retained cold page, both tiers (bench warmup
        reset: the timed trace starts from an empty pool)."""
        gids = list(self._cold)
        for gid in gids:
            self._drop_cold(gid)
        self.cold_drops -= len(gids)          # resets don't count
        return len(gids)

    # canonical `subsystem.metric` name -> legacy tier_stats() key
    TIER_LEGACY = {
        "tier.host_pages": "host_pages",
        "tier.host_used": "host_used",
        "tier.device_cold": "device_cold",
        "tier.host_cold": "host_cold",
        "tier.evictions": "evictions",
        "tier.cold_drops": "cold_drops",
        "tier.offloaded_pages": "offloaded_pages",
        "tier.promoted_pages": "promoted_pages",
    }

    def metrics(self) -> Dict[str, Any]:
        m = super().metrics()
        m.update({
            "tier.host_pages": self.host_pages,
            "tier.host_used": self.host_used,
            "tier.device_cold": self.cold_count(Tier.DEVICE),
            "tier.host_cold": self.cold_count(Tier.HOST),
            "tier.evictions": self.evictions,
            "tier.cold_drops": self.cold_drops,
            "tier.offloaded_pages": self.offloaded,
            "tier.promoted_pages": self.promoted,
            "tier.host_shadows": len(self._host_shadow),
        })
        m.update(self.xfer.queue.metrics())
        return m

    def tier_stats(self) -> Dict[str, Any]:
        m = self.metrics()
        s = {legacy: m[name] for name, legacy in self.TIER_LEGACY.items()}
        s.update(self.xfer.queue.stats())
        return s
