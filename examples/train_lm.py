"""End-to-end LM training driver on the host (CPU) mesh.

Trains a ~10M-parameter llama-style model (the yi-6b family scaled to
what one CPU core can push through a few hundred steps) on the
deterministic Markov corpus; loss drops well below the unigram entropy.
Checkpointing + failure recovery use the same code path as the pod
driver.  Scale d_model/layers up on real hardware.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses

import repro.configs as configs
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    base = configs.get("yi-6b")
    arch = dataclasses.replace(
        base, name="yi-host-10m", n_layers=args.layers,
        d_model=args.d_model, n_heads=8, n_kv_heads=4,
        d_ff=4 * args.d_model, vocab_size=4096, head_dim=0,
        dtype="float32", loss_chunk=64, microbatch_per_device=4)
    print(f"training {arch.name}: "
          f"{arch.param_count() / 1e6:.1f}M params, "
          f"{args.steps} steps x {args.batch}x{args.seq} tokens")
    _, _, losses = train(arch, args.steps, args.batch, args.seq,
                         ckpt_dir=args.ckpt_dir, ckpt_every=50,
                         log_every=20)
    import numpy as np
    first = np.mean([l for _, l in losses[:10]])
    last = np.mean([l for _, l in losses[-10:]])
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.2 else 'check config'})")


if __name__ == "__main__":
    main()
