"""Continuous-batching serving engines: chunked, paged, and dense.

The ParalleX reading of serving (DESIGN.md §4): each request is a
first-class object whose completion is an LCO — `submit` returns a
`core.lco.Future` that is set exactly once when the request finishes.
Arriving requests are parcels; decode is a dataflow chain per slot,
and the engine packs ready slots into batched decode steps (the
work-queue at token granularity).

Three engines share that skeleton:

* `ChunkedPagedServingEngine` (the default `ServingEngine`) — prefill
  is no longer one-shot per request: a prompt is split into
  page-size-aligned CHUNKS, each an independently schedulable task,
  and every `step()` spends a token budget on a mix of pending prefill
  chunks and the decode batch (decode-priority; chunks fill the
  remainder, FCFS by admission order — DESIGN.md §4b).  Time-to-first-
  token for short requests stops waiting behind long prompts, and the
  decode batch never idles for a whole-prompt admission — the serving
  rendering of the paper's Fig 3 granularity trade-off.

* `PagedServingEngine` — the whole-prompt baseline over the same AGAS
  page pool (serving/kvcache.py, DESIGN.md §4a; sharded across
  localities per §4c when `kv_shards > 1`): each admission runs
  one bucketed prefill for the entire prompt before any decode
  resumes.  Admission is gated on free *pages*, not free slots; when
  the pool runs dry the youngest request is preempted back to the
  queue (its pages freed, its progress carried so re-admission resumes
  seamlessly).  Every slot keeps its own position clock — there is no
  shared `len/cursor/abs`.  Per-step counters (queue depth, page
  occupancy, TTFT / inter-token latencies) expose the runtime's
  overheads in the spirit of the paper's Fig 9.

* `DenseServingEngine` — the static-ownership baseline: a bulk
  `(slots, max_len)` cache with one shared position clock spliced via
  `jnp.maximum`.  Kept as the CSP-style comparison point for parity
  tests and benchmarks/serve_bench.py; its memory scales with
  worst-case length whether or not tokens exist.

Design points that matter at scale and are implemented here:
* fixed-shape decode batch (slot pool) -> one compiled decode step;
* whole-prompt prefill runs at bucketed lengths (pad-to-bucket) and
  chunked prefill at one fixed chunk width, so compilation count stays
  bounded either way;
* slots free on EOS/length and refill from the queue (continuous
  batching);
* per-slot sampling state (greedy or temperature), keyed by the
  request id and its own generated-token count.
"""

from __future__ import annotations

import itertools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lco import Future
from repro.core.parcels import ParcelPort
from repro.core.percolation import CopyParcel, PercolationQueue
from repro.ft.failures import FailurePlan
from repro.ft.supervisor import RecoveryBudget
from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.obs.metrics import MetricsRegistry
from repro.obs.slo import FlightRecorder, NULL_RECORDER, classify, \
    record_verdict
from repro.obs.trace import NULL_TRACER
from repro.serving.kvcache import (PagedKVCache, PageExhausted,
                                   PAGED_FAMILIES, page_keys)
# Request/Completion moved to serving/types.py (the worker split);
# re-exported here because tests/benchmarks import them from engine
from repro.serving.types import Completion, Request, _mean, _pct  # noqa: F401
from repro.serving.workers import (DecodeWorker, HandoffDecodeWorker,
                                   ParcelPrefillWorker, PrefillWorker,
                                   PREFILL_ACTIONS, StepScheduler)


class _EngineBase:
    """Queue intake, bucketed prefill, sampling, and the run loop."""

    def __init__(self, params: Any, cfg: ArchConfig, *, slots: int,
                 max_len: int, prefill_buckets=(64, 128, 256),
                 tracer=None, flight_recorder=False):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.buckets = tuple(sorted(prefill_buckets))
        self.trace = tracer if tracer is not None else NULL_TRACER
        self.metrics = MetricsRegistry()
        # per-request lifecycle timelines (obs/slo.py); disabled is a
        # constant-time no-op singleton, mirroring NULL_TRACER
        self.recorder = FlightRecorder() if flight_recorder \
            else NULL_RECORDER
        self.slo_verdicts: Dict[int, dict] = {}   # rid -> classify()
        # queue items: {"req", "gen" (tokens carried over a
        # preemption), "preempts"}
        self.queue: List[dict] = []
        self.active: Dict[int, dict] = {}      # slot -> request state
        self.free_slots = list(range(slots))
        self.completions: List[Completion] = []
        self._futures: Dict[int, Future] = {}
        self._prefills: Dict[int, Any] = {}

    # -- observability ------------------------------------------------
    def set_tracer(self, tracer) -> None:
        """Rebind the tracer on the engine AND every subsystem it owns
        (pool, tiered transfer engine) — the hook serve_bench uses to
        attach tracing to an already-warmed engine."""
        tracer = tracer if tracer is not None else NULL_TRACER
        self.trace = tracer
        kvc = getattr(self, "kvc", None)
        if kvc is not None:
            kvc.trace = tracer
            kvc.pool.trace = tracer
            xfer = getattr(kvc.pool, "xfer", None)
            if xfer is not None:
                xfer.trace = tracer
                xfer.queue.trace = tracer

    def reset_metrics(self) -> None:
        """Zero the metrics registry (a serve_bench warmup boundary:
        callers that clear `completions`/`counters` clear this too so
        `stats()` stays consistent with the per-step telemetry).  The
        flight recorder and SLO verdicts reset with it — they are the
        same telemetry epoch."""
        self.metrics.reset()
        self.recorder.clear()
        self.slo_verdicts.clear()

    def set_recorder(self, recorder) -> None:
        """Swap the flight recorder on a warmed engine (serve_bench's
        recorder-cost A/B: same engine, recorder on vs off)."""
        self.recorder = recorder if recorder is not None \
            else NULL_RECORDER

    def _record_step_metrics(self, c: dict) -> None:
        """Fold one per-step counter dict into the registry."""
        m = self.metrics
        m.counter("engine.steps").inc()
        m.gauge("engine.peak_active").set_max(c["active"])
        resident = c.get("resident", c["active"])
        m.gauge("engine.peak_resident").set_max(resident)
        m.histogram("engine.resident").record(resident)
        if "page_occupancy" in c:
            m.gauge("engine.peak_page_occupancy").set_max(
                c["page_occupancy"])
        m.histogram("engine.decode_ms").record(c["decode_ms"])

    # -- request intake (a parcel arriving at the engine locality) ----
    def submit(self, req: Request) -> Future:
        """Enqueue; returns the completion LCO (set exactly once)."""
        fut = Future()
        self._futures[req.rid] = fut
        t_submit = time.perf_counter()
        self.queue.append({"req": req, "gen": [], "preempts": 0,
                           "t_submit": t_submit,
                           "ttft_s": None, "tok_t": []})
        self.trace.instant("engine", "submit", rid=req.rid,
                           prompt_len=len(req.prompt))
        if self.recorder.enabled:
            self.recorder.event(req.rid, "submit", t=t_submit,
                                prompt_len=len(req.prompt))
        return fut

    def _slot_bind(self, rid: int, slot: int) -> None:
        """Admission boundary: trace instant + flight-recorder bind
        event, one helper so every admit path records both."""
        self.trace.instant("engine", "slot_bind", rid=rid, slot=slot)
        if self.recorder.enabled:
            self.recorder.event(rid, "bind", slot=slot)

    @staticmethod
    def _queue_prompt(item: dict) -> np.ndarray:
        """Prompt + any tokens generated before a preemption."""
        req = item["req"]
        if item["gen"]:
            return np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(item["gen"], np.int32)])
        return np.asarray(req.prompt, np.int32)

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        # beyond the ladder: multiples of the largest bucket, so the
        # compile count stays bounded
        big = self.buckets[-1]
        return -(-n // big) * big

    @staticmethod
    def _pad_to(tokens: np.ndarray, length: int) -> np.ndarray:
        padded = np.zeros(length, np.int32)
        padded[length - len(tokens):] = tokens           # left-pad
        return padded

    def _padded_prompt(self, tokens: np.ndarray) -> np.ndarray:
        return self._pad_to(tokens, self._bucket(len(tokens)))

    def _prefill_fn(self, bucket: int):
        """One compiled prefill per bucket.  The real sequence may end
        before the padded buffer does (right-padded resumes); the last
        index is a traced operand, so it never forces a recompile."""
        if bucket not in self._prefills:
            cfg = self.cfg
            full_kv = self._FULL_KV

            def fn(params, tokens, last_index):
                batch = {"tokens": tokens}
                hidden, cache = T.prefill(params, batch, cfg,
                                          full_kv=full_kv,
                                          last_index=last_index)
                return T.logits_fn(params, hidden), cache
            self._prefills[bucket] = jax.jit(fn)
        return self._prefills[bucket]

    def _sample(self, logits: jnp.ndarray, req: Request,
                n_gen: int) -> int:
        """Sample keyed by (rid, generated-token count) — each step of
        each request gets a distinct PRNG key."""
        if req.temperature <= 0:
            return int(jnp.argmax(logits))
        key = jax.random.PRNGKey(req.rid * 7919 + n_gen)
        return int(jax.random.categorical(key,
                                          logits / req.temperature))

    def _reject(self, item: dict, err: Exception) -> None:
        """Fail one request without killing the engine: its completion
        LCO carries the error; everything else keeps flowing."""
        fut = self._futures.pop(item["req"].rid, None)
        if fut is not None:
            fut.set_error(err)

    def _finish_queued(self, item: dict) -> None:
        """Finish a queued (preempted) request without re-admitting it,
        delivering the generation it carries.  Used when re-admission
        hits the length cap: an un-preempted request in that state is
        truncate-finished with its tokens delivered, and a preempted
        one must be too — its generated tokens are real work, never to
        be discarded through an error LCO."""
        self._drop_item_kv(item)
        now = time.perf_counter()
        self._finish({"req": item["req"], "tokens": list(item["gen"]),
                      "prefill_s": 0.0, "t0": now,
                      "preempts": item.get("preempts", 0),
                      **self._latency_state(item, now)})

    def _drop_item_kv(self, item: dict) -> None:
        """Release any KV a queue item still owns (offloaded pages of
        a written-back preemption).  No-op for engines without a
        tiered pool."""

    def _fail_pending(self, err: Exception) -> None:
        """Fail every request still queued or active (engine exiting
        with work pending): each completion LCO carries the error, and
        pages/slots are reclaimed so the engine stays usable."""
        for slot in list(self.active):
            self.active.pop(slot)
            kvc = getattr(self, "kvc", None)
            if kvc is not None:
                kvc.release(slot)
            self.free_slots.append(slot)
        for item in self.queue:
            self._drop_item_kv(item)
        self.queue.clear()
        for rid in list(self._futures):
            fut = self._futures.pop(rid)
            if not fut.done():
                fut.set_error(err)

    def _finish(self, st: dict) -> None:
        tok_t = st.get("tok_t", [])
        now = time.perf_counter()
        comp = Completion(st["req"].rid, st["tokens"], st["prefill_s"],
                          now - st["t0"],
                          st.get("preempts", 0),
                          ttft_s=st.get("ttft_s") or 0.0,
                          itl_s=[b - a for a, b in zip(tok_t, tok_t[1:])])
        self.completions.append(comp)
        # latency metrics stream into bounded histograms at completion
        # time — stats() reads these, never a per-completion list
        m = self.metrics
        m.histogram("engine.prefill_ms").record(comp.prefill_s * 1e3)
        if comp.ttft_s > 0.0:
            m.histogram("engine.ttft_ms").record(comp.ttft_s * 1e3)
        itl_hist = m.histogram("engine.itl_ms")
        for d in comp.itl_s:
            itl_hist.record(d * 1e3)
        self.trace.instant("engine", "finish", rid=comp.rid,
                           n_tokens=len(comp.tokens))
        if self.recorder.enabled:
            self.recorder.event(comp.rid, "finish", t=now,
                                n_tokens=len(comp.tokens))
        req = st["req"]
        if req.ttft_deadline_ms is not None or \
                req.itl_deadline_ms is not None:
            v = classify(req, comp,
                         timeline=self.recorder.timeline(comp.rid))
            record_verdict(m, v)
            self.slo_verdicts[comp.rid] = v
        fut = self._futures.pop(comp.rid, None)
        if fut is not None:
            fut.set(comp)

    @staticmethod
    def _latency_state(item: dict, now: float) -> dict:
        """TTFT / inter-token bookkeeping threaded from a queue item
        into a slot state (and back, across preemptions)."""
        return {"t_submit": item.get("t_submit", now),
                "ttft_s": item.get("ttft_s"),
                "tok_t": list(item.get("tok_t", []))}

    def _first_token(self, st: dict, now: float) -> None:
        if st["ttft_s"] is None:
            st["ttft_s"] = now - st["t_submit"]
            if self.recorder.enabled:
                self.recorder.event(st["req"].rid, "first_token",
                                    t=now)
        st["tok_t"].append(now)

    @staticmethod
    def _stopped(req: Request, tokens: List[int]) -> bool:
        """EOS or length cap reached — checked after EVERY sampled
        token, including the one prefill produces (a max_new_tokens=1
        request must not enter the decode batch at all)."""
        if req.eos_id is not None and tokens and \
                tokens[-1] == req.eos_id:
            return True
        return len(tokens) >= req.max_new_tokens

    def step(self) -> int:
        """One scheduling step.  The root span of the per-step trace
        tree: overhead attribution decomposes its wall-clock into the
        child spans' kinds (obs/attribution.py)."""
        if not self.trace.enabled:
            return self._step()
        with self.trace.span("engine", "step") as sp:
            n = self._step()
            sp.args["ran"] = n
        return n

    def _step(self) -> int:
        raise NotImplementedError

    def _admit(self) -> None:
        raise NotImplementedError

    def run_to_completion(self, max_steps: int = 10_000,
                          on_step=None) -> None:
        """Drive the engine until idle.

        Never exits with submitted futures unset: exhausting
        `max_steps`, or a permanently head-of-line-blocked queue
        (nothing active to free pages, nothing admissible), fails the
        remaining futures instead of returning silently — a caller
        blocked on a completion LCO must either get its value or its
        error, never hang forever.
        """
        blocked_len = -1
        for _ in range(max_steps):
            if not self.active and not self.queue:
                return
            n = self.step()              # step() admits first
            if on_step is not None:      # periodic metrics reporting
                on_step(self)
            if n == 0 and not self.active and self.queue:
                # nothing ran and nothing is active: only a queue-head
                # rejection (queue shrinks) can change future steps —
                # an unchanged queue length means a permanent block
                if len(self.queue) == blocked_len:
                    self._fail_pending(RuntimeError(
                        f"head-of-line blocked: {len(self.queue)} "
                        "queued request(s) cannot be admitted and "
                        "nothing is active to free pages"))
                    return
                blocked_len = len(self.queue)
            else:
                blocked_len = -1
        if self.active or self.queue:
            self._fail_pending(RuntimeError(
                f"run_to_completion exhausted max_steps={max_steps} "
                f"with {len(self.active)} active and "
                f"{len(self.queue)} queued request(s)"))


class DenseServingEngine(_EngineBase):
    """Static bulk KV ownership: (slots, max_len), one shared clock."""

    _FULL_KV = False

    def __init__(self, params: Any, cfg: ArchConfig, *, slots: int = 4,
                 max_len: int = 512, prefill_buckets=(64, 128, 256),
                 tracer=None, flight_recorder=False):
        super().__init__(params, cfg, slots=slots, max_len=max_len,
                         prefill_buckets=prefill_buckets, tracer=tracer,
                         flight_recorder=flight_recorder)
        # one shared batched cache across slots
        self.cache = T.init_cache(cfg, slots, max_len)
        self._decode = jax.jit(
            lambda p, c, b: T.decode_step(p, c, b, cfg))

    def _admit(self) -> None:
        while self.queue and self.free_slots:
            item = self.queue.pop(0)
            req = item["req"]
            toks = self._padded_prompt(self._queue_prompt(item))
            bucket = len(toks)
            if bucket > self.max_len:
                self._reject(item, ValueError(
                    f"request {req.rid}: padded prompt {bucket} "
                    f"exceeds max_len {self.max_len}"))
                continue
            slot = self.free_slots.pop(0)
            self._slot_bind(req.rid, slot)
            t0 = time.perf_counter()
            with self.trace.span("engine", "prefill", kind="compute",
                                 rid=req.rid, bucket=bucket):
                logits, pcache = self._prefill_fn(bucket)(
                    self.params, jnp.asarray(toks[None]),
                    jnp.int32(bucket - 1))
            if self.recorder.enabled:
                self.recorder.event(req.rid, "prefill", bucket=bucket,
                                    dur=time.perf_counter() - t0)
            # splice this request's prefill cache into the slot pool
            self._splice_cache(slot, pcache, bucket)
            first = self._sample(logits[0], req, len(item["gen"]))
            now = time.perf_counter()
            self.active[slot] = {
                "req": req, "tokens": item["gen"] + [int(first)],
                "prefill_s": now - t0,
                "t0": now,
                "pos": bucket,
                "preempts": item["preempts"],
                **self._latency_state(item, now),
            }
            self._first_token(self.active[slot], now)
            if self._stopped(req, self.active[slot]["tokens"]):
                self._finish(self.active.pop(slot))
                self.free_slots.append(slot)

    def _splice_cache(self, slot: int, pcache: dict, plen: int) -> None:
        def splice(pool, part):
            if pool.ndim == 0 or part is None:
                return pool
            # find the batch axis: pool (…, slots, …) vs part (…,1,…)
            for ax in range(pool.ndim):
                if part.shape[ax] == 1 and pool.shape[ax] == self.slots:
                    break
            else:
                return pool
            # seq axes differ (plen vs max_len): pad part
            pads = []
            for d in range(pool.ndim):
                if d == ax:
                    pads.append((0, 0))
                else:
                    pads.append((0, pool.shape[d] - part.shape[d]))
            part = jnp.pad(part, pads)
            idx = [slice(None)] * pool.ndim
            idx[ax] = slice(slot, slot + 1)
            return pool.at[tuple(idx)].set(part)

        for k in self.cache:
            if k in ("len", "cursor", "abs"):
                continue
            self.cache[k] = splice(self.cache[k], pcache.get(k))
        # shared counters: the pool cache uses one clock; keep max
        self.cache["len"] = jnp.maximum(self.cache["len"],
                                        pcache["len"])
        self.cache["cursor"] = jnp.maximum(self.cache["cursor"],
                                           pcache["cursor"])
        self.cache["abs"] = jnp.maximum(self.cache["abs"],
                                        pcache["abs"])

    # -- the decode work-queue ----------------------------------------
    def _step(self) -> int:
        """One batched decode step over all active slots."""
        with self.trace.span("engine", "admit", kind="sched"):
            self._admit()
        if not self.active:
            return 0
        tokens = np.zeros((self.slots, 1), np.int32)
        for slot, st in self.active.items():
            tokens[slot, 0] = st["tokens"][-1]
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (self.slots, self.cfg.n_frontend_tokens,
                 32 if self.cfg.d_model < 1024 else 1280),
                jnp.dtype(self.cfg.dtype))
        with self.trace.span("engine", "decode_batch", kind="compute",
                             n=len(self.active)):
            logits, self.cache = self._decode(self.params, self.cache,
                                              batch)
        done = []
        now = time.perf_counter()
        for slot, st in self.active.items():
            req = st["req"]
            tok = self._sample(logits[slot], req, len(st["tokens"]))
            st["tokens"].append(tok)
            st["tok_t"].append(now)
            if self._stopped(req, st["tokens"]):
                done.append(slot)
        for slot in done:
            self._finish(self.active.pop(slot))
            self.free_slots.append(slot)
        return len(self.active) + len(done)


class PagedServingEngine(_EngineBase):
    """KV memory as AGAS pages: demand allocation, prefix sharing,
    page-gated admission, and preemption under pressure.

    ``kv_shards > 1`` shards the page pool across AGAS localities
    (DESIGN.md §4c): least-loaded allocation, per-shard occupancy in
    `stats()`, and imbalance-triggered page migration between steps
    (`rebalance_tolerance` pages of drift; pass a value < 1 to disable,
    None for the automatic default).  ``mesh`` (with a "kv" axis of
    size kv_shards) device-backs the shards; without it the localities
    are simulated on one device with bit-identical results.

    ``tiering=True`` adds the host DRAM tier (DESIGN.md §4d,
    serving/tiering.py; ``host_pages`` sizes it, default 4x the device
    pool): a preempted request's pages are written back to host and
    restored on re-admission instead of re-prefilled, cold prefix
    pages spill to host instead of dropping, and the step scheduler
    stages the next admission's host->device copies while the current
    batch computes.  Greedy outputs are token-identical with tiering
    on or off.

    ``prefix_cache_compute=True`` turns the prefix cache's memory
    savings into COMPUTE savings (DESIGN.md §4e): every prefill
    checkpoints the post-norm hidden state at each page's last
    position into the prefix index, and a later prompt fully covered
    by cached pages admits straight to decode — its first token is
    sampled from the cached checkpoint (`T.resume_prefill`), zero
    transformer passes.  This whole-prompt engine skips full covers
    only; the chunked engine also resumes partially covered prompts
    at the cover's end.  Greedy outputs are token-identical with the
    flag on or off.
    """

    _FULL_KV = True

    def __init__(self, params: Any, cfg: ArchConfig, *, slots: int = 4,
                 max_len: int = 512, prefill_buckets=(64, 128, 256),
                 page_size: int = 16, n_pages: Optional[int] = None,
                 kv_shards: int = 1, mesh=None,
                 rebalance_tolerance: Optional[int] = None,
                 tiering: bool = False, host_pages: int = 0,
                 prefix_cache_compute: bool = False,
                 pin_threshold: int = 4, tracer=None,
                 flight_recorder=False,
                 failure_plan: Optional[FailurePlan] = None):
        super().__init__(params, cfg, slots=slots, max_len=max_len,
                         prefill_buckets=prefill_buckets, tracer=tracer,
                         flight_recorder=flight_recorder)
        if n_pages is None:
            # default: the dense engine's worst-case footprint — callers
            # shrink it to oversubscribe (kvcache preempts under
            # pressure), or grow slots beyond what dense could afford —
            # rounded up to fill every KV shard evenly
            n_pages = slots * (-(-max_len // page_size))
            n_pages = -(-n_pages // kv_shards) * kv_shards
        if tiering and host_pages <= 0:
            host_pages = 4 * n_pages       # host DRAM ~several x HBM
        self._tiering = bool(tiering) and host_pages > 0
        self.kvc = PagedKVCache(cfg, slots, max_len, n_pages, page_size,
                                n_shards=kv_shards, mesh=mesh,
                                host_pages=host_pages
                                if self._tiering else 0,
                                pin_threshold=pin_threshold,
                                tracer=self.trace)
        if rebalance_tolerance is None:
            rebalance_tolerance = max(
                2, self.kvc.pool.pages_per_shard // 4)
        self._rebalance_tol = int(rebalance_tolerance)
        # donate the page pool: on accelerators the step updates KV
        # pages in place instead of holding input + output copies
        self._decode = jax.jit(
            lambda p, pages, b: T.decode_step_paged(p, pages, b, cfg),
            donate_argnums=(1,))
        self._seq = itertools.count()          # admission order
        self.preemptions = 0
        self.offloads = 0       # preemptions that wrote KV back to host
        self.restores = 0       # re-admissions that skipped prefill
        # locality-loss recovery (DESIGN.md §4g): the plan fires at the
        # top of a step; drained requests re-admit with futures pending
        self.failure_plan = failure_plan
        self._killed: set = set()     # (step, shard) pairs already fired
        self.recovery_budget = RecoveryBudget()
        self.re_prefills = 0     # requests that lost KV and re-prefilled
        self.drained_slots = 0   # active slots drained by a kill
        self.counters: List[dict] = []         # per-step telemetry
        # prefix-cache compute skip (DESIGN.md §4e)
        self._prefix_skip = bool(prefix_cache_compute)
        self.prefix_skips = 0            # fully-covered admissions
        self.prefix_partial_hits = 0     # partially-covered admissions
        self.prefill_tokens_skipped = 0  # prompt tokens never recomputed
        self._resume_logits = jax.jit(
            lambda p, h: T.resume_prefill(p, h))

    def _prefill_fn(self, bucket: int):
        """One compiled prefill per bucket, like the base engine's, but
        also returning the post-norm hidden at every page boundary plus
        the true last position — the activation checkpoints the prefix
        index stores for compute skip (DESIGN.md §4e).  The extra
        outputs are one tiny gather; the host copy that stores them is
        gated on `prefix_cache_compute` (the pool is per-engine, so a
        skip-off engine could never read them back)."""
        if bucket not in self._prefills:
            cfg = self.cfg
            ps = self.kvc.pool.page_size

            def fn(params, tokens, last_index):
                batch = {"tokens": tokens}
                hidden, cache = T.prefill(params, batch, cfg,
                                          full_kv=True, all_hidden=True)
                last = jax.lax.dynamic_index_in_dim(
                    hidden, last_index, axis=1, keepdims=False)
                return (T.logits_fn(params, last), cache,
                        hidden[:, ps - 1::ps], last)
            self._prefills[bucket] = jax.jit(fn)
        return self._prefills[bucket]

    # -- prefix-cache compute skip (DESIGN.md §4e) --------------------
    def _admit_skip(self, item: dict, layout: np.ndarray, real: int,
                    cov) -> bool:
        """Admit the queue head's fully-covered prompt straight to
        decode: attach the cached pages by refcount and sample the
        first token from the stored activation checkpoint — zero
        prefill compute, TTFT of one resume step.  False leaves the
        item at the queue head (pages or a promotion row not available
        yet — head-of-line blocking, like any page-gated admission)."""
        kvc = self.kvc
        need = sum(kvc.pool.page_cost(k) for k in cov.keys) + 1
        if need + self._upcoming_allocs() > kvc.pool.free_pages:
            return False
        self.queue.pop(0)
        slot = self.free_slots.pop(0)
        self._slot_bind(item["req"].rid, slot)
        t0 = time.perf_counter()
        try:
            kvc.attach_covered(slot, layout, cov.keys)
        except PageExhausted:
            # a covered page spilled and its promotion lost the race
            # for a device row; everything was rolled back — retry
            self.free_slots.append(slot)
            self.queue.insert(0, item)
            return False
        req = item["req"]
        tr = time.perf_counter() if self.recorder.enabled else 0.0
        with self.trace.span("engine", "resume", kind="compute",
                             rid=req.rid, slot=slot):
            logits = self._resume_logits(self.params,
                                         jnp.asarray(cov.hidden)[None])
        if self.recorder.enabled:
            self.recorder.event(req.rid, "resume",
                                dur=time.perf_counter() - tr)
        first = self._sample(logits[0], req, len(item["gen"]))
        now = time.perf_counter()
        self.prefix_skips += 1
        self.prefill_tokens_skipped += real
        self.active[slot] = {
            "req": req, "tokens": item["gen"] + [int(first)],
            "phase": "decode",       # no prefill phase at all (§4e)
            "n_gen0": len(item["gen"]),
            "prefill_s": now - t0,
            "t0": now,
            "seq": next(self._seq),
            "preempts": item["preempts"],
            "admit_step": len(self.counters),
            **self._latency_state(item, now),
        }
        self._first_token(self.active[slot], now)
        if self._stopped(req, self.active[slot]["tokens"]):
            self._finish(self.active.pop(slot))
            kvc.release(slot)
            self.free_slots.append(slot)
        return True

    # -- page-gated admission -----------------------------------------
    def _admission_layout(self, item: dict) -> Optional[tuple]:
        """Rebuild the queue head's token layout and screen out
        requests that can never run.

        Layouts are position-NORMALIZED: real tokens sit at positions
        0..len-1 with no pad, whether the request is fresh or
        re-admitted after a preemption (re-admission is simply prompt
        + generated tokens — identical positions, so it re-prefills to
        identical pages).  Padding exists only in the prefill COMPUTE
        buffer (right-pad to the bucket ladder, junk masked by the
        traced last index), never in the cache layout — which is what
        lets two prompts of different total lengths share prefix page
        keys (DESIGN.md §4e).  Returns (layout, real, need) where
        `need` counts fresh prefill pages plus one decode page of
        headroom, or None if the item was rejected (and popped)."""
        req = item["req"]
        layout = self._queue_prompt(item)
        real = len(layout)
        if real > self.max_len:
            self.queue.pop(0)
            if item["gen"]:
                # re-admission at the length cap: finish with the
                # partial generation (exactly what an un-preempted
                # request in this state gets via truncation) — never
                # error the LCO and discard generated tokens
                self._finish_queued(item)
            else:
                self._reject(item, ValueError(
                    f"request {req.rid}: prompt {real} "
                    f"exceeds max_len {self.max_len}"))
            return None
        need = self.kvc.pages_needed(layout) + 1
        if need > self.kvc.pool.capacity:
            self.queue.pop(0)
            if item["gen"]:
                self._finish_queued(item)
            else:
                self._reject(item, RuntimeError(
                    f"request {req.rid} needs {need} pages but the "
                    f"pool holds {self.kvc.pool.capacity}"))
            return None
        return layout, real, need

    def _upcoming_allocs(self) -> int:
        """Pages the CURRENT step's committed work will still take
        (decode writes at a page boundary or COW) — the admission
        watermark, so an admission can never be preempted away in the
        very same step."""
        return sum(1 for s in self.active if self.kvc.needs_alloc(s))

    def _admit(self) -> None:
        while self.queue and self.free_slots:
            item = self.queue[0]
            req = item["req"]
            if item.get("snap") is not None:
                if self._try_restore(item):
                    continue
                break                          # head-of-line blocking
            adm = self._admission_layout(item)
            if adm is None:
                continue
            layout, real, need = adm
            if self._prefix_skip:
                cov = self.kvc.covered_prefix(layout)
                if cov.full:
                    if self._admit_skip(item, layout, real, cov):
                        continue
                    break                      # head-of-line blocking
            # admit on PAGES, not slots: prefill pages (prefix-shared
            # ones are free), one decode page of headroom, plus a
            # watermark for active slots whose next write takes a page
            # (boundary alloc or COW) — otherwise an admission can be
            # preempted away in the very same step
            upcoming = self._upcoming_allocs()
            if need + upcoming > self.kvc.pool.free_pages:
                break                          # head-of-line blocking
            self.queue.pop(0)
            slot = self.free_slots.pop(0)
            self._slot_bind(req.rid, slot)
            t0 = time.perf_counter()
            # all prefills run at the bucket ladder: pad RIGHT (junk
            # tokens after the real end never enter the cache and,
            # under causality, cannot influence earlier positions), so
            # the compile count stays bucket-bounded while the CACHE
            # layout stays pad-free
            bucket = self._bucket(real)
            toks = np.zeros(bucket, np.int32)
            toks[:real] = layout
            tr = time.perf_counter() if self.recorder.enabled else 0.0
            with self.trace.span("engine", "prefill", kind="compute",
                                 rid=req.rid, bucket=bucket):
                logits, pcache, bh, hlast = self._prefill_fn(bucket)(
                    self.params, jnp.asarray(toks[None]),
                    jnp.int32(real - 1))
            if self.recorder.enabled:
                self.recorder.event(req.rid, "prefill", bucket=bucket,
                                    dur=time.perf_counter() - tr)
            self.kvc.attach(slot, layout,
                            pcache["k"][:, 0, :real],
                            pcache["v"][:, 0, :real])
            if self._prefix_skip:
                self.kvc.store_hidden_prefill(slot, real,
                                              np.asarray(bh[0]),
                                              np.asarray(hlast[0]))
            first = self._sample(logits[0], req, len(item["gen"]))
            now = time.perf_counter()
            self.active[slot] = {
                "req": req, "tokens": item["gen"] + [int(first)],
                "prefill_s": now - t0,
                "t0": now,
                "seq": next(self._seq),
                "preempts": item["preempts"],
                "admit_step": len(self.counters),
                **self._latency_state(item, now),
            }
            self._first_token(self.active[slot], now)
            if self._stopped(req, self.active[slot]["tokens"]):
                self._finish(self.active.pop(slot))
                self.kvc.release(slot)
                self.free_slots.append(slot)

    # -- inter-shard page migration (DESIGN.md §4c) -------------------
    def _maybe_rebalance(self) -> None:
        """Between steps: migrate pages when per-shard occupancy has
        drifted past the tolerance (block tables are refreshed, so the
        next gather resolves the moved rows — outputs are unchanged,
        which the migration-parity tests assert)."""
        if self.kvc.pool.n_shards > 1 and self._rebalance_tol >= 1:
            with self.trace.span("engine", "rebalance", kind="sched"):
                self.kvc.maybe_rebalance(self._rebalance_tol)

    def force_migrate(self) -> int:
        """Operational drill (and test hook): rotate every movable
        page to the next shard between steps.  Returns pages moved.
        Greedy outputs must be token-identical before and after — the
        AGAS promise that a page's global name survives the move."""
        moves = self.kvc.pool.plan_rotation()
        return self.kvc.migrate(moves) if moves else 0

    # -- locality failure and elastic membership (DESIGN.md §4g) ------
    def _check_failure_plan(self) -> None:
        """Poll the failure plan at the top of the step: a scheduled
        locality death fires here, through the same recovery path an
        operator drill (`kill_locality`) takes.  Idempotent per
        (step, shard) pair, so an engine that polls twice in one step
        (the disagg override) fires each kill exactly once."""
        if self.failure_plan is None:
            return
        shard = self.failure_plan.shard_to_kill(len(self.counters),
                                                self._killed)
        if shard is not None:
            self.kill_locality(shard)

    def kill_locality(self, locality: int) -> dict:
        """Lose one KV shard with requests in flight and keep every
        one of them alive (DESIGN.md §4g).

        The pool sweep retires the locality, rebuilds every page a
        host-tier copy covers, and returns the rest as LOST.  The
        drain pass here then walks every holder of a lost page —
        active slots, staged handoff snapshots, offloaded queue
        items — and re-admits the affected requests at the queue
        FRONT with their generated tokens retained: their completion
        futures stay pending and resolve with token-identical output
        after re-prefill (position-normalized layouts make the replay
        exact).  Spends one restart from the recovery budget — a
        fleet that keeps losing shards crashes loudly instead of
        thrashing forever."""
        self.recovery_budget.spend(f"locality {locality} loss")
        kvc = self.kvc
        lost = kvc.pool.kill_locality(locality)
        handoff_queue = getattr(self, "handoff_queue", None)
        drained: List[int] = []
        for slot in sorted(self.active):
            st = self.active[slot]
            snap = st.get("snap")
            if snap is not None:          # staged handoff (§4f)
                if not any(a.gid in lost for a in snap.addrs):
                    continue
                st.pop("snap")
                st.pop("next_phase", None)
                st.pop("handoff_step", None)
                if handoff_queue is not None:
                    handoff_queue.pop(("handoff", st["req"].rid))
                kvc.drop_snapshot(snap, lost)
                # detach already emptied the slot's table; nothing to
                # drain beyond the snapshot's refcounts
            else:
                if not any(a.gid in lost
                           for a in kvc._state[slot].addrs):
                    continue
                kvc.drain_slot(slot, lost)
            drained.append(slot)
        items = []
        for slot in sorted(drained,
                           key=lambda s: self.active[s]["seq"]):
            st = self.active.pop(slot)
            self.free_slots.append(slot)
            if self.recorder.enabled:
                self.recorder.event(st["req"].rid, "drain", slot=slot,
                                    locality=locality)
            items.append({"req": st["req"], "gen": list(st["tokens"]),
                          "preempts": st.get("preempts", 0),
                          "snap": None,
                          "prefill_s": st.get("prefill_s", 0.0),
                          "t_submit": st["t_submit"],
                          "ttft_s": st.get("ttft_s"),
                          "tok_t": st.get("tok_t", [])})
        self.queue[:0] = items            # FRONT, admission order kept
        # offloaded queue items whose snapshot lost a device-resident
        # shared page: drop the snapshot (and any staged restore) so
        # re-admission takes the re-prefill path instead of restoring
        # through a dangling name
        broken_snaps = 0
        xfer = getattr(kvc.pool, "xfer", None)
        for item in self.queue:
            snap = item.get("snap")
            if snap is None or \
                    not any(a.gid in lost for a in snap.addrs):
                continue
            if xfer is not None:
                xfer.drop(("restore", item["req"].rid))
            kvc.drop_snapshot(snap, lost)
            item["snap"] = None
            item.pop("resume", None)
            broken_snaps += 1
        # rebuilt pages moved shards: one directory walk re-resolves
        # every surviving slot's block table
        kvc.refresh_tables()
        self.drained_slots += len(items)
        self.re_prefills += len(items) + broken_snaps
        self.trace.instant("engine", "kill_locality",
                           locality=locality, lost=len(lost),
                           drained=len(items),
                           broken_snaps=broken_snaps)
        return {"locality": locality, "lost": len(lost),
                "drained": len(items), "broken_snaps": broken_snaps}

    def retire_locality(self, locality: int) -> int:
        """Planned elastic retire: evacuate every resident page to the
        surviving active shards (one migration — global names
        unchanged, so requests never notice) and remove the locality
        from placement.  Returns pages moved; raises `PageExhausted`
        (locality left active, nothing committed) when the survivors
        cannot hold its residents."""
        pool = self.kvc.pool
        if not pool.agas.is_active(locality):
            return 0
        pool.agas.deactivate(locality)
        try:
            moves = pool.plan_evacuation(locality)
        except PageExhausted:
            pool.agas.activate(locality)
            raise
        moved = self.kvc.migrate(moves) if moves else 0
        self.trace.instant("engine", "retire_locality",
                           locality=locality, moved=moved)
        return moved

    def join_locality(self, locality: int) -> int:
        """Elastic join (or re-join after a kill/retire): re-admit the
        locality to placement and rebalance movable pages toward it.
        Returns pages moved."""
        pool = self.kvc.pool
        pool.agas.activate(locality)
        moves = pool.plan_rebalance(1)
        moved = self.kvc.migrate(moves) if moves else 0
        self.trace.instant("engine", "join_locality",
                           locality=locality, moved=moved)
        return moved

    # -- percolation: offload / restore / prefetch (DESIGN.md §4d) ----
    def _try_restore(self, item: dict) -> bool:
        """Re-admit an offloaded request by promoting its written-back
        pages — KV restored byte-for-byte, no re-prefill.  False means
        the device tier cannot hold it yet (head-of-line blocking,
        exactly like a page-gated fresh admission)."""
        snap = item["snap"]
        req = item["req"]
        need = self.kvc.restore_pages_needed(snap) + 1
        if need + self._upcoming_allocs() > self.kvc.pool.free_pages:
            return False
        self.queue.pop(0)
        slot = self.free_slots.pop(0)
        self._slot_bind(req.rid, slot)
        tr = time.perf_counter() if self.recorder.enabled else 0.0
        try:
            with self.trace.span("engine", "restore", kind="sched",
                                 rid=req.rid, slot=slot):
                self.kvc.restore_slot(slot, snap,
                                      staged_key=("restore", req.rid))
        except PageExhausted:
            # the free-page estimate raced a pinned page; the snapshot
            # is still consistent — put everything back and wait.  The
            # failed attempt still burned TTFT-window time (and left a
            # restore span), so the flight timeline keeps it too
            if self.recorder.enabled:
                self.recorder.event(req.rid, "restore", ran=False,
                                    dur=time.perf_counter() - tr)
            self.free_slots.append(slot)
            self.queue.insert(0, item)
            return False
        self.restores += 1
        now = time.perf_counter()
        if self.recorder.enabled:
            self.recorder.event(req.rid, "restore", t=now,
                                dur=now - tr)
        st = {
            "req": req, "tokens": list(item["gen"]),
            "phase": "decode",      # overridden for mid-prefill below
            "prefill_s": item.get("prefill_s", 0.0),
            "t0": now,
            "seq": next(self._seq),
            "preempts": item["preempts"],
            "admit_step": len(self.counters),
            **self._latency_state(item, now),
        }
        resume = item.get("resume")
        if resume is not None:          # offloaded mid-prefill: keep
            st.update(phase="prefill",  # chunking where it stopped
                      layout=resume["layout"], real=resume["real"],
                      pos=resume["pos"], n_gen0=len(item["gen"]))
        self.active[slot] = st
        return True

    def _drop_item_kv(self, item: dict) -> None:
        snap = item.get("snap")
        if snap is not None:
            pool = self.kvc.pool
            pool.xfer.drop(("restore", item["req"].rid))
            self.kvc.drop_snapshot(snap)
            item["snap"] = None

    def _prefetch_percolation(self) -> None:
        """Stage the next admissions' host->device copies NOW, so they
        run while this step's batch computes (the §4d overlap model:
        `jax.device_put` dispatches asynchronously; the double buffer
        caps how far ahead the prefetcher works)."""
        if not self._tiering:
            return
        for item in self.queue[:2]:
            snap = item.get("snap")
            if snap is not None and \
                    self.kvc.restore_pages_needed(snap):
                self.kvc.stage_restore(("restore", item["req"].rid),
                                       snap)

    def force_demote(self) -> int:
        """Forced-eviction drill (and test hook): demote every
        evictable cold device page to host between steps.  Everything
        still decoding must be token-identical afterwards — evictable
        pages are refcount-0 by construction (refcount pinning)."""
        pool = self.kvc.pool
        if not getattr(pool, "tiered", False):
            return 0
        moved = pool.demote_all_cold()
        return moved

    # -- preemption under page pressure -------------------------------
    def _preempt(self, slot: int) -> None:
        """Evict a request: requeue it with its progress.  With
        tiering on, its pages
        are written back to the host tier (`KVSnapshot` in the queue
        item) so re-admission restores the KV instead of re-running
        prefill; otherwise — or when the host tier is full — they are
        freed and re-admission reconstructs the identical context
        layout by re-prefilling."""
        st = self.active.pop(slot)
        snap = self.kvc.offload_slot(slot) if self._tiering else None
        if snap is None:
            self.kvc.release(slot)
        else:
            self.offloads += 1
        self.free_slots.append(slot)
        self.preemptions += 1
        self.trace.instant("engine", "preempt", rid=st["req"].rid,
                           slot=slot, offloaded=snap is not None)
        if self.recorder.enabled:
            self.recorder.event(st["req"].rid, "preempt", slot=slot,
                                offloaded=snap is not None)
        item = {"req": st["req"], "gen": st["tokens"],
                "preempts": st["preempts"] + 1,
                "snap": snap,
                "prefill_s": st.get("prefill_s", 0.0),
                "t_submit": st["t_submit"],
                "ttft_s": st.get("ttft_s"),
                "tok_t": st.get("tok_t", [])}
        if snap is not None and st.get("phase") == "prefill":
            item["resume"] = {"layout": st["layout"],
                              "real": st["real"], "pos": st["pos"]}
        if snap is None:
            # pages forfeited: re-prefill is the costly path, so the
            # victim goes back to the queue FRONT and reclaims its
            # context at the first opportunity
            self.queue.insert(0, item)
        else:
            # KV written back: preemption is cheap now, so the victim
            # yields to fresh admissions (their first token is the
            # latency that matters; this one's restore is one staged
            # copy away whenever capacity returns) — the percolation
            # dividend: many more requests stay concurrently resident
            # than the device tier alone could hold
            self.queue.append(item)

    def _decode_slots(self) -> List[int]:
        """Slots currently in the decode phase (every active slot for
        the whole-prompt engine; the chunked engine overlays a prefill
        phase whose slots ride the decode batch as masked passengers)."""
        return [s for s in self.active
                if self.active[s].get("phase", "decode") == "decode"]

    def _prepare_writes(self, slots: Optional[List[int]] = None) -> None:
        """Reserve every decoding slot's write page, preempting the
        youngest request (LIFO — the oldest keeps its pages, so the
        system always drains) until the pool fits.  A lone request the
        pool cannot hold is failed via its LCO, not the engine."""
        while True:
            try:
                todo = [s for s in slots if s in self.active] \
                    if slots is not None else self._decode_slots()
                for slot in sorted(todo,
                                   key=lambda s: self.active[s]["seq"]):
                    self.kvc.prepare_decode(slot)
                return
            except PageExhausted:
                if len(self.active) <= 1:
                    slot, st = next(iter(self.active.items()))
                    self.active.pop(slot)
                    self.kvc.release(slot)
                    self.free_slots.append(slot)
                    self._reject({"req": st["req"]}, RuntimeError(
                        "page pool too small for request "
                        f"{st['req'].rid}: {self.kvc.pool.capacity} "
                        f"pages of {self.kvc.pool.page_size}"))
                    return
                victim = max(self.active,
                             key=lambda s: self.active[s]["seq"])
                self._preempt(victim)

    # -- the decode work-queue ----------------------------------------
    def _decode_batch(self, slots: List[int]) -> List[int]:
        """One compiled decode step for `slots`: assemble the batch,
        sample each slot's next token, finish/release requests that hit
        EOS or their length cap.  Returns the finished slots.  Shared
        by the whole-prompt and chunked engines, so sampling and
        completion bookkeeping can never diverge between them."""
        if not self.trace.enabled:
            return self._decode_batch_impl(slots)
        with self.trace.span("engine", "decode_batch", kind="compute",
                             n=len(slots)) as sp:
            done = self._decode_batch_impl(slots)
            sp.args["finished"] = len(done)
        return done

    def _decode_batch_impl(self, slots: List[int]) -> List[int]:
        tokens = np.zeros((self.slots, 1), np.int32)
        for slot in slots:
            tokens[slot, 0] = self.active[slot]["tokens"][-1]
        batch = {"tokens": jnp.asarray(tokens),
                 **self.kvc.batch_inputs()}
        logits, pages = self._decode(self.params, self.kvc.pool.pages,
                                     batch)
        self.kvc.pool.pages = pages
        done: List[int] = []
        now = time.perf_counter()
        for slot in slots:
            st = self.active[slot]
            self.kvc.advance(slot)
            req = st["req"]
            tok = self._sample(logits[slot], req, len(st["tokens"]))
            st["tokens"].append(tok)
            st["tok_t"].append(now)
            if self._stopped(req, st["tokens"]):
                done.append(slot)
        for slot in done:
            self._finish(self.active.pop(slot))
            self.kvc.release(slot)
            self.free_slots.append(slot)
        return done

    def _offloaded_queued(self) -> int:
        """Queued requests whose KV is resident in the host tier."""
        return sum(1 for it in self.queue
                   if it.get("snap") is not None)

    def _step(self) -> int:
        """One batched decode step over all active slots."""
        self._check_failure_plan()         # scheduled locality loss
        self._maybe_rebalance()            # between-steps migration
        with self.trace.span("engine", "admit", kind="sched"):
            self._admit()
        # stage the next admissions' host->device copies: they run
        # under this step's compute (percolation, DESIGN.md §4d)
        with self.trace.span("engine", "prefetch", kind="parcel"):
            self._prefetch_percolation()
        # truncate requests whose next token has no cache room left
        # (bucket + generated reached max_len) instead of overflowing
        for slot in [s for s in self.active
                     if self.kvc.lengths[s] >= self.max_len]:
            self._finish(self.active.pop(slot))
            self.kvc.release(slot)
            self.free_slots.append(slot)
        if not self.active:
            return 0
        with self.trace.span("engine", "prepare_writes", kind="pages"):
            self._prepare_writes()
        if not self.active:                    # lone request rejected
            return 0
        t0 = time.perf_counter()
        done = self._decode_batch(list(self.active))
        pool = self.kvc.pool
        self.counters.append({
            "t": time.perf_counter(),
            "queue_depth": len(self.queue),
            "active": len(self.active) + len(done),
            # concurrently RESIDENT requests: decoding slots plus
            # offloaded requests whose KV survives in the host tier —
            # the capacity the tiered pool grows beyond HBM
            "resident": len(self.active) + len(done)
            + self._offloaded_queued(),
            "pages_used": pool.used_pages,
            "page_occupancy": pool.occupancy(),
            "preemptions": self.preemptions,
            "decode_ms": (time.perf_counter() - t0) * 1e3,
        })
        self._record_step_metrics(self.counters[-1])
        return len(self.active) + len(done)

    def stats(self) -> dict:
        """Aggregate telemetry assembled from the metrics registry
        (the Fig 9 overhead view).  Step aggregates stream into the
        registry at step time and latency percentiles come from
        bounded streaming histograms recorded at completion time — no
        per-completion list is ever scanned here, so memory stays
        O(buckets) over arbitrarily long runs.  Safe to call at any
        point in the engine's life: before the first completion every
        aggregate degrades to 0.0.  Keys are the legacy names the
        serve_bench JSON and the dashboards read; the namespaced view
        is `engine.metrics.snapshot()`."""
        m = self.metrics
        pool = self.kvc.pool
        # mirror the pool's namespaced counters into the registry so
        # one snapshot() covers every subsystem the engine owns
        for name, v in pool.metrics().items():
            if isinstance(v, (int, float)):
                m.gauge(name).set(v)
        m.counter("engine.preemptions").value = self.preemptions
        m.counter("engine.re_prefills").value = self.re_prefills
        m.counter("engine.drained_slots").value = self.drained_slots
        m.counter("engine.prefix_skips").value = self.prefix_skips
        m.counter("engine.prefix_partial_hits").value = \
            self.prefix_partial_hits
        m.counter("engine.prefill_tokens_skipped").value = \
            self.prefill_tokens_skipped
        ttft = m.histogram("engine.ttft_ms")
        itl = m.histogram("engine.itl_ms")
        out = {
            "steps": int(m.counter("engine.steps").value),
            "peak_active": int(m.gauge("engine.peak_active").value),
            "peak_resident": int(m.gauge("engine.peak_resident").value),
            "mean_resident": m.histogram("engine.resident").mean,
            "peak_page_occupancy": float(
                m.gauge("engine.peak_page_occupancy").value),
            "mean_decode_ms": m.histogram("engine.decode_ms").mean,
            "preemptions": self.preemptions,
            "page_allocs": pool.allocs,
            "page_shares": pool.shares,
            "cow_copies": pool.cow_copies,
            # sharded-pool telemetry (length-1 lists on a single
            # locality, so dashboards need no special case)
            "kv_shards": pool.n_shards,
            "shard_pages_used": pool.shard_used(),
            "shard_occupancy": pool.shard_occupancy(),
            "page_migrations": pool.page_migrations,
            "mean_prefill_ms": m.histogram("engine.prefill_ms").mean,
            # latency split the chunked scheduler is judged on:
            # time-to-first-token vs steady-state inter-token gaps
            "mean_ttft_ms": ttft.mean,
            "ttft_p50_ms": ttft.quantile(50.0),
            "ttft_p95_ms": ttft.quantile(95.0),
            "ttft_p99_ms": ttft.quantile(99.0),
            "mean_itl_ms": itl.mean,
            "itl_p50_ms": itl.quantile(50.0),
            "itl_p95_ms": itl.quantile(95.0),
            "itl_p99_ms": itl.quantile(99.0),
            # prefix-cache compute skip (DESIGN.md §4e): covered
            # admissions (full skips vs partial radix hits) and the
            # prompt tokens never recomputed
            "prefix_cache_compute": self._prefix_skip,
            "prefix_skips": self.prefix_skips,
            "prefix_partial_hits": self.prefix_partial_hits,
            "prefill_tokens_skipped": self.prefill_tokens_skipped,
        }
        # locality-loss recovery (DESIGN.md §4g): what a kill swept,
        # what a host-tier copy rebuilt, what had to re-prefill
        out["recovery"] = {
            "localities_killed": pool.localities_killed,
            "pages_rebuilt": pool.pages_rebuilt,
            "pages_lost": pool.pages_lost,
            "re_prefills": self.re_prefills,
            "drained_slots": self.drained_slots,
            "recovery_restarts": self.recovery_budget.restarts,
        }
        # two-tier percolation telemetry (DESIGN.md §4d): offload /
        # promote traffic, prefetch overlap, write-back effectiveness
        out["tiering"] = bool(getattr(pool, "tiered", False))
        if out["tiering"]:
            out["offloads"] = self.offloads
            out["restores"] = self.restores
            out.update(pool.tier_stats())
        # SLO/goodput (obs/slo.py): only when any request carried a
        # deadline — the registry counters exist iff classify() ran
        tracked = m.get("slo.requests")
        if tracked is not None and tracked.value:
            from repro.obs.slo import BLAME_PHASES
            snap = m.snapshot()
            out["slo"] = {
                "requests": int(tracked.value),
                "met": int(snap.get("slo.met", 0)),
                "goodput": float(snap.get("slo.goodput", 0.0)),
                "ttft_misses": int(snap.get("slo.ttft_misses", 0)),
                "itl_misses": int(snap.get("slo.itl_misses", 0)),
                "blame": {p: int(snap.get(f"slo.blame.{p}", 0))
                          for p in BLAME_PHASES + ("unattributed",)},
            }
        return out


class ChunkedPagedServingEngine(PagedServingEngine):
    """Chunked prefill under a token-budget step scheduler.

    The serving grain is a page-size-aligned CHUNK of a prompt
    (DESIGN.md §4b): every `step()` spends at most `step_tokens`
    tokens — one per decoding slot first (decode priority), pending
    prefill chunks filling the remainder in admission order.  A long
    admission therefore never stalls the decode batch for its whole
    prefill, and a short prompt's first token stops waiting behind a
    long prompt's.  Admission is gated on the FIRST chunk's pages
    (plus headroom), not the whole prompt: later chunks allocate as
    they run, and page exhaustion mid-prefill preempts LIFO exactly
    like exhaustion mid-decode (the preempted request re-enters the
    queue and re-prefills from scratch on re-admission — deterministic,
    since an identical pad-free layout reproduces identical pages).

    With ``prefix_cache_compute=True`` (DESIGN.md §4e) admission first
    measures the prompt's covered prefix: fully-covered prompts skip
    prefill entirely (first token off the cached activation
    checkpoint), and partially-covered ones attach the cached pages
    by refcount and start chunking at the cover's end — the step
    budget is charged only for uncovered tokens, so a warm
    shared-system-prompt wave prefills at a fraction of its cold cost
    (`serve_bench --prefix-heavy` measures the TTFT dividend).
    """

    def __init__(self, params: Any, cfg: ArchConfig, *, slots: int = 4,
                 max_len: int = 512, prefill_buckets=(64, 128, 256),
                 page_size: int = 16, n_pages: Optional[int] = None,
                 chunk_size: Optional[int] = None,
                 step_tokens: Optional[int] = None,
                 kv_shards: int = 1, mesh=None,
                 rebalance_tolerance: Optional[int] = None,
                 tiering: bool = False, host_pages: int = 0,
                 prefix_cache_compute: bool = False,
                 pin_threshold: int = 4, tracer=None,
                 flight_recorder=False,
                 failure_plan: Optional[FailurePlan] = None):
        super().__init__(params, cfg, slots=slots, max_len=max_len,
                         prefill_buckets=prefill_buckets,
                         page_size=page_size, n_pages=n_pages,
                         kv_shards=kv_shards, mesh=mesh,
                         rebalance_tolerance=rebalance_tolerance,
                         tiering=tiering, host_pages=host_pages,
                         prefix_cache_compute=prefix_cache_compute,
                         pin_threshold=pin_threshold,
                         tracer=tracer,
                         flight_recorder=flight_recorder,
                         failure_plan=failure_plan)
        if chunk_size is None:
            chunk_size = 2 * page_size
        if chunk_size <= 0 or chunk_size % page_size:
            raise ValueError(
                f"chunk_size {chunk_size} must be a positive multiple "
                f"of page_size {page_size}")
        self.chunk_size = int(chunk_size)
        # every decoding slot gets its token, and at least one full
        # chunk always fits in the remainder-free case
        self.step_tokens = int(step_tokens or (slots + chunk_size))
        if self.step_tokens < self.chunk_size:
            raise ValueError(
                f"step_tokens {self.step_tokens} must cover at least "
                f"one chunk of {self.chunk_size}")
        # ONE compiled chunk step (fixed chunk width; the true last
        # position and start offset are traced operands).  Besides the
        # logits it returns the post-norm hidden at the true last
        # position and at every page boundary — the activation
        # checkpoints the prefix index stores for compute skip (§4e)
        ps = page_size

        def chunk_fn(p, pages, toks, tables, start, rows, last):
            x, pages = T.prefill_chunk(p, pages, {
                "tokens": toks, "block_tables": tables, "start": start,
                "chunk_rows": rows, "last_index": last}, cfg,
                all_hidden=True)
            out = jax.lax.dynamic_index_in_dim(x, last, axis=1,
                                               keepdims=False)
            return T.logits_fn(p, out), out, x[:, ps - 1::ps], pages
        self._chunk_step = jax.jit(chunk_fn, donate_argnums=(1,))
        # the role composition (DESIGN.md §4f): a role-agnostic token-
        # budget scheduler drives a prefill role and a decode role.
        # This engine is the single-locality composition — both roles
        # run where the engine runs; the disaggregated engine swaps in
        # parcel-dispatched workers without touching the scheduler.
        self._sched = StepScheduler(self.step_tokens, self.chunk_size,
                                    page_size)
        self._prefill_role = PrefillWorker()
        self._decode_role = DecodeWorker()

    # -- admission: gated on the first chunk, not the whole prompt ----
    def _upcoming_allocs(self) -> int:
        """The watermark counts EVERY allocation already committed for
        this step: decode writes at a page boundary/COW, AND the pages
        each mid-prefill slot's next chunk will take — prefill chunks
        run right after admission, so ignoring them (a decode-only
        count) would let an admission be preempted away in the very
        same step."""
        upcoming = sum(1 for s in self._decode_slots()
                       if self.kvc.needs_alloc(s))
        for s, st in self.active.items():
            if st.get("phase") == "prefill":
                nxt = min(st["pos"] + self.chunk_size, st["real"])
                upcoming += self.kvc.pages_needed_chunk(
                    st["layout"], st["pos"], nxt)
        return upcoming

    def _admit(self) -> None:
        while self.queue and self.free_slots:
            item = self.queue[0]
            req = item["req"]
            if item.get("snap") is not None:
                if self._try_restore(item):
                    continue
                break                          # head-of-line blocking
            adm = self._admission_layout(item)
            if adm is None:
                continue
            layout, real, _ = adm
            # compute skip (§4e): a fully-covered prompt admits
            # straight to decode off its cached checkpoint; a partial
            # cover starts chunking at the cover's end, charging only
            # uncovered tokens against the step budget
            start = 0
            cov = None
            if self._prefix_skip:
                cov = self.kvc.covered_prefix(layout)
                if cov.full:
                    if self._admit_skip(item, layout, real, cov):
                        continue
                    break                      # head-of-line blocking
                start = cov.covered
            # gate on the first UNCOVERED chunk plus one page of
            # headroom (and the watermark), plus any device rows the
            # covered pages' promotions will take; later chunks
            # allocate as they are scheduled and preempt under pressure
            first_end = min(start + self.chunk_size, real)
            upcoming = self._upcoming_allocs()
            need = self.kvc.pages_needed_chunk(layout, start,
                                               first_end) + 1
            if cov is not None:
                need += sum(self.kvc.pool.page_cost(k)
                            for k in cov.keys)
            if need + upcoming > self.kvc.pool.free_pages:
                break                          # head-of-line blocking
            self.queue.pop(0)
            slot = self.free_slots.pop(0)
            self._slot_bind(req.rid, slot)
            if start:
                try:
                    self.kvc.attach_covered(slot, layout, cov.keys)
                except PageExhausted:
                    # a covered page's promotion lost its device row;
                    # rolled back — retry from the queue head later
                    self.free_slots.append(slot)
                    self.queue.insert(0, item)
                    break
                self.prefix_partial_hits += 1
                self.prefill_tokens_skipped += start
            now = time.perf_counter()
            self.active[slot] = {
                "req": req, "tokens": list(item["gen"]),
                "phase": "prefill",
                "layout": layout, "real": real, "pos": start,
                "prefill_s": 0.0,
                "t0": now,                      # reset at first token
                "seq": next(self._seq),
                "preempts": item["preempts"],
                "n_gen0": len(item["gen"]),
                "admit_step": len(self.counters),
                **self._latency_state(item, now),
            }

    def _prefetch_percolation(self) -> None:
        """Chunked engines also percolate spilled PREFIX pages ahead
        of the chunk that will share them: stage this chunk's and the
        next chunk's host-resident prefix hits, so by the time
        `begin_chunk` resolves them the copy has been running under
        decode compute."""
        super()._prefetch_percolation()
        if not self._tiering:
            return
        for s, st in self.active.items():
            if st.get("phase") == "prefill":
                end = min(st["pos"] + 2 * self.chunk_size, st["real"])
                self.kvc.prefetch_chunk(s, st["layout"], st["pos"],
                                        end)

    def _chunk_locality(self, slot: int, st: dict) -> Optional[int]:
        """Placement hint for a chunk's fresh page allocations — None
        keeps the pool's default policy.  The disaggregated engine
        returns the chunk's parcel-dispatch locality, so a prefill
        worker's chunks allocate their pages where the worker runs
        (DESIGN.md §4f)."""
        return None

    # -- one prefill chunk as a schedulable task ----------------------
    def _run_chunk(self, slot: int, take: int) -> bool:
        """Acquire pages for and run one chunk of `slot`'s prompt.
        Returns False if the slot was preempted (or rejected) by page
        exhaustion instead of advanced."""
        rec = self.recorder.enabled
        if not self.trace.enabled and not rec:
            return self._run_chunk_impl(slot, take)
        st = self.active[slot]
        rid = st["req"].rid
        start = st["pos"]
        tr = time.perf_counter() if rec else 0.0
        if not self.trace.enabled:
            ok = self._run_chunk_impl(slot, take)
        else:
            with self.trace.span("engine", "prefill_chunk",
                                 kind="compute", rid=rid, slot=slot,
                                 start=start, take=take,
                                 loc=self._chunk_locality(slot, st)) \
                    as sp:
                ok = self._run_chunk_impl(slot, take)
                sp.args["ran"] = ok
        if rec:
            self.recorder.event(rid, "prefill_chunk", start=start,
                                take=take, ran=ok,
                                dur=time.perf_counter() - tr)
        return ok

    def _run_chunk_impl(self, slot: int, take: int) -> bool:
        st = self.active[slot]
        start = st["pos"]
        end = start + take
        while True:
            try:
                rows, _ = self.kvc.begin_chunk(
                    slot, st["layout"], start, end,
                    locality=self._chunk_locality(slot, st))
                break
            except PageExhausted:
                if len(self.active) <= 1:
                    self.active.pop(slot)
                    self.kvc.release(slot)
                    self.free_slots.append(slot)
                    self._reject({"req": st["req"]}, RuntimeError(
                        "page pool too small for request "
                        f"{st['req'].rid}: {self.kvc.pool.capacity} "
                        f"pages of {self.kvc.pool.page_size}"))
                    return False
                victim = max(self.active,
                             key=lambda s: self.active[s]["seq"])
                self._preempt(victim)
                if victim == slot:
                    return False
        ps = self.kvc.pool.page_size
        t0 = time.perf_counter()
        toks = np.zeros(self.chunk_size, np.int32)
        toks[:take] = st["layout"][start:end]
        rows_arr = np.full(self.chunk_size // ps,
                           self.kvc.pool.null_row, np.int32)
        rows_arr[:len(rows)] = rows
        logits, hlast, bh, pages = self._chunk_step(
            self.params, self.kvc.pool.pages,
            jnp.asarray(toks[None]),
            jnp.asarray(self.kvc.tables[slot][None]),
            jnp.asarray([start], jnp.int32),
            jnp.asarray(rows_arr[None]),
            jnp.int32(take - 1))
        self.kvc.pool.pages = pages
        if self._prefix_skip:
            # checkpoint the chunk's page-boundary activations into
            # the prefix index (one small host copy) — later identical
            # prefixes resume from them instead of recomputing (§4e)
            self.kvc.store_hidden_chunk(slot, start, end,
                                        np.asarray(bh[0]),
                                        np.asarray(hlast[0]))
        st["pos"] = end
        st["prefill_s"] += time.perf_counter() - t0
        if end == st["real"]:
            self._finish_prefill(slot, st, logits)
        return True

    def _finish_prefill(self, slot: int, st: dict, logits) -> None:
        """Final chunk landed: the prompt is resident — sample the
        first token and hand the slot to the decode batch.  The
        disaggregated engine overrides this seam to stage the
        prefill->decode KV handoff instead of flipping the phase in
        place (DESIGN.md §4f)."""
        now = time.perf_counter()
        st["phase"] = "decode"
        st["t0"] = now
        first = self._sample(logits[0], st["req"], st["n_gen0"])
        st["tokens"].append(int(first))
        self._first_token(st, now)
        if self._stopped(st["req"], st["tokens"]):
            self._finish(self.active.pop(slot))
            self.kvc.release(slot)
            self.free_slots.append(slot)

    # -- the token-budget step ----------------------------------------
    def _step(self) -> int:
        """One budgeted step: every decoding slot gets its token, and
        pending prefill chunks (FCFS by admission order) fill whatever
        budget remains.  A prompt whose final chunk lands this step
        samples its first token now but starts decoding next step, so
        the step never exceeds its token budget."""
        self._check_failure_plan()         # scheduled locality loss
        self._maybe_rebalance()            # between-steps migration
        with self.trace.span("engine", "admit", kind="sched"):
            self._admit()
        with self.trace.span("engine", "prefetch", kind="parcel"):
            self._prefetch_percolation()
        # truncate decoding requests whose next token has no cache room
        for slot in [s for s in self._decode_slots()
                     if self.kvc.lengths[s] >= self.max_len]:
            self._finish(self.active.pop(slot))
            self.kvc.release(slot)
            self.free_slots.append(slot)
        if not self.active:
            return 0
        # the token-budget loop and the decode batch are the role-
        # agnostic scheduler's job (serving/workers.py): decode
        # reservation first, FCFS prefill chunks in the remainder —
        # this engine plugs in the single-locality roles, the
        # disaggregated engine the parcel-dispatched ones
        done, decoding, n_chunks, prefill_tok, t0 = \
            self._sched.run_step(self, self._prefill_role,
                                 self._decode_role)
        pool = self.kvc.pool
        self.counters.append({
            "t": time.perf_counter(),
            "queue_depth": len(self.queue),
            "active": len(self.active) + len(done),
            "resident": len(self.active) + len(done)
            + self._offloaded_queued(),
            "pages_used": pool.used_pages,
            "page_occupancy": pool.occupancy(),
            "preemptions": self.preemptions,
            "decode_ms": (time.perf_counter() - t0) * 1e3,
            "prefill_chunks": n_chunks,
            "prefill_chunk_tokens": prefill_tok,
            "decode_tokens": len(decoding),
            "budget_tokens": self.step_tokens,
        })
        self._record_step_metrics(self.counters[-1])
        return len(self.active) + len(done)


class DisaggChunkedServingEngine(ChunkedPagedServingEngine):
    """Disaggregated prefill/decode over the chunked scheduler
    (DESIGN.md §4f).

    The step scheduler and its budget policy are inherited untouched;
    only the ROLES change.  Prefill chunks become `PrefillParcel`s
    dispatched through a `ParcelPort` to a prefill-worker locality —
    the locality owning the prompt's radix-matched prefix pages when
    the prompt is warm (move the work to the data: the shared pages
    never cross localities), least-loaded among the prefill workers
    when cold.  A finished prefill does not flip its slot to decode in
    place: its KV detaches into a snapshot, a `CopyParcel` is staged
    on the handoff percolation queue while the step's decode batch
    runs (the §4d double buffer), and the decode role commits the
    restore at the top of the next step — so the handoff copy
    overlaps decode compute instead of serializing before it.

    Because detach/restore round-trips the slot byte-identically
    (block table, position clock, chunk hash chain) and the scheduler
    is shared, this engine stays greedy token-identical to
    `ChunkedPagedServingEngine` — the differential fuzzer and
    serve_bench assert it.
    """

    def __init__(self, params: Any, cfg: ArchConfig, *,
                 prefill_workers: Optional[int] = None,
                 decode_workers: int = 1, **kwargs):
        super().__init__(params, cfg, **kwargs)
        n_loc = self.kvc.pool.n_shards
        self.prefill_workers = max(
            1, min(int(prefill_workers or n_loc), n_loc))
        self.decode_workers = max(1, min(int(decode_workers), n_loc))
        self._port = ParcelPort(self.kvc.pool.agas, PREFILL_ACTIONS)
        self._prefill_role = ParcelPrefillWorker(self.prefill_workers)
        self._decode_role = HandoffDecodeWorker()
        #: staged prefill->decode KV handoffs in flight (§4d machinery
        #: reused at the §4f role boundary; push/pop only — the
        #: demote/promote traffic counters belong to tiering)
        self.handoff_queue = PercolationQueue()
        self.handoffs = 0
        self.handoff_bytes = 0
        self.handoff_overlapped = 0
        self._last_chunk_ok = False

    # -- dispatch policy ----------------------------------------------
    def _dispatch_target(self, slot: int, st: dict):
        """(anchor, destination locality, warm) for a chunk parcel.

        A slot whose pages already live somewhere follows them (the
        anchor is its last page — sticky, so one prompt's chunks never
        scatter).  An unattached prompt's first chunk walks the radix
        prefix index stat-free (`lookup_prefix`, NOT `match` — match
        stamps hit stats and auto-pins, which would diverge from the
        single-locality engine) and dispatches to the deepest hit's
        owner; no hit places it on the least-loaded prefill worker.
        """
        pool = self.kvc.pool
        agas = pool.agas
        addrs = self.kvc._state[slot].addrs
        if addrs:
            anchor = addrs[-1]
            dst = agas.locality_of(anchor)
            if dst >= self.prefill_workers:   # host-tier resident
                dst = st.get("ploc", 0)
            if not agas.is_active(dst):       # cached target died (§4g)
                dst = self._cold_dispatch()
            st.setdefault("pwarm", True)      # attached covered pages
            st["ploc"] = dst
            st["panchor"] = anchor
            return anchor, dst, st["pwarm"]
        if "ploc" in st and agas.is_active(st["ploc"]):
            return st.get("panchor"), st["ploc"], st["pwarm"]
        anchor = None
        for key in page_keys(st["layout"], pool.page_size):
            hit = pool.lookup_prefix(key)
            if hit is None:
                break
            anchor = hit
        warm = anchor is not None \
            and agas.locality_of(anchor) < self.prefill_workers \
            and agas.is_active(agas.locality_of(anchor))
        if warm:
            dst = agas.locality_of(anchor)
        else:
            dst = self._cold_dispatch()
        st["ploc"] = dst
        st["panchor"] = anchor if warm else None
        st["pwarm"] = warm
        return st["panchor"], dst, warm

    def _cold_dispatch(self) -> int:
        """Least-loaded ACTIVE prefill worker, lowest locality on
        ties; when every worker shard is retired (§4g), any surviving
        active shard — a dead locality must never be a dispatch
        target, or its parcels' page allocations would raise."""
        agas = self.kvc.pool.agas
        cands = [l for l in range(self.prefill_workers)
                 if agas.is_active(l)]
        if not cands:
            cands = [l for l in range(self.kvc.pool.n_shards)
                     if agas.is_active(l)]
        return max(cands, key=lambda l: (agas.free_count(l), -l))

    def _home_locality(self, slot: int) -> int:
        """The decode locality a slot's handoff lands on (round-robin
        over the decode workers) — parcels dispatched elsewhere count
        as inter-locality sends."""
        return slot % self.decode_workers

    def _chunk_locality(self, slot: int, st: dict) -> Optional[int]:
        return st.get("ploc")

    # -- the prefill->decode handoff ----------------------------------
    def _finish_prefill(self, slot: int, st: dict, logits) -> None:
        """Final chunk landed at the prefill worker: sample the first
        token THERE (the logits die with the chunk), then stage the
        KV handoff to the decode role instead of flipping the phase in
        place."""
        now = time.perf_counter()
        st["t0"] = now
        first = self._sample(logits[0], st["req"], st["n_gen0"])
        st["tokens"].append(int(first))
        self._first_token(st, now)
        if self._stopped(st["req"], st["tokens"]):
            self._finish(self.active.pop(slot))
            self.kvc.release(slot)
            self.free_slots.append(slot)
            return
        self._stage_handoff(slot, st, next_phase="decode")

    def _stage_handoff(self, slot: int, st: dict,
                       next_phase: str) -> None:
        """Detach the slot's KV into a snapshot and stage its copy
        parcel.  The pages keep this slot's refcounts (they can never
        be evicted while staged), so the commit's restore is
        guaranteed to find them device-resident — the copy itself
        runs under whatever decode batch this step schedules."""
        pool = self.kvc.pool
        rid = st["req"].rid
        tr = time.perf_counter() if self.recorder.enabled else 0.0
        with self.trace.span("percolation", "handoff_stage",
                             kind="copy", rid=rid, slot=slot,
                             loc=self._home_locality(slot)):
            snap = self.kvc.detach_slot(slot)
            if snap is None:                  # empty slot: nothing to move
                st["phase"] = next_phase
                return
            st["snap"] = snap
            st["next_phase"] = next_phase
            st["phase"] = "handoff"
            st["handoff_step"] = len(self.counters)
            nbytes = len(snap.addrs) * pool.page_bytes() \
                + pool.hidden_nbytes(snap.addrs)
            self.handoff_queue.push(CopyParcel(
                ("handoff", rid), tuple(a.gid for a in snap.addrs),
                "handoff", nbytes))
        if self.recorder.enabled:
            self.recorder.event(rid, "handoff_stage", slot=slot,
                                nbytes=nbytes,
                                dur=time.perf_counter() - tr)

    def _commit_handoff(self, slot: int) -> None:
        """Land a staged handoff: restore the snapshot into the slot
        (on one locality, a table rebuild — the pages never moved; a
        multi-host port would commit its staged copy here) and hand
        the slot to its next phase."""
        st = self.active[slot]
        snap = st.pop("snap")
        parcel = self.handoff_queue.pop(("handoff", st["req"].rid))
        staged = st.pop("handoff_step", len(self.counters))
        # overlapped iff the staging step ran a decode batch under the
        # staged copy before this commit (the §4d double buffer)
        overlapped = len(self.counters) > staged \
            and self.counters[staged].get("decode_tokens", 0) > 0
        tr = time.perf_counter() if self.recorder.enabled else 0.0
        with self.trace.span("percolation", "handoff_commit",
                             kind="copy", rid=st["req"].rid, slot=slot,
                             gids=[a.gid for a in snap.addrs],
                             loc=self._home_locality(slot)):
            self.kvc.restore_slot(slot, snap)
        if self.recorder.enabled:
            self.recorder.event(st["req"].rid, "handoff_commit",
                                slot=slot, overlapped=overlapped,
                                dur=time.perf_counter() - tr)
        st["phase"] = st.pop("next_phase")
        self.handoffs += 1
        if parcel is not None:
            self.handoff_bytes += parcel.nbytes
        self.handoff_overlapped += int(overlapped)

    def force_handoff(self) -> int:
        """Drill: stage a MID-PREFILL handoff for every prefilling
        slot with resident pages (next phase: resume chunking where it
        left off).  Chunk boundaries are page-aligned, so the restored
        chain/position always satisfy `begin_chunk`'s resume contract.
        Returns the number of handoffs staged."""
        n = 0
        for slot, st in list(self.active.items()):
            if st.get("phase") == "prefill" \
                    and self.kvc._state[slot].addrs:
                self._stage_handoff(slot, st, next_phase="prefill")
                n += st.get("phase") == "handoff"
        return n

    # -- lifecycle seams the handoff phase must survive ---------------
    def _step(self) -> int:
        # the failure plan fires BEFORE staged handoffs commit: a
        # locality death takes in-flight handoff snapshots with it,
        # which is exactly the seam the chaos drill must exercise
        # (the second poll inside super()._step is idempotent)
        self._check_failure_plan()
        # commit staged handoffs FIRST: a prefill that finished in
        # step N decodes in step N+1, the same cadence the single-
        # locality engine has — with the copy already run under step
        # N's decode batch
        self._decode_role.commit_handoffs(self)
        return super()._step()

    def kill_locality(self, locality: int) -> dict:
        out = super().kill_locality(locality)
        # surviving prefill slots re-resolve their dispatch next
        # chunk: a cached target locality or anchor page may have
        # died with the shard
        for st in self.active.values():
            if st.get("phase") == "prefill":
                for k in ("ploc", "panchor", "pwarm"):
                    st.pop(k, None)
        return out

    def _preempt(self, slot: int) -> None:
        st = self.active.get(slot)
        if st is not None and st.get("phase") == "handoff":
            # land the handoff before evicting: the snapshot holds
            # page refcounts the offload/release path must see on the
            # slot, not dangling from the queue
            self._commit_handoff(slot)
        super()._preempt(slot)

    def _fail_pending(self, err: Exception) -> None:
        for slot, st in list(self.active.items()):
            if st.get("phase") == "handoff":
                snap = st.pop("snap", None)
                if snap is not None:
                    self.kvc.drop_snapshot(snap)
                self.handoff_queue.pop(("handoff", st["req"].rid))
                st["phase"] = st.pop("next_phase", "decode")
        super()._fail_pending(err)

    def stats(self) -> dict:
        out = super().stats()
        role = self._prefill_role
        total = role.parcels
        out.update({
            "disagg": True,
            "prefill_workers": self.prefill_workers,
            "decode_workers": self.decode_workers,
            # dispatch affinity: fraction of prefill parcels that ran
            # at the locality owning their prompt's prefix pages
            "prefill_parcels": total,
            "prefill_parcels_owner": role.owner_parcels,
            "prefill_parcels_cold": role.cold_parcels,
            "prefill_parcel_affinity":
                role.owner_parcels / total if total else 0.0,
            "prefill_parcels_inter_locality": role.inter_locality,
            "parcels_sent": self._port.sent,
            "parcels_local": self._port.local_applied,
            "dispatch_sizes": sorted(role.dispatch_sizes),
            # prefill->decode KV handoffs and their §4d overlap
            "handoffs": self.handoffs,
            "handoff_bytes": self.handoff_bytes,
            "handoff_overlap":
                self.handoff_overlapped / self.handoffs
                if self.handoffs else 0.0,
        })
        m = self.metrics
        m.counter("engine.prefill_parcels").value = total
        m.counter("engine.handoffs").value = self.handoffs
        m.counter("engine.handoff_bytes").value = self.handoff_bytes
        return out


#: The serving engine: chunked prefill over AGAS pages.
ServingEngine = ChunkedPagedServingEngine


def make_engine(params: Any, cfg: ArchConfig, *,
                engine: str = "chunked", disagg: bool = False,
                **kwargs) -> _EngineBase:
    """Engine factory.  `engine` selects the scheduler for
    attention-cache families: "chunked" (default — chunked prefill
    under a token budget), "paged" (whole-prompt prefill over AGAS
    pages), or "dense" (static slot-pool baseline).  ``disagg=True``
    upgrades the chunked engine to the disaggregated prefill/decode
    composition (DESIGN.md §4f; `prefill_workers`/`decode_workers`
    kwargs pick the role counts).  Families whose recurrent state has
    no paged layout (ssm/hybrid/vlm) always fall back to the dense
    engine."""
    if engine not in ("chunked", "paged", "dense"):
        raise ValueError(f"unknown engine {engine!r}")
    if disagg and engine != "chunked":
        raise ValueError(
            "disaggregated prefill/decode requires the chunked engine")
    if cfg.family in PAGED_FAMILIES and engine != "dense":
        if engine == "chunked":
            if disagg:
                return DisaggChunkedServingEngine(params, cfg, **kwargs)
            kwargs.pop("prefill_workers", None)
            kwargs.pop("decode_workers", None)
            return ChunkedPagedServingEngine(params, cfg, **kwargs)
        kwargs.pop("chunk_size", None)
        kwargs.pop("step_tokens", None)
        return PagedServingEngine(params, cfg, **kwargs)
    for k in ("page_size", "n_pages", "chunk_size", "step_tokens",
              "kv_shards", "mesh", "rebalance_tolerance", "tiering",
              "host_pages", "prefix_cache_compute", "pin_threshold",
              "prefill_workers", "decode_workers", "failure_plan"):
        kwargs.pop(k, None)
    return DenseServingEngine(params, cfg, **kwargs)
