"""Jitted public wrapper: (B, S, H, D) layout -> kernel layout."""

from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.attention.flash import flash_attention_bhsd
from repro.kernels.attention.paged import (paged_attention_bhd,
                                           paged_prefill_attention_btd)


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@partial(jax.jit,
         static_argnames=("causal", "window", "q_offset", "bq", "bk"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, bq: int = 128,
                    bk: int = 128) -> jnp.ndarray:
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D) (GQA without repetition).

    models/attention.attention() repeats kv before calling (it serves
    the jnp path too); the kernel undoes nothing — if KV == H the
    index map is identity, so both call patterns are valid.
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    n_rep = h // kvh
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, k.shape[1], d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, v.shape[1], d)
    out = flash_attention_bhsd(
        qf, kf, vf, causal=causal, window=window, q_offset=q_offset,
        n_rep=n_rep, bq=bq, bk=bk, interpret=_interpret_default())
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("window",))
def paged_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                    v_pages: jnp.ndarray, block_tables: jnp.ndarray,
                    positions: jnp.ndarray, *,
                    window: int = 0) -> jnp.ndarray:
    """q: (B, 1, H, D); k/v_pages: (N, ps, KV, D) (GQA without
    repetition) or (S, R, ps, KV, D) for a locality-sharded pool;
    block_tables: (B, P) physical page rows (``locality * R + slot``
    encoded when sharded); positions: (B,) per-slot absolute position
    of the token being decoded.  Same contract as
    kernels.attention.ref.paged_attention_ref."""
    out = paged_attention_bhd(
        q[:, 0], k_pages, v_pages, block_tables, positions,
        window=window, interpret=_interpret_default())
    return out[:, None]


@partial(jax.jit, static_argnames=("window",))
def paged_prefill_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                            v_pages: jnp.ndarray,
                            block_tables: jnp.ndarray,
                            start: jnp.ndarray, *,
                            window: int = 0) -> jnp.ndarray:
    """q: (B, T, H, D) chunk queries; k/v_pages: (N, ps, KV, D) (GQA
    without repetition) or (S, R, ps, KV, D) for a locality-sharded
    pool; block_tables: (B, P) physical page rows (``locality * R +
    slot`` encoded when sharded); start: (B,) absolute position of
    each chunk's first query.  Same contract as
    kernels.attention.ref.paged_prefill_attention_ref."""
    return paged_prefill_attention_btd(
        q, k_pages, v_pages, block_tables, start, window=window,
        interpret=_interpret_default())
