"""Runtime observability: causal tracing, metrics, overhead attribution.

Five layers (ISSUE 6 + ISSUE 9):

- ``trace``       ring-buffer tracer emitting typed spans/instants with
                  monotonic timestamps and causal ids (request -> slot ->
                  page chain -> parcel); Chrome trace-event JSON export.
- ``metrics``     unified registry of counters / gauges / streaming
                  histograms under a ``subsystem.metric`` namespace.
- ``attribution`` per-step wall-clock decomposition into kernel compute
                  vs runtime overhead (the paper's Fig. 9 analysis applied
                  online to serving), plus the per-role / per-locality
                  split for disaggregated serving.
- ``slo``         request-level lifecycle flight recorder and TTFT/ITL
                  deadline classification with per-phase blame.
- ``export``      Prometheus text exposition and JSONL interval
                  snapshots over the metrics registry.
"""

from repro.obs.trace import (  # noqa: F401
    NULL_TRACER,
    Tracer,
    get_global,
    set_global,
)
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    MetricsRegistry,
    StreamingHistogram,
)
from repro.obs.slo import (  # noqa: F401
    NULL_RECORDER,
    FlightRecorder,
    build_report,
    classify,
    derive_phases,
    record_verdict,
)
from repro.obs.export import (  # noqa: F401
    JsonlExporter,
    parse_prometheus,
    read_jsonl,
    to_prometheus,
    verify_roundtrip,
)
