"""Serving benchmark: chunked prefill vs whole-prompt paged vs dense.

Two comparisons, each on the trace it is valid for:

* dense vs paged (PR 1): a short single-bucket trace — the dense
  engine's one shared ``len/cursor/abs`` clock is only correct when
  every concurrent request shares a prefill bucket and the cursor
  never outruns ``max_len``, so the bulk-ownership baseline is
  measured inside its own validity envelope.  At equal peak KV bytes
  the paged engine runs more concurrent requests, because short
  requests only hold the pages they touched.
* whole-prompt vs chunked prefill (DESIGN.md §4b): a mixed short/long
  trace with the long prompts queued FIRST — the head-of-line shape
  chunked prefill exists to break.  At EQUAL page budget, splitting
  prefill into page-aligned chunks under a per-step token budget must
  hold p50 time-to-first-token strictly below the whole-prompt engine
  at a total-throughput cost within 10%.

``--kv-shards N`` additionally serves the mixed trace from a pool
sharded over N AGAS localities (DESIGN.md §4c) — device-backed when
the runtime has one device per shard (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` like
tests/test_distributed.py), simulated otherwise — with a forced
mid-trace page migration, and asserts the greedy outputs are
token-identical to the single-locality chunked engine.

Engines are warmed up (prefill buckets, the chunk step, and the decode
step compiled) on a throwaway trace before timing, so the latency
split reflects scheduling, not XLA compilation.

Emits the run.py ``name,us_per_call,derived`` CSV contract plus one
``# json {...}`` line (and ``--out FILE`` to persist the JSON).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import emit

ARCH = "yi-6b"

# -- dense vs paged (PR 1): short trace, one shared bucket -------------
SLOTS_DENSE = 4
DENSE_MAX_LEN = 96          # dense peak: 4 * 96 = 384 KV token rows
PAGE_SIZE = 16
DENSE_N_PAGES = SLOTS_DENSE * DENSE_MAX_LEN // PAGE_SIZE     # 24 pages
SLOTS_PAGED = 8             # paged runs 2x the decode width, same bytes

# -- whole-prompt vs chunked (this PR): mixed trace, equal pages -------
MIXED_MAX_LEN = 128
MIXED_N_PAGES = 32          # 512 KV token rows for both paged engines
CHUNK = 32
STEP_TOKENS = SLOTS_PAGED + 2 * CHUNK
N_SHORT = 14
N_LONG = 2
MAX_NEW = 16


def _short_requests(cfg, n, max_new=MAX_NEW, rid0=0, seed=0):
    rng = np.random.default_rng(seed)
    from repro.serving.engine import Request
    return [Request(rid0 + i, rng.integers(
        0, cfg.vocab_size, size=int(rng.integers(8, 30)))
        .astype(np.int32), max_new_tokens=max_new)
        for i in range(n)]


def _mixed_requests(cfg, n_short=N_SHORT, n_long=N_LONG,
                    max_new=MAX_NEW):
    """Long prompts FIRST, shorts queued behind them."""
    rng = np.random.default_rng(0)
    from repro.serving.engine import Request
    longs = [Request(rid, rng.integers(
        0, cfg.vocab_size, size=int(rng.integers(80, 96)))
        .astype(np.int32), max_new_tokens=max_new)
        for rid in range(n_long)]
    return longs + _short_requests(cfg, n_short, max_new=max_new,
                                   rid0=n_long, seed=1)


def _warmup(eng, cfg, lens):
    """Compile every executable the timed trace will hit, then wipe
    the engine's telemetry so timings reflect scheduling only."""
    from repro.serving.engine import Request
    rng = np.random.default_rng(2)
    for rid, n in enumerate(lens):
        eng.submit(Request(-1 - rid, rng.integers(
            0, cfg.vocab_size, size=n).astype(np.int32),
            max_new_tokens=4))
    eng.run_to_completion()
    eng.completions.clear()
    if hasattr(eng, "counters"):
        eng.counters.clear()
        eng.preemptions = 0
        pool = eng.kvc.pool
        pool.allocs = pool.shares = pool.cow_copies = 0


def _serve(eng, reqs):
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    new_tokens = sum(len(c.tokens) for c in eng.completions)
    assert len(eng.completions) == len(reqs)
    return dt, new_tokens


def _eng_stats(st, slots, tok, wall):
    return {"slots": slots, "tok_s": tok / wall, "wall_s": wall,
            "peak_active": st["peak_active"],
            "peak_page_occupancy": st["peak_page_occupancy"],
            "preemptions": st["preemptions"],
            "page_shares": st["page_shares"],
            "cow_copies": st["cow_copies"],
            "ttft_p50_ms": st["ttft_p50_ms"],
            "ttft_p95_ms": st["ttft_p95_ms"],
            "itl_p50_ms": st["itl_p50_ms"],
            "itl_p95_ms": st["itl_p95_ms"]}


def _serve_sharded(params, cfg, kw_mixed, warm_lens, mixed, kv_shards,
                   baseline_tokens):
    """Mixed trace over a kv_shards-locality pool + a forced mid-trace
    migration; greedy outputs must match the single-locality engine
    token for token (the AGAS name-stability promise, end to end)."""
    from repro.distributed.sharding import kv_pool_mesh
    from repro.serving.engine import make_engine

    mesh = kv_pool_mesh(kv_shards)
    eng = make_engine(params, cfg, engine="chunked", chunk_size=CHUNK,
                      step_tokens=STEP_TOKENS, kv_shards=kv_shards,
                      mesh=mesh, **kw_mixed)
    _warmup(eng, cfg, warm_lens)
    eng.kvc.pool.page_migrations = 0
    for r in mixed:
        eng.submit(r)
    t0 = time.perf_counter()
    for _ in range(4):                  # into the trace, then force a
        eng.step()                      # mid-trace migration
    eng.force_migrate()
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    new_tokens = sum(len(c.tokens) for c in eng.completions)
    st = eng.stats()
    toks = {c.rid: c.tokens for c in eng.completions}
    assert toks == baseline_tokens, (
        f"kv_shards={kv_shards} outputs diverge from the "
        "single-locality engine")
    out = _eng_stats(st, eng.slots, new_tokens, dt)
    out.update(kv_shards=kv_shards,
               backing="mesh" if mesh is not None else "simulated",
               shard_occupancy=st["shard_occupancy"],
               page_migrations=st["page_migrations"])
    return out


def run(verbose=True, out_path=None, smoke=False, kv_shards=0):
    import jax

    import repro.configs as configs
    from repro.models import transformer as T
    from repro.serving.engine import make_engine

    cfg = configs.get_reduced(ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    result = {"arch": ARCH, "page_size": PAGE_SIZE}

    # -- dense vs paged on the short trace ----------------------------
    short = _short_requests(cfg, 4 if smoke else 16,
                            max_new=4 if smoke else MAX_NEW)
    kw_short = dict(max_len=DENSE_MAX_LEN, prefill_buckets=(32,))
    dense = make_engine(params, cfg, engine="dense",
                        slots=SLOTS_DENSE, **kw_short)
    _warmup(dense, cfg, (12,))
    dense_s, dense_tok = _serve(dense, short)

    paged_s_eng = make_engine(params, cfg, engine="paged",
                              slots=SLOTS_PAGED, page_size=PAGE_SIZE,
                              n_pages=DENSE_N_PAGES, **kw_short)
    _warmup(paged_s_eng, cfg, (12,))
    pshort_s, pshort_tok = _serve(paged_s_eng, short)
    ps_st = paged_s_eng.stats()

    result["short_trace"] = {
        "kv_token_rows": SLOTS_DENSE * DENSE_MAX_LEN,
        "n_requests": len(short),
        "dense": {"slots": SLOTS_DENSE, "tok_s": dense_tok / dense_s,
                  "wall_s": dense_s, "peak_active": SLOTS_DENSE},
        "paged": _eng_stats(ps_st, SLOTS_PAGED, pshort_tok, pshort_s),
    }

    # -- whole-prompt vs chunked on the mixed trace -------------------
    mixed = _mixed_requests(cfg, n_short=4 if smoke else N_SHORT,
                            n_long=1 if smoke else N_LONG,
                            max_new=4 if smoke else MAX_NEW)
    kw_mixed = dict(max_len=MIXED_MAX_LEN, prefill_buckets=(32,),
                    slots=SLOTS_PAGED, page_size=PAGE_SIZE,
                    n_pages=MIXED_N_PAGES)
    # cover every bucket a preempted request's re-admission can land
    # in (32/64/96/128), not just the fresh-prompt buckets — otherwise
    # a preemption drops an XLA compile inside the timed region
    warm_lens = (97, 90, 33, 12)

    paged = make_engine(params, cfg, engine="paged", **kw_mixed)
    _warmup(paged, cfg, warm_lens)
    paged_s, paged_tok = _serve(paged, mixed)
    pst = paged.stats()

    chunked = make_engine(params, cfg, engine="chunked",
                          chunk_size=CHUNK, step_tokens=STEP_TOKENS,
                          **kw_mixed)
    _warmup(chunked, cfg, warm_lens)
    chunked_s, chunked_tok = _serve(chunked, mixed)
    cst = chunked.stats()

    result["mixed_trace"] = {
        "pages": MIXED_N_PAGES, "chunk_size": CHUNK,
        "step_tokens": STEP_TOKENS,
        "n_long": 1 if smoke else N_LONG,
        "n_short": 4 if smoke else N_SHORT,
        "paged": _eng_stats(pst, SLOTS_PAGED, paged_tok, paged_s),
        "chunked": _eng_stats(cst, SLOTS_PAGED, chunked_tok,
                              chunked_s),
    }

    # -- sharded pool on the mixed trace (DESIGN.md §4c) --------------
    if kv_shards > 1:
        baseline = {c.rid: c.tokens for c in chunked.completions}
        sh = _serve_sharded(params, cfg, kw_mixed, warm_lens, mixed,
                            kv_shards, baseline)
        result["mixed_trace"]["sharded"] = sh
        if verbose:
            occ = ", ".join(f"{o:.2f}" for o in sh["shard_occupancy"])
            print(f"# serve_bench sharded {sh['tok_s']:8.1f} tok/s "
                  f"(mixed, {kv_shards} shards, {sh['backing']}) "
                  f"occ=[{occ}] migrations={sh['page_migrations']} "
                  "token-identical to single-locality")
        emit("serve_sharded_tok_s", sh["tok_s"], "tok_per_s")
        emit("serve_sharded_page_migrations", sh["page_migrations"],
             f"kv_shards_{kv_shards}")
    if verbose:
        print(f"# serve_bench dense   {dense_tok / dense_s:8.1f} tok/s "
              f"(short trace, peak_active={SLOTS_DENSE})")
        print(f"# serve_bench paged   {pshort_tok / pshort_s:8.1f} tok/s "
              f"(short trace, peak_active={ps_st['peak_active']})")
        print(f"# serve_bench paged   {paged_tok / paged_s:8.1f} tok/s "
              f"(mixed) ttft_p50={pst['ttft_p50_ms']:.1f}ms "
              f"itl_p50={pst['itl_p50_ms']:.2f}ms "
              f"preempt={pst['preemptions']}")
        print(f"# serve_bench chunked {chunked_tok / chunked_s:8.1f} tok/s "
              f"(mixed) ttft_p50={cst['ttft_p50_ms']:.1f}ms "
              f"itl_p50={cst['itl_p50_ms']:.2f}ms "
              f"preempt={cst['preemptions']}")
        print("# json " + json.dumps(result))
    # serve_dense/paged_tok_s stay the SAME short trace as PR 1 (the
    # equal-KV-bytes pair); the mixed-trace engines get their own names
    emit("serve_dense_tok_s", dense_tok / dense_s, "tok_per_s")
    emit("serve_paged_tok_s", pshort_tok / pshort_s, "tok_per_s")
    emit("serve_paged_mixed_tok_s", paged_tok / paged_s, "tok_per_s")
    emit("serve_chunked_tok_s", chunked_tok / chunked_s, "tok_per_s")
    emit("serve_paged_peak_active", ps_st["peak_active"],
         f"dense_slots_{SLOTS_DENSE}_equal_kv_bytes")
    emit("serve_paged_ttft_p50", pst["ttft_p50_ms"] * 1e3, "us")
    emit("serve_chunked_ttft_p50", cst["ttft_p50_ms"] * 1e3, "us")
    emit("serve_paged_itl_p50", pst["itl_p50_ms"] * 1e3, "us")
    emit("serve_chunked_itl_p50", cst["itl_p50_ms"] * 1e3, "us")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny traces (CI): exercises all three engines"
                         " without asserting the latency split")
    ap.add_argument("--kv-shards", type=int, default=0,
                    help="also serve the mixed trace from a pool "
                         "sharded over N AGAS localities (with a "
                         "forced migration) and assert token parity "
                         "with the single-locality engine")
    args = ap.parse_args()
    run(out_path=args.out, smoke=args.smoke, kv_shards=args.kv_shards)
