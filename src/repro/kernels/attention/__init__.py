"""Pallas kernel package."""
