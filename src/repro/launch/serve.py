"""Serving driver: reduced-config batched decode demo.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    import repro.configs as configs
    from repro.models import transformer as T
    from repro.serving.engine import Request, ServingEngine

    cfg = configs.get_reduced(args.arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, slots=args.slots,
                        max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        n = int(rng.integers(8, 48))
        eng.submit(Request(rid, rng.integers(
            0, cfg.vocab_size, size=n).astype(np.int32),
            max_new_tokens=args.max_new))
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    total_new = sum(len(c.tokens) for c in eng.completions)
    print(f"[serve] {len(eng.completions)} completions, "
          f"{total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s)")
    for c in eng.completions[:4]:
        print(f"  rid={c.rid} new={len(c.tokens)} "
              f"prefill={c.prefill_s * 1e3:.0f}ms "
              f"decode={c.decode_s * 1e3:.0f}ms")


if __name__ == "__main__":
    main()
