"""Assigned-architecture registry.

10 LM archs (task statement, public literature) + the paper's AMR
problem.  `get(name)` returns the full ArchConfig; `get_reduced(name)`
the CPU smoke variant; `ARCHS` lists all ids.
"""

from __future__ import annotations

from typing import Dict

from repro.models.config import SHAPES, ArchConfig, ShapeConfig

from repro.configs.h2o_danube_3_4b import CONFIG as _danube
from repro.configs.chatglm3_6b import CONFIG as _chatglm
from repro.configs.command_r_plus_104b import CONFIG as _commandr
from repro.configs.yi_6b import CONFIG as _yi
from repro.configs.falcon_mamba_7b import CONFIG as _falcon
from repro.configs.zamba2_7b import CONFIG as _zamba
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.phi35_moe_42b import CONFIG as _phi
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.llama32_vision_90b import CONFIG as _llamav

_REGISTRY: Dict[str, ArchConfig] = {
    c.name: c for c in [
        _danube, _chatglm, _commandr, _yi, _falcon, _zamba, _mixtral,
        _phi, _musicgen, _llamav,
    ]
}

ARCHS = sorted(_REGISTRY)


def get(name: str) -> ArchConfig:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {ARCHS}") from None


def get_reduced(name: str) -> ArchConfig:
    return get(name).reduced()


__all__ = ["ARCHS", "get", "get_reduced", "SHAPES", "ArchConfig",
           "ShapeConfig"]
