"""Pallas kernel package."""
