"""Continuous-batching serving engines: paged (AGAS pages) and dense.

The ParalleX reading of serving (DESIGN.md §4): each request is a
first-class object whose completion is an LCO — `submit` returns a
`core.lco.Future` that is set exactly once when the request finishes.
Arriving requests are parcels that trigger a prefill task; decode is a
dataflow chain per slot, and the engine packs ready slots into batched
decode steps (the work-queue at token granularity).

Two engines share that skeleton:

* `PagedServingEngine` (the default `ServingEngine`) — KV memory is a
  pool of AGAS-named pages (serving/kvcache.py, DESIGN.md §4a).
  Admission is gated on free *pages*, not free slots: a request enters
  when the pool can hold its prefill (prefix-shared pages excluded)
  plus one decode page of headroom.  When the pool runs dry mid-decode
  the youngest request is preempted back to the queue (its pages freed,
  its progress carried so re-admission resumes seamlessly).  Every slot
  keeps its own position clock — there is no shared `len/cursor/abs`.
  Per-step counters (queue depth, page occupancy, latencies) expose the
  runtime's overheads in the spirit of the paper's Fig 9.

* `DenseServingEngine` — the static-ownership baseline: a bulk
  `(slots, max_len)` cache with one shared position clock spliced via
  `jnp.maximum`.  Kept as the CSP-style comparison point for parity
  tests and benchmarks/serve_bench.py; its memory scales with
  worst-case length whether or not tokens exist.

Design points that matter at scale and are implemented here:
* fixed-shape decode batch (slot pool) -> one compiled decode step;
* prefill runs per request at bucketed lengths (pad-to-bucket) to
  bound compilation count;
* slots free on EOS/length and refill from the queue (continuous
  batching);
* per-slot sampling state (greedy or temperature), keyed by the
  request id and its own generated-token count.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lco import Future
from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.serving.kvcache import (PagedKVCache, PageExhausted,
                                   PAGED_FAMILIES)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]
    prefill_s: float
    decode_s: float
    preemptions: int = 0


class _EngineBase:
    """Queue intake, bucketed prefill, sampling, and the run loop."""

    def __init__(self, params: Any, cfg: ArchConfig, *, slots: int,
                 max_len: int, prefill_buckets=(64, 128, 256)):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.buckets = tuple(sorted(prefill_buckets))
        # queue items: {"req", "gen" (tokens carried over a
        # preemption), "preempts"}
        self.queue: List[dict] = []
        self.active: Dict[int, dict] = {}      # slot -> request state
        self.free_slots = list(range(slots))
        self.completions: List[Completion] = []
        self._futures: Dict[int, Future] = {}
        self._prefills: Dict[int, Any] = {}

    # -- request intake (a parcel arriving at the engine locality) ----
    def submit(self, req: Request) -> Future:
        """Enqueue; returns the completion LCO (set exactly once)."""
        fut = Future()
        self._futures[req.rid] = fut
        self.queue.append({"req": req, "gen": [], "preempts": 0,
                           "bucket": None})
        return fut

    @staticmethod
    def _queue_prompt(item: dict) -> np.ndarray:
        """Prompt + any tokens generated before a preemption."""
        req = item["req"]
        if item["gen"]:
            return np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(item["gen"], np.int32)])
        return np.asarray(req.prompt, np.int32)

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        # beyond the ladder: multiples of the largest bucket, so the
        # compile count stays bounded
        big = self.buckets[-1]
        return -(-n // big) * big

    @staticmethod
    def _pad_to(tokens: np.ndarray, length: int) -> np.ndarray:
        padded = np.zeros(length, np.int32)
        padded[length - len(tokens):] = tokens           # left-pad
        return padded

    def _padded_prompt(self, tokens: np.ndarray) -> np.ndarray:
        return self._pad_to(tokens, self._bucket(len(tokens)))

    def _prefill_fn(self, bucket: int):
        """One compiled prefill per bucket.  The real sequence may end
        before the padded buffer does (right-padded resumes); the last
        index is a traced operand, so it never forces a recompile."""
        if bucket not in self._prefills:
            cfg = self.cfg
            full_kv = self._FULL_KV

            def fn(params, tokens, last_index):
                batch = {"tokens": tokens}
                hidden, cache = T.prefill(params, batch, cfg,
                                          full_kv=full_kv,
                                          last_index=last_index)
                return T.logits_fn(params, hidden), cache
            self._prefills[bucket] = jax.jit(fn)
        return self._prefills[bucket]

    def _sample(self, logits: jnp.ndarray, req: Request,
                n_gen: int) -> int:
        """Sample keyed by (rid, generated-token count) — each step of
        each request gets a distinct PRNG key."""
        if req.temperature <= 0:
            return int(jnp.argmax(logits))
        key = jax.random.PRNGKey(req.rid * 7919 + n_gen)
        return int(jax.random.categorical(key,
                                          logits / req.temperature))

    def _reject(self, item: dict, err: Exception) -> None:
        """Fail one request without killing the engine: its completion
        LCO carries the error; everything else keeps flowing."""
        fut = self._futures.pop(item["req"].rid, None)
        if fut is not None:
            fut.set_error(err)

    def _finish(self, st: dict) -> None:
        comp = Completion(st["req"].rid, st["tokens"], st["prefill_s"],
                          time.perf_counter() - st["t0"],
                          st.get("preempts", 0))
        self.completions.append(comp)
        fut = self._futures.pop(comp.rid, None)
        if fut is not None:
            fut.set(comp)

    def step(self) -> int:
        raise NotImplementedError

    def _admit(self) -> None:
        raise NotImplementedError

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.active and not self.queue:
                return
            self.step()                  # step() admits first


class DenseServingEngine(_EngineBase):
    """Static bulk KV ownership: (slots, max_len), one shared clock."""

    _FULL_KV = False

    def __init__(self, params: Any, cfg: ArchConfig, *, slots: int = 4,
                 max_len: int = 512, prefill_buckets=(64, 128, 256)):
        super().__init__(params, cfg, slots=slots, max_len=max_len,
                         prefill_buckets=prefill_buckets)
        # one shared batched cache across slots
        self.cache = T.init_cache(cfg, slots, max_len)
        self._decode = jax.jit(
            lambda p, c, b: T.decode_step(p, c, b, cfg))

    def _admit(self) -> None:
        while self.queue and self.free_slots:
            item = self.queue.pop(0)
            req = item["req"]
            toks = self._padded_prompt(self._queue_prompt(item))
            bucket = len(toks)
            if bucket > self.max_len:
                self._reject(item, ValueError(
                    f"request {req.rid}: padded prompt {bucket} "
                    f"exceeds max_len {self.max_len}"))
                continue
            slot = self.free_slots.pop(0)
            t0 = time.perf_counter()
            logits, pcache = self._prefill_fn(bucket)(
                self.params, jnp.asarray(toks[None]),
                jnp.int32(bucket - 1))
            # splice this request's prefill cache into the slot pool
            self._splice_cache(slot, pcache, bucket)
            first = self._sample(logits[0], req, len(item["gen"]))
            self.active[slot] = {
                "req": req, "tokens": item["gen"] + [int(first)],
                "prefill_s": time.perf_counter() - t0,
                "t0": time.perf_counter(),
                "pos": bucket,
                "preempts": item["preempts"],
            }

    def _splice_cache(self, slot: int, pcache: dict, plen: int) -> None:
        def splice(pool, part):
            if pool.ndim == 0 or part is None:
                return pool
            # find the batch axis: pool (…, slots, …) vs part (…,1,…)
            for ax in range(pool.ndim):
                if part.shape[ax] == 1 and pool.shape[ax] == self.slots:
                    break
            else:
                return pool
            # seq axes differ (plen vs max_len): pad part
            pads = []
            for d in range(pool.ndim):
                if d == ax:
                    pads.append((0, 0))
                else:
                    pads.append((0, pool.shape[d] - part.shape[d]))
            part = jnp.pad(part, pads)
            idx = [slice(None)] * pool.ndim
            idx[ax] = slice(slot, slot + 1)
            return pool.at[tuple(idx)].set(part)

        for k in self.cache:
            if k in ("len", "cursor", "abs"):
                continue
            self.cache[k] = splice(self.cache[k], pcache.get(k))
        # shared counters: the pool cache uses one clock; keep max
        self.cache["len"] = jnp.maximum(self.cache["len"],
                                        pcache["len"])
        self.cache["cursor"] = jnp.maximum(self.cache["cursor"],
                                           pcache["cursor"])
        self.cache["abs"] = jnp.maximum(self.cache["abs"],
                                        pcache["abs"])

    # -- the decode work-queue ----------------------------------------
    def step(self) -> int:
        """One batched decode step over all active slots."""
        self._admit()
        if not self.active:
            return 0
        tokens = np.zeros((self.slots, 1), np.int32)
        for slot, st in self.active.items():
            tokens[slot, 0] = st["tokens"][-1]
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (self.slots, self.cfg.n_frontend_tokens,
                 32 if self.cfg.d_model < 1024 else 1280),
                jnp.dtype(self.cfg.dtype))
        logits, self.cache = self._decode(self.params, self.cache,
                                          batch)
        done = []
        for slot, st in self.active.items():
            req = st["req"]
            tok = self._sample(logits[slot], req, len(st["tokens"]))
            st["tokens"].append(tok)
            if (req.eos_id is not None and tok == req.eos_id) or \
                    len(st["tokens"]) >= req.max_new_tokens:
                done.append(slot)
        for slot in done:
            self._finish(self.active.pop(slot))
            self.free_slots.append(slot)
        return len(self.active) + len(done)


class PagedServingEngine(_EngineBase):
    """KV memory as AGAS pages: demand allocation, prefix sharing,
    page-gated admission, and preemption under pressure."""

    _FULL_KV = True

    def __init__(self, params: Any, cfg: ArchConfig, *, slots: int = 4,
                 max_len: int = 512, prefill_buckets=(64, 128, 256),
                 page_size: int = 16, n_pages: Optional[int] = None):
        super().__init__(params, cfg, slots=slots, max_len=max_len,
                         prefill_buckets=prefill_buckets)
        if n_pages is None:
            # default: the dense engine's worst-case footprint — callers
            # shrink it to oversubscribe (kvcache preempts under
            # pressure), or grow slots beyond what dense could afford
            n_pages = slots * (-(-max_len // page_size))
        self.kvc = PagedKVCache(cfg, slots, max_len, n_pages, page_size)
        # donate the page pool: on accelerators the step updates KV
        # pages in place instead of holding input + output copies
        self._decode = jax.jit(
            lambda p, pages, b: T.decode_step_paged(p, pages, b, cfg),
            donate_argnums=(1,))
        self._seq = itertools.count()          # admission order
        self.preemptions = 0
        self.counters: List[dict] = []         # per-step telemetry

    # -- page-gated admission -----------------------------------------
    def _admit(self) -> None:
        while self.queue and self.free_slots:
            item = self.queue[0]
            req = item["req"]
            prompt = self._queue_prompt(item)
            if item["gen"]:
                # re-admission after preemption: reconstruct the
                # ORIGINAL padded layout (same left-pad count, same
                # positions) extended by the generated tokens, so the
                # resumed request decodes exactly as if it had never
                # been preempted
                padded = self._pad_to(
                    prompt, item["bucket"] + len(item["gen"]))
            else:
                padded = self._padded_prompt(prompt)
            real = len(padded)
            if real > self.max_len:
                self.queue.pop(0)
                self._reject(item, ValueError(
                    f"request {req.rid}: padded prompt {real} "
                    f"exceeds max_len {self.max_len}"))
                continue
            # admit on PAGES, not slots: prefill pages (prefix-shared
            # ones are free), one decode page of headroom, plus a
            # watermark for active slots whose next write takes a page
            # (boundary alloc or COW) — otherwise an admission can be
            # preempted away in the very same step
            upcoming = sum(1 for s in self.active
                           if self.kvc.needs_alloc(s))
            need = self.kvc.pages_needed(padded) + 1
            if need > self.kvc.pool.capacity:
                self.queue.pop(0)
                self._reject(item, RuntimeError(
                    f"request {req.rid} needs {need} pages but the "
                    f"pool holds {self.kvc.pool.capacity}"))
                continue
            if need + upcoming > self.kvc.pool.free_pages:
                break                          # head-of-line blocking
            self.queue.pop(0)
            slot = self.free_slots.pop(0)
            t0 = time.perf_counter()
            # resumes run at the bucket ladder too: pad RIGHT (junk
            # tokens after the real end never enter the cache and,
            # under causality, cannot influence earlier positions), so
            # the compile count stays bucket-bounded
            bucket = self._bucket(real)
            toks = np.zeros(bucket, np.int32)
            toks[:real] = padded
            logits, pcache = self._prefill_fn(bucket)(
                self.params, jnp.asarray(toks[None]),
                jnp.int32(real - 1))
            self.kvc.attach(slot, padded,
                            pcache["k"][:, 0, :real],
                            pcache["v"][:, 0, :real])
            first = self._sample(logits[0], req, len(item["gen"]))
            self.active[slot] = {
                "req": req, "tokens": item["gen"] + [int(first)],
                "prefill_s": time.perf_counter() - t0,
                "t0": time.perf_counter(),
                "seq": next(self._seq),
                "preempts": item["preempts"],
                "bucket": item["bucket"] if item["gen"] else real,
            }

    # -- preemption under page pressure -------------------------------
    def _preempt(self, slot: int) -> None:
        """Evict a request: free its pages, requeue it at the front
        with its progress AND its original padded bucket, so
        re-admission reconstructs the identical context layout and
        resumes where it left off."""
        st = self.active.pop(slot)
        self.kvc.release(slot)
        self.free_slots.append(slot)
        self.preemptions += 1
        self.queue.insert(0, {"req": st["req"], "gen": st["tokens"],
                              "preempts": st["preempts"] + 1,
                              "bucket": st["bucket"]})

    def _prepare_writes(self) -> None:
        """Reserve every active slot's write page, preempting the
        youngest request (LIFO — the oldest keeps its pages, so the
        system always drains) until the pool fits.  A lone request the
        pool cannot hold is failed via its LCO, not the engine."""
        while True:
            try:
                for slot in sorted(self.active,
                                   key=lambda s: self.active[s]["seq"]):
                    self.kvc.prepare_decode(slot)
                return
            except PageExhausted:
                if len(self.active) <= 1:
                    slot, st = next(iter(self.active.items()))
                    self.active.pop(slot)
                    self.kvc.release(slot)
                    self.free_slots.append(slot)
                    self._reject({"req": st["req"]}, RuntimeError(
                        "page pool too small for request "
                        f"{st['req'].rid}: {self.kvc.pool.capacity} "
                        f"pages of {self.kvc.pool.page_size}"))
                    return
                victim = max(self.active,
                             key=lambda s: self.active[s]["seq"])
                self._preempt(victim)

    # -- the decode work-queue ----------------------------------------
    def step(self) -> int:
        """One batched decode step over all active slots."""
        self._admit()
        # truncate requests whose next token has no cache room left
        # (bucket + generated reached max_len) instead of overflowing
        for slot in [s for s in self.active
                     if self.kvc.lengths[s] >= self.max_len]:
            self._finish(self.active.pop(slot))
            self.kvc.release(slot)
            self.free_slots.append(slot)
        if not self.active:
            return 0
        self._prepare_writes()
        if not self.active:                    # lone request rejected
            return 0
        t0 = time.perf_counter()
        tokens = np.zeros((self.slots, 1), np.int32)
        for slot, st in self.active.items():
            tokens[slot, 0] = st["tokens"][-1]
        batch = {"tokens": jnp.asarray(tokens),
                 **self.kvc.batch_inputs()}
        logits, pages = self._decode(self.params, self.kvc.pool.pages,
                                     batch)
        self.kvc.pool.pages = pages
        done = []
        for slot, st in self.active.items():
            self.kvc.advance(slot)
            req = st["req"]
            tok = self._sample(logits[slot], req, len(st["tokens"]))
            st["tokens"].append(tok)
            if (req.eos_id is not None and tok == req.eos_id) or \
                    len(st["tokens"]) >= req.max_new_tokens:
                done.append(slot)
        for slot in done:
            self._finish(self.active.pop(slot))
            self.kvc.release(slot)
            self.free_slots.append(slot)
        pool = self.kvc.pool
        self.counters.append({
            "t": time.perf_counter(),
            "queue_depth": len(self.queue),
            "active": len(self.active) + len(done),
            "pages_used": pool.used_pages,
            "page_occupancy": pool.occupancy(),
            "preemptions": self.preemptions,
            "decode_ms": (time.perf_counter() - t0) * 1e3,
        })
        return len(self.active) + len(done)

    def stats(self) -> dict:
        """Aggregate per-step counters (the Fig 9 overhead view)."""
        c = self.counters
        pool = self.kvc.pool
        return {
            "steps": len(c),
            "peak_active": max((x["active"] for x in c), default=0),
            "peak_page_occupancy": max(
                (x["page_occupancy"] for x in c), default=0.0),
            "mean_decode_ms": float(np.mean(
                [x["decode_ms"] for x in c])) if c else 0.0,
            "preemptions": self.preemptions,
            "page_allocs": pool.allocs,
            "page_shares": pool.shares,
            "cow_copies": pool.cow_copies,
            "mean_prefill_ms": float(np.mean(
                [x.prefill_s for x in self.completions])) * 1e3
            if self.completions else 0.0,
        }


#: The serving engine: paged KV over AGAS pages.
ServingEngine = PagedServingEngine


def make_engine(params: Any, cfg: ArchConfig, **kwargs) -> _EngineBase:
    """Paged engine for attention-cache families, dense fallback for
    families whose recurrent state has no paged layout (ssm/hybrid/vlm)."""
    if cfg.family in PAGED_FAMILIES:
        return PagedServingEngine(params, cfg, **kwargs)
    kwargs.pop("page_size", None)
    kwargs.pop("n_pages", None)
    return DenseServingEngine(params, cfg, **kwargs)
