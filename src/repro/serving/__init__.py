"""serving subpackage: paged KV cache + continuous-batching engines."""

from repro.serving.engine import (ChunkedPagedServingEngine,
                                  DenseServingEngine,
                                  DisaggChunkedServingEngine,
                                  PagedServingEngine,
                                  ServingEngine, make_engine)
from repro.serving.kvcache import (PagedKVCache, PageExhausted,
                                   PagePool, page_keys)
from repro.serving.types import Completion, Request
from repro.serving.workers import (DecodeWorker, HandoffDecodeWorker,
                                   ParcelPrefillWorker, PrefillWorker,
                                   StepScheduler)

__all__ = [
    "ChunkedPagedServingEngine", "Completion", "DenseServingEngine",
    "DisaggChunkedServingEngine", "PagedServingEngine", "Request",
    "ServingEngine", "make_engine",
    "PagedKVCache", "PageExhausted", "PagePool", "page_keys",
    "DecodeWorker", "HandoffDecodeWorker", "ParcelPrefillWorker",
    "PrefillWorker", "StepScheduler",
]
