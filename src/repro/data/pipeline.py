"""Deterministic, sharded, resumable synthetic data pipeline.

A real corpus is out of scope offline; this pipeline has the
production-relevant properties anyway:

* deterministic: batch(step) is a pure function of (seed, step) via
  PRNG fold_in — restart-safe with no data-order drift;
* sharded: each data-parallel rank materializes only its slice;
* resumable: the checkpointed state is just the step counter;
* structured: token streams carry Zipf-distributed unigrams with
  Markov bigram structure, so language-model losses actually decrease
  (examples/train_lm.py demonstrates) instead of saturating at
  log(vocab).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_strength: float = 0.8


class SyntheticCorpus:
    """step -> {tokens, labels} (global arrays; caller shards)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        v = cfg.vocab_size
        rng = np.random.default_rng(cfg.seed)
        # Zipf unigram distribution + a sparse "successor" table that
        # injects predictable bigrams (what the model can learn).
        ranks = np.arange(1, v + 1)
        p = ranks ** (-cfg.zipf_a)
        self._unigram = jnp.asarray(p / p.sum(), jnp.float32)
        self._succ = jnp.asarray(rng.integers(0, v, size=(v,)),
                                 jnp.int32)

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        k1, k2 = jax.random.split(key)
        b, s = cfg.global_batch, cfg.seq_len
        base = jax.random.categorical(
            k1, jnp.log(self._unigram)[None, None, :],
            shape=(b, s))
        # Markov structure: with prob `markov_strength`, token t+1 is
        # succ[token t].
        flips = jax.random.bernoulli(k2, cfg.markov_strength,
                                     (b, s - 1))
        toks = [base[:, :1]]
        prev = base[:, 0]
        for t in range(1, s):
            nxt = jnp.where(flips[:, t - 1], self._succ[prev],
                            base[:, t])
            toks.append(nxt[:, None])
            prev = nxt
        tokens = jnp.concatenate(toks, axis=1)
        labels = jnp.concatenate(
            [tokens[:, 1:], tokens[:, :1]], axis=1)
        return {"tokens": tokens, "labels": labels}

    def batch_fast(self, step: int) -> Dict[str, jnp.ndarray]:
        """Vectorized variant (one fused where-scan) for larger shapes."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        k1, k2 = jax.random.split(key)
        b, s = cfg.global_batch, cfg.seq_len
        base = jax.random.categorical(
            k1, jnp.log(self._unigram)[None, :], shape=(b, s))
        flips = jax.random.bernoulli(k2, cfg.markov_strength, (b, s))

        def step_fn(prev, xs):
            base_t, flip_t = xs
            nxt = jnp.where(flip_t, self._succ[prev], base_t)
            return nxt, nxt

        _, seq = jax.lax.scan(
            step_fn, base[:, 0],
            (base.swapaxes(0, 1), flips.swapaxes(0, 1)))
        tokens = seq.swapaxes(0, 1)
        labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        return {"tokens": tokens, "labels": labels}


@dataclasses.dataclass
class PipelineState:
    """The entire resumable state: one integer."""

    step: int = 0

    def to_dict(self) -> dict:
        return {"step": self.step}

    @staticmethod
    def from_dict(d: dict) -> "PipelineState":
        return PipelineState(int(d["step"]))


def iterate(corpus: SyntheticCorpus,
            state: Optional[PipelineState] = None
            ) -> Iterator[Dict[str, jnp.ndarray]]:
    state = state or PipelineState()
    while True:
        yield corpus.batch_fast(state.step)
        state.step += 1
