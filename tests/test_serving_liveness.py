"""Serving-engine liveness regressions: a preempted request re-admitted
at the length cap delivers its partial generation (never an error LCO),
`run_to_completion` fails pending futures instead of leaving callers
blocked forever, and an admission is never preempted away in the very
same step it was granted (the chunked watermark counts pending-chunk
demand, not just decode writes)."""

import numpy as np
import pytest
import jax

import repro.configs as configs
from repro.models import transformer as T
from repro.serving.engine import (ChunkedPagedServingEngine,
                                  PagedServingEngine, Request,
                                  make_engine)

RNG = np.random.default_rng(13)


def _cfg(name="yi-6b"):
    return configs.get_reduced(name)


def _params(cfg):
    return T.init_params(jax.random.PRNGKey(0), cfg)


# -- fix 1: re-admission at the cap finishes with partial tokens -------

@pytest.mark.parametrize("engine", ["paged", "chunked"])
def test_preempted_request_at_cap_delivers_partial_tokens(engine):
    """A preempted request whose prompt + generated tokens exceed
    max_len must FINISH with the tokens it already generated — exactly
    what an un-preempted request in the same state gets via
    truncation — not be rejected through its LCO with all its work
    discarded."""
    cfg = _cfg()
    params = _params(cfg)
    eng = make_engine(params, cfg, engine=engine, slots=2, max_len=64,
                      prefill_buckets=(32,), page_size=16)
    prompt = RNG.integers(0, cfg.vocab_size, size=20).astype(np.int32)
    fut = eng.submit(Request(0, prompt, max_new_tokens=50))
    # reconstruct the carried-preemption state at the head of the
    # queue: 20 prompt + 50 generated tokens = 70 > max_len 64
    item = eng.queue[0]
    gen = [int(x) for x in RNG.integers(0, cfg.vocab_size, size=50)]
    item["gen"] = list(gen)
    item["preempts"] = 2
    eng.run_to_completion()
    comp = fut.get()                    # must NOT raise
    assert comp.tokens == gen
    assert comp.preemptions == 2
    assert eng.kvc.pool.used_pages == 0
    # the engine stayed healthy: a follow-up request completes
    f2 = eng.submit(Request(1, prompt[:10], max_new_tokens=4))
    eng.run_to_completion()
    assert len(f2.get().tokens) == 4


def test_readmission_exceeding_pool_capacity_delivers_partial_tokens():
    """Same principle when the re-admission's page need outgrows the
    pool: generated tokens are delivered, not discarded."""
    cfg = _cfg()
    params = _params(cfg)
    eng = PagedServingEngine(params, cfg, slots=2, max_len=256,
                            prefill_buckets=(32,), page_size=16,
                            n_pages=4)
    prompt = RNG.integers(0, cfg.vocab_size, size=20).astype(np.int32)
    fut = eng.submit(Request(0, prompt, max_new_tokens=200))
    item = eng.queue[0]
    gen = [int(x) for x in RNG.integers(0, cfg.vocab_size, size=60)]
    item["gen"] = list(gen)             # 80 tokens -> 5 pages + 1 > 4
    item["preempts"] = 1
    eng.run_to_completion()
    assert fut.get().tokens == gen


# -- fix 2: run_to_completion never strands futures --------------------

def test_exhausted_max_steps_fails_futures_instead_of_hanging():
    cfg = _cfg()
    params = _params(cfg)
    eng = PagedServingEngine(params, cfg, slots=2, max_len=64,
                            prefill_buckets=(32,), page_size=16)
    futs = [eng.submit(Request(rid, np.arange(10, dtype=np.int32),
                               max_new_tokens=30))
            for rid in range(2)]
    eng.run_to_completion(max_steps=1)
    # every future is resolved: a caller blocked on one gets its error
    assert all(f.done() for f in futs)
    with pytest.raises(RuntimeError, match="max_steps"):
        futs[0].get()
    # pages and slots were reclaimed; the engine is reusable
    assert eng.kvc.pool.used_pages == 0
    assert not eng.active and not eng.queue
    f2 = eng.submit(Request(9, np.arange(8, dtype=np.int32),
                            max_new_tokens=4))
    eng.run_to_completion()
    assert len(f2.get().tokens) == 4


def test_head_of_line_block_fails_future_instead_of_hanging():
    """A queue head that can never be admitted (pages held elsewhere,
    nothing active to free them) must fail its LCO, not spin silently
    while the caller blocks forever."""
    cfg = _cfg()
    params = _params(cfg)
    eng = PagedServingEngine(params, cfg, slots=2, max_len=64,
                            prefill_buckets=(32,), page_size=16,
                            n_pages=6)
    held = [eng.kvc.pool.alloc() for _ in range(5)]   # 1 page left
    fut = eng.submit(Request(0, np.arange(20, dtype=np.int32),
                             max_new_tokens=4))       # needs 3
    eng.run_to_completion()
    assert fut.done()
    with pytest.raises(RuntimeError, match="head-of-line"):
        fut.get()
    # freeing the held pages un-wedges the engine for new work
    for a in held:
        eng.kvc.pool.decref(a)
    f2 = eng.submit(Request(1, np.arange(20, dtype=np.int32),
                            max_new_tokens=4))
    eng.run_to_completion()
    assert len(f2.get().tokens) == 4


# -- fix 3: no same-step admit-then-preempt ----------------------------

def test_admission_is_never_preempted_in_its_own_step():
    """The chunked watermark must count the pages mid-prefill slots'
    next chunks will take (they run right after admission), exactly as
    the paged engine counts decode writes — otherwise an admission can
    be granted and preempted away within one step() call."""
    cfg = _cfg()
    params = _params(cfg)
    eng = ChunkedPagedServingEngine(params, cfg, slots=4, max_len=64,
                                    prefill_buckets=(8, 16),
                                    page_size=8, chunk_size=8,
                                    n_pages=4, step_tokens=32)
    violations = []
    orig_preempt = eng._preempt

    def spy(slot):
        st = eng.active[slot]
        if st.get("admit_step") == len(eng.counters):
            violations.append(st["req"].rid)
        orig_preempt(slot)
    eng._preempt = spy

    rng = np.random.default_rng(7)
    L1 = Request(0, rng.integers(0, cfg.vocab_size, size=16)
                 .astype(np.int32), max_new_tokens=4)
    L2 = Request(1, rng.integers(0, cfg.vocab_size, size=16)
                 .astype(np.int32), max_new_tokens=4)
    S = Request(2, rng.integers(0, cfg.vocab_size, size=6)
                .astype(np.int32), max_new_tokens=4)
    futs = [eng.submit(L1), eng.submit(L2)]
    eng.step()          # L1, L2 admitted; one chunk each (2 pages free)
    futs.append(eng.submit(S))
    eng.step()
    # S must NOT have been admitted: the 2 free pages are spoken for by
    # L1's and L2's next chunks (the old decode-only watermark admitted
    # S here and the chunk exhaustion preempted it in this very step)
    assert all(st["req"].rid != S.rid for st in eng.active.values())
    eng.run_to_completion()
    assert violations == []
    comps = {c.rid: c for c in eng.completions}
    assert set(comps) == {0, 1, 2}
    assert all(len(comps[r].tokens) == 4 for r in comps)
    assert eng.preemptions > 0          # the pressure was real
    assert eng.kvc.pool.used_pages == 0
