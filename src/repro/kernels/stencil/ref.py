"""Pure-jnp oracle for the stencil kernel: vmapped fused_rk3_block."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.amr.wave import fused_rk3_block


def stencil_rk3_ref(u_ext: jnp.ndarray, r_ext: jnp.ndarray,
                    flags: jnp.ndarray, *, dr: float, dt: float,
                    p: int) -> jnp.ndarray:
    """Same signature as stencil.stencil_rk3 (minus interpret)."""
    fn = lambda u, r, f: fused_rk3_block(
        u, r, dr, dt, p,
        left_phys=f[0] > 0, right_phys=f[1] > 0)
    return jax.vmap(fn)(u_ext, r_ext, flags)
