"""Prefix-cache compute skip (DESIGN.md §4e): fully / partially /
un-cached prompts are token-identical to cold prefill, a full cover
admits with zero prefill compute, checkpoints survive a spill to the
host tier, and COW handles divergence inside a covered partial page.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import transformer as T
from repro.serving.engine import Request, make_engine
from repro.serving.kvcache import PagedKVCache

RNG = np.random.default_rng(31)

KW = dict(slots=4, max_len=160, prefill_buckets=(32,), page_size=16,
          chunk_size=32, n_pages=48, tiering=True, host_pages=48,
          prefix_cache_compute=True)


@pytest.fixture(scope="module", params=["yi-6b", "mixtral-8x7b"])
def setup(request):
    cfg = configs.get_reduced(request.param)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg):
    """A/B share a 56-token head (3 full pages of the pad-free
    layout); C shares nothing.  Totals are equal (80) only so the cold
    references stay comparable — position-normalized keys make the
    sharing independent of total length."""
    rng = np.random.default_rng(17)
    head = rng.integers(0, cfg.vocab_size, size=56)
    tail_a = rng.integers(0, cfg.vocab_size, size=24)
    tail_b = rng.integers(0, cfg.vocab_size, size=24)
    a = np.concatenate([head, tail_a]).astype(np.int32)
    b = np.concatenate([head, tail_b]).astype(np.int32)
    c = rng.integers(0, cfg.vocab_size, size=40).astype(np.int32)
    return a, b, c


def _serve(eng, reqs, **rtc):
    futs = [eng.submit(r) for r in reqs]
    eng.run_to_completion(**rtc)
    return {f.get().rid: f.get().tokens for f in futs}


def _cold(params, cfg, prompt, max_new, engine="chunked"):
    """Cold-prefill ground truth: a fresh engine, nothing cached."""
    eng = make_engine(params, cfg, engine=engine, **KW)
    return _serve(eng, [Request(0, prompt, max_new_tokens=max_new)])[0]


# -- the headline parity: full / partial / uncached vs cold prefill ----

def test_full_partial_uncached_parity_vs_cold(setup):
    cfg, params = setup
    a, b, c = _prompts(cfg)
    truth = {p.tobytes(): _cold(params, cfg, p, 6) for p in (a, b, c)}

    eng = make_engine(params, cfg, **KW)
    warm = _serve(eng, [Request(0, a, max_new_tokens=6)])
    assert warm[0] == truth[a.tobytes()]
    assert eng.prefix_skips == 0            # nothing cached yet

    got = _serve(eng, [Request(1, a, max_new_tokens=6),   # full cover
                       Request(2, b, max_new_tokens=6),   # partial
                       Request(3, c, max_new_tokens=6)])  # uncached
    assert got[1] == truth[a.tobytes()]
    assert got[2] == truth[b.tobytes()]
    assert got[3] == truth[c.tobytes()]
    # the repeat admitted straight to decode (all 80 real tokens); B
    # skipped its 3 covered head pages (56 -> 48 page-aligned); C is
    # genuinely uncached and skipped nothing — pad-free layouts have
    # no all-zeros left-pad page to cover by luck
    assert eng.prefix_skips == 1
    assert eng.prefix_partial_hits == 1
    assert eng.prefill_tokens_skipped == 80 + 48 + 0
    st = eng.stats()
    assert st["prefix_cache_compute"] is True
    assert st["prefill_tokens_skipped"] == 128
    assert st["prefix_partial_hits"] == 1


def test_whole_prompt_engine_full_cover_skips(setup):
    """The whole-prompt paged engine rides the same full-cover path
    (partial covers still prefill whole — memory sharing only)."""
    cfg, params = setup
    a, _, _ = _prompts(cfg)
    truth = _cold(params, cfg, a, 6, engine="paged")
    eng = make_engine(params, cfg, engine="paged", **KW)
    warm = _serve(eng, [Request(0, a, max_new_tokens=6)])
    assert warm[0] == truth
    got = _serve(eng, [Request(1, a, max_new_tokens=6)])
    assert got[1] == truth
    assert eng.prefix_skips == 1
    assert eng.prefill_tokens_skipped == 80


def test_spilled_activation_restores_with_its_pages(setup):
    """A prefix hit whose pages AND activation checkpoint spilled to
    host: the full-cover skip still works — ensure_device promotes
    the chain, the checkpoint rides along, outputs stay cold-exact."""
    cfg, params = setup
    a, _, _ = _prompts(cfg)
    truth = _cold(params, cfg, a, 6)
    eng = make_engine(params, cfg, **KW)
    _serve(eng, [Request(0, a, max_new_tokens=6)])
    moved = eng.force_demote()              # spill every cold page
    pool = eng.kvc.pool
    assert moved > 0 and pool.host_used > 0
    promoted_before = pool.promoted
    got = _serve(eng, [Request(1, a, max_new_tokens=6)])
    assert got[1] == truth
    assert eng.prefix_skips == 1            # still a zero-compute admit
    assert pool.promoted > promoted_before  # the hit really promoted


def test_cow_divergence_mid_covered_page(setup):
    """Two fully-covered repeats decode concurrently: both append into
    the covered PARTIAL page, so the first divergent write must COW —
    and both must still match the cold reference.  With pad-free
    layouts any prompt length off the page grid gives a partial final
    page (36 -> the last page holds 4 of 16)."""
    cfg, params = setup
    kw = dict(KW)
    rng = np.random.default_rng(41)
    a = rng.integers(0, cfg.vocab_size, size=36).astype(np.int32)
    eng_cold = make_engine(params, cfg, **kw)
    truth = _serve(eng_cold, [Request(0, a, max_new_tokens=10)])[0]
    eng = make_engine(params, cfg, **kw)
    _serve(eng, [Request(0, a, max_new_tokens=10)])
    cow_before = eng.kvc.pool.cow_copies
    got = _serve(eng, [Request(1, a, max_new_tokens=10),
                       Request(2, a, max_new_tokens=10)])
    assert got[1] == truth and got[2] == truth
    assert eng.prefix_skips == 2
    assert eng.kvc.pool.cow_copies > cow_before


def test_mixed_length_prompts_share_prefix(setup):
    """The headline fix: prompts of DIFFERENT total lengths sharing a
    real-token head share its pages and skip its compute.  Under the
    old padded-layout keying the differing left-pad counts made every
    page key diverge and this skipped zero tokens."""
    cfg, params = setup
    rng = np.random.default_rng(23)
    head = rng.integers(0, cfg.vocab_size, size=48)   # 3 full pages
    short = np.concatenate(
        [head, rng.integers(0, cfg.vocab_size, size=8)]
    ).astype(np.int32)                                # 56 total
    long = np.concatenate(
        [head, rng.integers(0, cfg.vocab_size, size=40)]
    ).astype(np.int32)                                # 88 total
    truth_s = _cold(params, cfg, short, 6)
    truth_l = _cold(params, cfg, long, 6)
    eng = make_engine(params, cfg, **KW)
    got = _serve(eng, [Request(0, short, max_new_tokens=6)])
    assert got[0] == truth_s
    got = _serve(eng, [Request(1, long, max_new_tokens=6)])
    assert got[1] == truth_l                # token-identical to cold
    assert eng.prefix_partial_hits == 1
    assert eng.prefill_tokens_skipped == 48  # the shared head pages
    assert eng.kvc.pool.shares >= 3


def test_skip_off_engine_shares_memory_but_never_skips(setup):
    cfg, params = setup
    a, _, _ = _prompts(cfg)
    kw = dict(KW, prefix_cache_compute=False)
    truth = _cold(params, cfg, a, 6)
    eng = make_engine(params, cfg, **kw)
    _serve(eng, [Request(0, a, max_new_tokens=6)])
    got = _serve(eng, [Request(1, a, max_new_tokens=6)])
    assert got[1] == truth
    assert eng.kvc.pool.shares > 0          # memory savings stay
    assert eng.prefix_skips == 0
    assert eng.prefill_tokens_skipped == 0


# -- kvcache-level unit coverage ---------------------------------------

def test_covered_prefix_requires_checkpoint_for_full_cover():
    """KV cached but no activation checkpoint (the pages came from a
    path that never computed hidden states): the cover drops the final
    page so a resumed chunk recomputes it — page-aligned, inside the
    prompt."""
    cfg = configs.get_reduced("yi-6b")
    kvc = PagedKVCache(cfg, slots=2, max_len=96, n_pages=6,
                       page_size=16, host_pages=8)
    padded = RNG.integers(0, 100, size=40).astype(np.int32)
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    z = jnp.zeros((L, 40, kvh, hd), jnp.float32)
    kvc.attach(0, padded, z, z)             # 2 full pages + 8/16
    kvc.release(0)                          # retained cold (tiered)
    cov = kvc.covered_prefix(padded)
    assert not cov.full
    assert cov.covered == 32 and len(cov.keys) == 2
    # checkpoint the final page by hand: the cover completes
    from repro.serving.kvcache import page_keys
    keys = page_keys(padded, 16)
    kvc.pool.store_hidden(kvc.pool.lookup_prefix(keys[-1]),
                          np.ones(cfg.d_model, np.float32))
    cov = kvc.covered_prefix(padded)
    assert cov.full and cov.covered == 40
    assert cov.hidden is not None

    # attach_covered rebuilds the slot exactly as prefill left it
    kvc.attach_covered(1, padded, cov.keys)
    assert kvc.lengths[1] == 40
    assert kvc.pages_needed(padded) == 0
    np.testing.assert_array_equal(
        kvc.tables[1][:3],
        [kvc.pool.row(a) for a in kvc._state[1].addrs])
    kvc.release(1)


def test_checkpoint_dies_with_its_page():
    """Dropping a cold page (or freeing an unregistered one) drops its
    checkpoint; the prefix index can never serve a stale activation."""
    cfg = configs.get_reduced("yi-6b")
    kvc = PagedKVCache(cfg, slots=1, max_len=64, n_pages=4,
                       page_size=16, host_pages=4)
    pool = kvc.pool
    padded = RNG.integers(0, 100, size=16).astype(np.int32)
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    z = jnp.zeros((L, 16, kvh, hd), jnp.float32)
    kvc.attach(0, padded, z, z)
    addr = kvc._state[0].addrs[0]
    pool.store_hidden(addr, np.ones(4, np.float32))
    from repro.serving.kvcache import page_keys
    key = page_keys(padded, 16)[0]
    assert pool.hidden_for(key) is not None
    kvc.release(0)                          # cold, checkpoint retained
    assert pool.hidden_for(key) is not None
    pool._drop_cold(addr.gid)
    assert pool.hidden_for(key) is None
    assert addr.gid not in pool._hidden


def test_dropped_cover_page_raises_cleanly_before_attach():
    """Forced-pressure regression (prefix-index purge on drop): cold
    covered pages demoted and then DROPPED under host-tier pressure
    between the cover probe and `attach_covered`.  The drop must purge
    the radix index atomically — a fresh probe shrinks, and attaching
    with the stale keys raises PageExhausted with everything rolled
    back, never a freed address."""
    from repro.serving.kvcache import PageExhausted
    cfg = configs.get_reduced("yi-6b")
    kvc = PagedKVCache(cfg, slots=2, max_len=96, n_pages=4,
                       page_size=16, host_pages=1)
    pool = kvc.pool
    toks = RNG.integers(0, 100, size=40).astype(np.int32)
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    z = jnp.zeros((L, 40, kvh, hd), jnp.float32)
    kvc.attach(0, toks, z, z)               # 3 pages
    kvc.release(0)                          # retained cold
    cov = kvc.covered_prefix(toks)
    assert cov.covered == 32                # no checkpoints: 2 keys
    # drive eviction: 1 free page, the host tier holds only 1 — the
    # first eviction demotes, the next must DROP a covered page
    held = [pool.alloc() for _ in range(3)]
    assert pool.cold_drops >= 1
    # the drop purged the index: the cover shrank atomically
    assert kvc.covered_prefix(toks).covered < cov.covered
    used = pool.used_pages
    with pytest.raises(PageExhausted):
        kvc.attach_covered(1, toks, cov.keys)
    # clean rollback: no refs leaked, the slot never came up
    assert pool.used_pages == used
    assert kvc.lengths[1] == 0
    pool.prefix.check()
    for a in held:
        pool.decref(a)


def test_resume_prefill_is_the_vocab_projection():
    cfg = configs.get_reduced("yi-6b")
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    h = jnp.asarray(RNG.normal(size=(1, cfg.d_model)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(T.resume_prefill(params, h)),
        np.asarray(T.logits_fn(params, h)))
