import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first (before any jax-touching import):
jax locks the device count at first init, and the dry-run needs 512
placeholder host devices to build the production meshes.

Per cell this script:
  1. builds the 16x16 ("data","model") or 2x16x16 ("pod","data",
     "model") mesh;
  2. constructs abstract params / opt-state / cache / batch
     (ShapeDtypeStruct only — no allocation);
  3. jit-lowers the right step (train_step / prefill_step /
     decode_step), compiles it, and records memory_analysis(),
     cost_analysis(), and the collective-byte parse of the HLO;
  4. appends the record to the results JSON (resumable cache).

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--amr]
  python -m repro.launch.dryrun --all --both-meshes
"""

import argparse
import json
import sys
import time
import traceback


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             use_pallas: bool = False, fsdp=None,
             options=None) -> dict:
    import jax
    import repro.configs as configs
    from repro.launch import steps as S
    from repro.launch.cost_model import analytic_costs
    from repro.launch.hlo_analysis import Roofline, model_flops_for
    from repro.launch.hlo_parse import collective_totals
    from repro.launch.mesh import make_production_mesh
    from repro.models.config import SHAPES, shape_applicable

    arch = configs.get(arch_name)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape)
    rec = {"arch": arch_name, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "multi_pod": multi_pod}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    params_abs = S.abstract_params(arch, mesh)
    batch_abs = S.input_specs(arch, shape, mesh)

    options = options or S.StepOptions()
    shardings_of = lambda t: jax.tree.map(lambda a: a.sharding, t)
    if shape.kind == "train":
        step, n_accum = S.make_train_step(arch, shape, mesh,
                                          use_pallas=use_pallas,
                                          options=options)
        opt_abs = S.abstract_opt_state(arch, mesh, params_abs)
        jfn = jax.jit(
            step, donate_argnums=(0, 1),
            out_shardings=(shardings_of(params_abs),
                           shardings_of(opt_abs), None))
        args = (params_abs, opt_abs, batch_abs)
        rec["n_accum"] = n_accum
    elif shape.kind == "prefill":
        step = S.make_prefill_step(arch, shape, mesh,
                                   use_pallas=use_pallas)
        # The produced cache must come out in its serving sharding —
        # without this the partitioner materializes a poorly-sharded
        # (up to 24 GiB/device) output (§Perf log, baseline bug).
        # prefill's cache tree matches init_cache's, so the decode
        # cache shardings apply directly.
        cache_abs = S.abstract_cache(arch, shape, mesh)
        jfn = jax.jit(step,
                      out_shardings=(None, shardings_of(cache_abs)))
        args = (params_abs, batch_abs)
    else:
        step = S.make_decode_step(arch, shape, mesh)
        cache_abs = S.abstract_cache(arch, shape, mesh)
        jfn = jax.jit(step, donate_argnums=(1,),
                      out_shardings=(None, shardings_of(cache_abs)))
        args = (params_abs, cache_abs, batch_abs)

    from repro.models.layers import constraint_mesh
    with mesh, constraint_mesh(mesh):
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    cs = collective_totals(hlo)           # trip-weighted (exact)
    ac = analytic_costs(
        arch, shape, n_chips,
        dp=n_chips // mesh.shape["model"],
        tp_moe=S.model_tp(arch, mesh),
        n_accum=rec.get("n_accum", 1))
    # wire_bytes_tpu corrects for the CPU backend's bf16->f32
    # legalization (activation collectives carry 2x bytes in this
    # artifact vs a TPU compilation); raw bytes stay in `collectives`.
    rl = Roofline(flops=ac.flops_total, hbm_bytes=ac.hbm_bytes_per_chip
                  * n_chips, wire_bytes=cs.wire_bytes_tpu,
                  n_chips=n_chips,
                  model_flops=model_flops_for(arch, shape),
                  kind=shape.kind)
    dev_bytes = getattr(mem, "temp_size_in_bytes", 0) + \
        getattr(mem, "argument_size_in_bytes", 0) + \
        getattr(mem, "output_size_in_bytes", 0) - \
        getattr(mem, "alias_size_in_bytes", 0)
    rec.update(
        status="ok",
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "per_device_total": dev_bytes,
            "per_device_gib": round(dev_bytes / 2**30, 3),
        },
        collectives=cs.to_dict(),
        hlo_cost_analysis={"flops_body_once": float(cost.get("flops",
                                                             0.0)),
                           "bytes_body_once": float(
                               cost.get("bytes accessed", 0.0))},
        analytic=ac.to_dict(),
        roofline=rl.to_dict(),
        hlo_bytes=len(hlo),
    )
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--amr", action="store_true",
                    help="also dry-run the compiled AMR engine")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    import repro.configs as configs
    from repro.models.config import SHAPES

    results = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    def key(a, s, mp):
        return f"{a}|{s}|{'2pod' if mp else '1pod'}"

    cells = []
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    if args.all:
        for a in configs.ARCHS:
            for s in SHAPES:
                for mp in meshes:
                    cells.append((a, s, mp))
    elif args.arch and args.shape:
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))
    if args.amr:
        for mp in meshes:
            cells.append(("AMR-wave-uniform", "amr", mp))

    failures = 0
    for a, s, mp in cells:
        k = key(a, s, mp)
        if k in results and results[k].get("status") in ("ok",
                                                         "skipped") \
                and not args.force:
            print(f"[cached] {k}", flush=True)
            continue
        print(f"[dryrun] {k} ...", flush=True)
        try:
            if a == "AMR-wave-uniform":
                rec = run_amr_cell(mp)
            else:
                rec = run_cell(a, s, mp, use_pallas=args.use_pallas)
            print(f"  -> {rec['status']} "
                  f"mem={rec.get('memory', {}).get('per_device_gib', '-')}GiB "
                  f"compile={rec.get('compile_s', '-')}s", flush=True)
        except Exception as e:
            rec = {"arch": a, "shape": s, "multi_pod": mp,
                   "status": "failed", "error": repr(e),
                   "trace": traceback.format_exc()[-2000:]}
            failures += 1
            print(f"  -> FAILED: {e!r}", flush=True)
        results[k] = rec
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    print(f"done: {len(cells)} cells, {failures} failures", flush=True)
    return 1 if failures else 0


def run_amr_cell(multi_pod: bool, steps_per_exchange: int = 1) -> dict:
    """Dry-run the paper's compiled AMR engine on the production mesh."""
    import jax
    from repro.amr import compiled as cp
    from repro.amr.wave import H, WaveProblem
    from repro.launch.hlo_analysis import Roofline
    from repro.launch.hlo_parse import collective_totals
    from repro.launch.mesh import make_production_mesh

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh.axis_names
    prob = WaveProblem(rmax=100.0, amplitude=0.004)
    cfg = cp.CompiledAMRConfig(grain=2048, slots=16, n_steps=16,
                               steps_per_exchange=steps_per_exchange)
    step, mk, _init, _to_g, shard, info = cp.make_uniform_step(
        prob, cfg, mesh, axes)
    with mesh:
        lowered = jax.jit(step).lower(mk())
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cs = collective_totals(compiled.as_text())
    n_chips = mesh.devices.size
    n_pts = info["n_points"]
    K = cfg.steps_per_exchange
    # Analytic terms (the fused-step flop count is ~60/point incl. the
    # shrinking-halo overlap; HBM = one pool read+write per K steps).
    halo_overhead = 1.0 + 3.0 * K * (K + 1) / cfg.grain
    flops = 60.0 * n_pts * cfg.n_steps * halo_overhead
    model_flops = 60.0 * n_pts * cfg.n_steps
    pool_bytes = n_pts * 3 * 4.0
    hbm = 2.0 * pool_bytes * (cfg.n_steps / K)
    rl = Roofline(flops, hbm, cs.wire_bytes_tpu, n_chips, model_flops,
                  kind="train")
    return {
        "arch": "AMR-wave-uniform",
        "shape": f"amr_k{K}" if K > 1 else "amr",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "multi_pod": multi_pod, "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "n_points": n_pts, "steps_per_exchange": K,
        "memory": {"per_device_gib": round(
            (getattr(mem, "temp_size_in_bytes", 0) +
             getattr(mem, "argument_size_in_bytes", 0)) / 2**30, 4)},
        "collectives": cs.to_dict(),
        "roofline": rl.to_dict(),
    }


if __name__ == "__main__":
    sys.exit(main())
