"""llama-3.2-vision-90b: dense decoder with gated cross-attention
image layers every 5th layer.  [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  The vision
tower is a STUB: input_specs() supplies precomputed patch embeddings
(n=4096, d=1280) that the model projects and cross-attends to.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=5.0e5,
    cross_attn_every=5,       # 20 cross-attention layers
    n_frontend_tokens=4096,
    frontend="vision_stub",
    microbatch_per_device=1,
)
