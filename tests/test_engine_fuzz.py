"""Stateful differential fuzz harness over the serving engines.

A trace machine drives random request traces — mixed prompt lengths
sharing real-token heads (the mixed-length prefix sharing the radix
index exists for), staggered arrivals, forced preemptions /
migrations / demotions / mid-prefill KV handoffs / locality kills
and elastic re-joins (§4g chaos) — through the chunked engine under
a randomly chosen
``(kv_shards, tiering, prefix_cache_compute, disagg)`` configuration,
and asserts greedy token-identity against an ample-pool
single-locality reference after EVERY completion.  Hand-written parity tests cover
each mechanism alone; with four engines x sharding x tiering x
compute skip interacting, only model-based traces cover the product
of their state spaces.

Two drivers share the machine:

* ``EngineFuzz`` — a `hypothesis.stateful.RuleBasedStateMachine` (25
  trace programs in CI at a pinned ``--hypothesis-seed``).  Skipped
  when hypothesis is missing — and CI asserts via
  `tools/assert_no_skips.py` that it really ran, closing the
  importorskip silent-pass hole.
* ``test_trace_machine_deterministic`` — the same rule set driven by
  a fixed numpy RNG, one trace per configuration, so the harness is
  exercised even in environments without hypothesis.

Engines are cached per configuration across traces (JAX recompiles
per engine instance otherwise); every trace drains its engine and
verifies the pool is empty before the next reuses it, and retained
cold prefix pages deliberately survive between traces — warm-cache
admissions are part of the state space under test, and token identity
must hold regardless.
"""

import itertools
from functools import lru_cache

import numpy as np
import pytest
import jax

import repro.configs as configs
from repro.models import transformer as T
from repro.serving.engine import Request, make_engine

ARCH = "yi-6b"
SLOTS = 3
MAX_LEN = 96
PAGE = 16
CHUNK = 32
N_PAGES = 12          # pressure: 3 slots x 5-6 pages wants > 12
HOST_PAGES = 24
MAX_NEW = (1, 4, 8)   # 32+20 prompt + 8 <= MAX_LEN, so a
TAIL_LENS = (1, 5, 8, 12, 16, 20)    # re-admission never truncates
# shared real-token heads (0 = none); 16/24 share one page, 32 shares
# two — mixed TOTAL lengths behind a shared head are the traffic the
# position-normalized keys exist for (equal-length-only sharing was
# the §9.4 defect), so most (head, tail) draws differ in total length
# while hitting the same radix path
PREFIX_LENS = (0, 16, 24, 32)
N_VARIANTS = 3

CONFIGS = [
    {"kv_shards": s, "tiering": t, "prefix_cache_compute": p,
     "disagg": d}
    for s in (1, 2) for t in (False, True) for p in (False, True)
    for d in (False, True)
]

_rids = itertools.count(1000)
_ref_rids = itertools.count(-1000, -1)
_ref_tokens = {}                     # (prompt bytes, max_new) -> toks
_engines = {}                        # config index -> engine


@lru_cache(maxsize=1)
def _setup():
    cfg = configs.get_reduced(ARCH)
    return cfg, T.init_params(jax.random.PRNGKey(0), cfg)


@lru_cache(maxsize=1)
def _ref_engine():
    """Ample pages, one locality, no tiering, no compute skip: the
    ground truth a per-slot-clock engine must reproduce under any
    pressure/percolation/skip schedule."""
    cfg, params = _setup()
    return make_engine(params, cfg, engine="chunked", slots=SLOTS,
                       max_len=MAX_LEN, prefill_buckets=(32,),
                       page_size=PAGE, chunk_size=CHUNK, n_pages=24)


def _reference(prompt: np.ndarray, max_new: int):
    key = (prompt.tobytes(), max_new)
    if key not in _ref_tokens:
        eng = _ref_engine()
        fut = eng.submit(Request(next(_ref_rids), prompt,
                                 max_new_tokens=max_new))
        eng.run_to_completion()
        eng.completions.clear()
        _ref_tokens[key] = fut.get().tokens
    return _ref_tokens[key]


def _prompt(prefix_idx: int, tail_len: int, variant: int) -> np.ndarray:
    """Deterministic prompt content per parameter triple, so repeated
    draws share prefixes (and whole prompts) across traces — which is
    what makes the prefix cache and compute skip reachable."""
    cfg, _ = _setup()
    plen = PREFIX_LENS[prefix_idx]
    head = np.random.default_rng(97 + prefix_idx).integers(
        0, cfg.vocab_size, size=plen)
    tail = np.random.default_rng(
        1009 * tail_len + variant).integers(
        0, cfg.vocab_size, size=tail_len)
    return np.concatenate([head, tail]).astype(np.int32)


def _engine_for(idx: int):
    if idx not in _engines:
        cfg, params = _setup()
        kw = CONFIGS[idx]
        _engines[idx] = make_engine(
            params, cfg, engine="chunked", slots=SLOTS,
            max_len=MAX_LEN, prefill_buckets=(32,), page_size=PAGE,
            chunk_size=CHUNK, n_pages=N_PAGES,
            host_pages=HOST_PAGES if kw["tiering"] else 0, **kw)
    return _engines[idx]


class EngineTrace:
    """The machine body both drivers share: every mutation re-checks
    completed requests against the ample-pool reference."""

    def __init__(self, config_idx: int):
        self.config = CONFIGS[config_idx]
        self.eng = _engine_for(config_idx)
        if self.eng.active or self.eng.queue:
            # a previous failing trace left work behind; reclaim so
            # this trace starts clean (pages released, LCOs errored)
            self.eng._fail_pending(RuntimeError("fuzz trace reset"))
        pool = self.eng.kvc.pool
        for loc in range(pool.n_shards):
            if not pool.agas.is_active(loc):
                # a previous trace's kill left the shard retired;
                # elastic re-join so every trace starts full-strength
                self.eng.join_locality(loc)
        self.eng.recovery_budget.restarts = 0
        self.eng.completions.clear()
        self.expected = {}           # rid -> (future, ref tokens)
        self.checked = 0

    def submit(self, prefix_idx, tail_len, variant, max_new):
        prompt = _prompt(prefix_idx, tail_len, variant)
        rid = next(_rids)
        fut = self.eng.submit(Request(rid, prompt,
                                      max_new_tokens=max_new))
        self.expected[rid] = (fut, _reference(prompt, max_new))
        self._check()

    def step(self, n):
        for _ in range(n):
            self.eng.step()
        self._check()

    def preempt(self):
        """Force-preempt the youngest active request (the engine's own
        LIFO victim choice) between steps."""
        if self.eng.active:
            victim = max(self.eng.active,
                         key=lambda s: self.eng.active[s]["seq"])
            self.eng._preempt(victim)
        self._check()

    def migrate(self):
        if self.eng.kvc.pool.n_shards > 1:
            self.eng.force_migrate()

    def demote(self):
        if getattr(self.eng.kvc.pool, "tiered", False):
            self.eng.force_demote()

    def handoff(self):
        """Force mid-prefill KV handoffs (disagg engines only): every
        prefilling slot detaches into a snapshot and resumes chunking
        after the commit at the next step's top."""
        if hasattr(self.eng, "force_handoff"):
            self.eng.force_handoff()

    def kill(self):
        """Kill the highest active shard (§4g locality loss) with
        whatever is in flight — staged handoffs, offloaded snapshots,
        mid-prefill chunks included.  Every affected request must
        still finish token-identically via rebuild or re-prefill."""
        act = self.eng.kvc.pool.active_shards()
        if len(act) > 1:
            self.eng.kill_locality(act[-1])
        self._check()

    def join(self):
        """Elastically re-join the lowest retired shard (§4g)."""
        pool = self.eng.kvc.pool
        dead = [loc for loc in range(pool.n_shards)
                if not pool.agas.is_active(loc)]
        if dead:
            self.eng.join_locality(dead[0])
        self._check()

    def _check(self):
        for c in self.eng.completions[self.checked:]:
            if c.rid not in self.expected:
                continue             # another trace's leftover
            _, want = self.expected[c.rid]
            assert c.tokens == want, (
                f"rid {c.rid} diverged under {self.config}: "
                f"{c.tokens} != {want}")
        self.checked = len(self.eng.completions)

    def drain(self):
        self.eng.run_to_completion(max_steps=50000)
        self._check()
        for rid, (fut, want) in self.expected.items():
            assert fut.done(), f"rid {rid} never completed"
            assert fut.get().tokens == want    # .get raises on error
        assert self.eng.kvc.pool.used_pages == 0
        assert not self.eng.active and not self.eng.queue
        self.eng.completions.clear()
        self.checked = 0


# -- driver 1: deterministic numpy traces (no hypothesis needed) -------

@pytest.mark.parametrize("config_idx", range(len(CONFIGS)))
def test_trace_machine_deterministic(config_idx):
    rng = np.random.default_rng(100 + config_idx)
    t = EngineTrace(config_idx)
    for _ in range(14):
        op = rng.choice(["submit", "submit", "submit", "step",
                         "step", "preempt", "migrate", "demote",
                         "handoff", "kill", "join"])
        if op == "submit":
            t.submit(int(rng.integers(len(PREFIX_LENS))),
                     int(rng.choice(TAIL_LENS)),
                     int(rng.integers(N_VARIANTS)),
                     int(rng.choice(MAX_NEW)))
        elif op == "step":
            t.step(int(rng.integers(1, 4)))
        elif op == "preempt":
            t.preempt()
        elif op == "migrate":
            t.migrate()
        elif op == "handoff":
            t.handoff()
        elif op == "kill":
            t.kill()
        elif op == "join":
            t.join()
        else:
            t.demote()
    t.drain()


# -- driver 2: hypothesis stateful traces ------------------------------

try:
    from hypothesis import HealthCheck, settings
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     precondition, rule)
    HAVE_HYPOTHESIS = True
except ImportError:                  # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    class EngineFuzz(RuleBasedStateMachine):
        def __init__(self):
            super().__init__()
            self.t = None

        @initialize(idx=st.integers(0, len(CONFIGS) - 1))
        def setup(self, idx):
            self.t = EngineTrace(idx)

        @precondition(lambda self: self.t is not None)
        @rule(prefix_idx=st.integers(0, len(PREFIX_LENS) - 1),
              tail_len=st.sampled_from(TAIL_LENS),
              variant=st.integers(0, N_VARIANTS - 1),
              max_new=st.sampled_from(MAX_NEW))
        def submit_request(self, prefix_idx, tail_len, variant,
                           max_new):
            self.t.submit(prefix_idx, tail_len, variant, max_new)

        @precondition(lambda self: self.t is not None)
        @rule(n=st.integers(1, 3))
        def run_steps(self, n):
            self.t.step(n)

        @precondition(lambda self: self.t is not None)
        @rule()
        def force_preempt(self):
            self.t.preempt()

        @precondition(lambda self: self.t is not None)
        @rule()
        def force_migrate(self):
            self.t.migrate()

        @precondition(lambda self: self.t is not None)
        @rule()
        def force_demote(self):
            self.t.demote()

        @precondition(lambda self: self.t is not None)
        @rule()
        def force_handoff(self):
            self.t.handoff()

        @precondition(lambda self: self.t is not None)
        @rule()
        def kill_locality(self):
            self.t.kill()

        @precondition(lambda self: self.t is not None)
        @rule()
        def join_locality(self):
            self.t.join()

        def teardown(self):
            if self.t is not None:
                self.t.drain()

    TestEngineFuzz = EngineFuzz.TestCase
    TestEngineFuzz.settings = settings(
        max_examples=25, stateful_step_count=10, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
else:                                # keep the skip visible locally;
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_engine_fuzz_stateful():  # CI asserts it did NOT skip
        ...
