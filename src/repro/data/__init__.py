"""data subpackage."""
