"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus `# ...` context
lines).  Figures covered: 3 (granularity), 5 (cone), 6 (barrier
removal), 7 (strong scaling), 8 (wallclock/crossover), 9 (thread
overhead), the roofline table from the multi-pod dry-run, and the
paged-vs-dense serving comparison (serve_bench).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (fig3_granularity, fig5_cone, fig6_barrier,
                            fig7_scaling, fig8_wallclock,
                            fig9_overhead, roofline, serve_bench)

    print("name,us_per_call,derived")
    failures = 0
    for mod in (fig3_granularity, fig5_cone, fig6_barrier,
                fig7_scaling, fig8_wallclock, fig9_overhead,
                roofline, serve_bench):
        try:
            mod.run(verbose=True)
        except Exception:
            failures += 1
            print(f"# BENCH FAILED: {mod.__name__}")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
