"""Dynamic regridding: error flagging, region proposal, state transfer.

Paper, Sec. III: finer meshes are placed "where truncation error is
highest" and "the higher resolution meshes adjust accordingly" as the
pulse moves.  We flag on a shadow-truncation estimate (the standard
self-shadow proxy: second differences, scaled) plus a gradient
criterion, buffer the flags, and rebuild a single properly-nested
region per level — the shape of the paper's Fig 2 hierarchy.

Regridding happens BETWEEN dataflow windows: the task graph of a window
assumes static specs, and the regrid itself is an AGAS event (blocks
are allocated/freed/migrated in the directory).
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.amr import hierarchy as hi
from repro.amr.wave import H, WaveProblem, initial_data


def flag_cells(u: np.ndarray, dr: float, grad_threshold: float
               ) -> np.ndarray:
    """Truncation-error proxy flags on one level's proper data.

    chi's scaled second difference (the local truncation error of the
    second-order scheme scales with dr^2 * u'') plus first-difference
    magnitude; either crossing `grad_threshold` flags the cell.
    """
    chi = u[0]
    d1 = np.abs(np.gradient(chi, dr))
    d2 = np.abs(np.gradient(np.gradient(chi, dr), dr)) * dr
    return (d1 + d2) > grad_threshold


def propose_specs(states: Sequence[hi.LevelState], prob: WaveProblem,
                  grad_threshold: float, max_levels: int,
                  buffer_cells: int = 8) -> List[hi.LevelSpec]:
    """Rebuild the spec list from current data (single region per level)."""
    specs: List[hi.LevelSpec] = [
        hi.LevelSpec(0, 0, prob.n_points, True, True)]
    for l in range(1, max_levels):
        src = states[min(l - 1, len(states) - 1)]
        if src.spec.level != l - 1:
            break
        a, b = src.spec.proper_extent
        u = np.asarray(src.arr[:, a:b])
        flags = flag_cells(u, src.dr, grad_threshold * (2.0 ** (l - 1)))
        if not flags.any():
            break
        idx = np.nonzero(flags)[0]
        parent = specs[l - 1]
        lo_l = max(int(idx.min()) - buffer_cells, 0) + parent.lo
        hi_l = min(int(idx.max()) + buffer_cells + 1,
                   parent.n) + parent.lo
        # child coordinates (x2), alignment, nesting margins
        margin = hi.TAPER // 2 + H + 2
        c_lo = max(2 * lo_l, 2 * (parent.lo + margin))
        c_hi = min(2 * hi_l, 2 * (parent.hi - margin))
        left_phys = False
        right_phys = False
        if 2 * lo_l <= 2 * margin:          # touches the origin
            c_lo, left_phys = 0, True
        if 2 * hi_l >= 2 * parent.hi - 2 * margin:  # touches outer edge
            c_hi, right_phys = 2 * parent.hi - 1, True
        c_lo -= c_lo % 2
        if not right_phys:
            c_hi -= c_hi % 2
        if c_hi - c_lo < 4 * hi.TAPER:
            break
        specs.append(hi.LevelSpec(l, c_lo, c_hi - c_lo,
                                  left_phys, right_phys))
    hi.validate_specs(specs, prob.n_points)
    return specs


def transfer(states: Sequence[hi.LevelState],
             new_specs: Sequence[hi.LevelSpec],
             prob: WaveProblem) -> List[hi.LevelState]:
    """Build states on new specs: copy overlaps, prolongate the rest.

    Processes coarsest-to-finest so each child can prolongate from its
    already-transferred parent.
    """
    old_by_level = {s.spec.level: s for s in states}
    out: List[hi.LevelState] = []
    for spec in new_specs:
        dr_l = prob.dr / (2 ** spec.level)
        r = (spec.arr_lo + jnp.arange(spec.width,
                                      dtype=prob.jnp_dtype())) * dr_l
        if spec.level == 0:
            st0 = old_by_level[0]
            out.append(hi.LevelState(spec, st0.arr, r,
                                     spec.full_extent, dr_l))
            continue
        parent = out[spec.level - 1]
        # Start from parent prolongation everywhere...
        tmp_child = hi.LevelState(
            spec, jnp.zeros((3, spec.width), prob.jnp_dtype()), r,
            spec.full_extent, dr_l)
        vals = hi.prolongate_band(parent, tmp_child, 0, spec.width)
        arr = vals
        # ...then overwrite with old same-level data where it overlaps.
        old = old_by_level.get(spec.level)
        if old is not None:
            ol, oh = old.spec.proper_extent
            old_lo_l = old.spec.a2l(ol)
            old_hi_l = old.spec.a2l(oh)
            lo_l = max(old_lo_l, spec.a2l(0))
            hi_l = min(old_hi_l, spec.a2l(spec.width))
            if hi_l > lo_l:
                src = old.arr[:, old.spec.l2a(lo_l):old.spec.l2a(hi_l)]
                arr = arr.at[:, spec.l2a(lo_l):spec.l2a(hi_l)].set(src)
        out.append(hi.LevelState(spec, arr, r, spec.full_extent, dr_l))
    return out
