"""Granularity control + hypothesis invariants on the AMR system."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import amr
from repro.amr import taskgraph as tg
from repro.core import GrainModel, list_schedule, n_tasks
from repro.core.granularity import (auto_tune, efficiency,
                                    optimal_grain_analytic, sweep)


def _build(prob, specs, n_workers):
    def f(g):
        wg = tg.build_window_graph(specs, 2, g)
        tg.assign_owners(wg, n_workers)
        return list_schedule(wg.graph, n_workers, overhead=4e-6)
    return f


def test_grain_sweep_has_interior_optimum():
    """Paper Fig 3: an optimal grain exists between the extremes."""
    prob = amr.WaveProblem(n_points=256, rmax=20.0, amplitude=0.005)
    specs = amr.default_specs(prob, 2)
    f = _build(prob, specs, 8)
    grains = [2, 4, 8, 16, 64, 256]
    pts = sweep(grains, f)
    spans = {p.grain: p.makespan for p in pts}
    best = auto_tune(grains, f)
    assert spans[best] <= spans[2] and spans[best] <= spans[256]
    # extremes are penalized: tiny grains by overhead, huge by idling
    assert pts[0].overhead_fraction > pts[-1].overhead_fraction
    assert pts[-1].idle_fraction > pts[2].idle_fraction


def test_optimal_grain_weakly_depends_on_workers():
    """Paper: 'the optimal grain size does not seem to depend heavily
    on the number of cores requested' (Fig 3)."""
    prob = amr.WaveProblem(n_points=256, rmax=20.0, amplitude=0.005)
    specs = amr.default_specs(prob, 2)
    grains = [4, 8, 16, 32, 64]
    bests = [auto_tune(grains, _build(prob, specs, p)) for p in
             (4, 8, 16)]
    assert max(bests) / max(min(bests), 1) <= 4


def test_analytic_grain_model():
    m = GrainModel(c_point=1e-6, sigma=4e-6)
    g = optimal_grain_analytic(4096, 8, m)
    assert 1 <= g <= 4096
    assert efficiency(m, 1) < efficiency(m, g) < 1.0


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(1, 16))
def test_n_tasks_covers_domain(n_points, g):
    nt = n_tasks(n_points, g)
    assert (nt - 1) * g < n_points <= nt * g


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 4), st.sampled_from([8, 16, 32]),
       st.integers(1, 3))
def test_window_graph_invariants(levels, grain, n_coarse):
    """Structural invariants of the dataflow graph for random configs."""
    prob = amr.WaveProblem(n_points=128, rmax=20.0, amplitude=0.005)
    specs = amr.default_specs(prob, levels)
    wg = tg.build_window_graph(specs, n_coarse, grain)
    g = wg.graph
    g.topo_order()                                 # acyclic
    # step-task count: every level runs n_coarse * 2^l substeps
    for l, spec in enumerate(wg.specs):
        nb = len(wg.blocks[l])
        steps = [m for m in wg.meta
                 if m.kind == "step" and m.level == l]
        assert len(steps) == nb * n_coarse * 2 ** l
    # every non-initial step task depends on its own previous substep
    for tid, m in enumerate(wg.meta):
        if m.kind == "step" and m.index > 0:
            deps = {wg.meta[d].kind for d in g.tasks[tid].deps}
            assert deps, f"step task {tid} has no deps"


def test_front_bounded_by_causality():
    """No point can be more than n_coarse steps ahead; front >= 0."""
    prob = amr.WaveProblem(n_points=128, rmax=20.0, amplitude=0.005)
    specs = amr.default_specs(prob, 2)
    wg = tg.build_window_graph(specs, 3, 16)
    tg.assign_owners(wg, 4)
    r = list_schedule(wg.graph, 4, overhead=1e-6)
    for frac in (0.25, 0.5, 1.0):
        front = tg.timestep_front(wg, r.finish, r.makespan * frac,
                                  prob.n_points)
        assert front.min() >= 0
        assert front.max() <= 3 + 1e-9
    full = tg.timestep_front(wg, r.finish, r.makespan + 1,
                             prob.n_points)
    np.testing.assert_allclose(full, 3.0)   # everything finished
