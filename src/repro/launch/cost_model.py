"""Analytic FLOP / HBM-traffic model per (arch x shape x mesh).

Why analytic: XLA's `cost_analysis()` counts while-loop bodies once, so
scan-built programs (all of ours) under-report by the trip factors
(measured: yi-6b train_4k reports 8e11 flops vs the true ~5e16).  The
collective term IS taken from the compiled HLO exactly (trip-weighted
parse, launch/hlo_parse.py); compute and memory come from the formulas
below, which are exact for the matmul-dominated terms and carry stated
approximations for activation traffic.  EXPERIMENTS.md §Roofline
documents this methodology.

Conventions:
  train   full remat: fwd(2) + recompute(2) + bwd(4) = 8 flops per
          matmul param per token; attention/scan factor 4x forward.
  prefill forward only: 2 flops/param/token; attention 1x forward.
  decode  2 flops/param/new-token + cache streaming.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.models.config import ArchConfig, ShapeConfig

TRAIN_MM = 8.0        # fwd + remat-recompute + bwd
FWD_MM = 2.0
ATTN_TRAIN = 4.0      # x forward attention flops
ACT_ALPHA = 12.0      # residual-stream HBM touches per layer (approx)


def _embed_params(arch: ArchConfig) -> int:
    return arch.vocab_size * arch.d_model * \
        (1 if arch.tie_embeddings else 2)


def _matmul_params(arch: ArchConfig) -> int:
    """Active matmul params per token (excludes embeddings/norms)."""
    return arch.active_param_count() - _embed_params(arch)


def _attn_flops_fwd_per_token(arch: ArchConfig, s: int) -> float:
    """Score+PV flops per token per attention layer (forward)."""
    if arch.n_heads == 0:
        return 0.0
    s_eff = min(s, arch.sliding_window) if arch.sliding_window else s
    if not arch.sliding_window:
        s_eff = s / 2.0           # causal
    hd = arch.head_dim
    if arch.family == "hybrid":
        hd = 2 * arch.d_model // arch.n_heads
    return 4.0 * s_eff * arch.n_heads * hd


def _n_attn_layers(arch: ArchConfig) -> float:
    if arch.family == "ssm":
        return 0
    if arch.family == "hybrid":
        return arch.n_layers // arch.shared_attn_every
    return arch.n_layers


def _scan_flops_fwd_per_token(arch: ArchConfig) -> float:
    if arch.family not in ("ssm", "hybrid"):
        return 0.0
    return 10.0 * arch.d_inner * arch.ssm_state * arch.n_layers


def _moe_dispatch_flops_fwd(arch: ArchConfig, tokens: float,
                            tp: int) -> float:
    if arch.family != "moe":
        return 0.0
    gs = arch.moe_group_size
    per_tok = 2 * (tp * arch.top_k * arch.capacity_factor * gs) * \
        arch.d_model
    return 2.0 * per_tok * tokens        # dispatch + combine einsums


@dataclasses.dataclass
class AnalyticCosts:
    flops_total: float          # whole step, all chips
    hbm_bytes_per_chip: float
    model_flops: float          # useful 6ND / 2ND
    breakdown: Dict[str, float]

    def to_dict(self) -> dict:
        return {"flops_total": self.flops_total,
                "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
                "model_flops": self.model_flops,
                "breakdown": self.breakdown}


def analytic_costs(arch: ArchConfig, shape: ShapeConfig, n_chips: int,
                   dp: int, tp_moe: int = 1,
                   n_accum: int = 1) -> AnalyticCosts:
    T = float(shape.tokens())
    B, S = shape.global_batch, shape.seq_len
    P_mm = float(_matmul_params(arch))
    P_all = float(arch.param_count())
    E_p = float(_embed_params(arch))
    V, D = arch.vocab_size, arch.d_model
    bk: Dict[str, float] = {}

    if shape.kind == "train":
        bk["matmul"] = TRAIN_MM * P_mm * T
        bk["head"] = TRAIN_MM * V * D * T
        bk["attention"] = ATTN_TRAIN * _attn_flops_fwd_per_token(
            arch, S) * _n_attn_layers(arch) * T
        bk["ssm_scan"] = ATTN_TRAIN * _scan_flops_fwd_per_token(arch) * T
        bk["moe_dispatch"] = ATTN_TRAIN / 2 * _moe_dispatch_flops_fwd(
            arch, T, tp_moe)
        model_flops = 6.0 * arch.active_param_count() * T

        p_bytes = 2.0 * P_all / n_chips
        bk_mem = {
            # weights: fwd + bwd + remat-recompute reads per microbatch
            "weights": 3.0 * p_bytes * n_accum,
            # f32 grad accumulation read+write per microbatch + opt read
            "grad_accum": (2.0 * 4.0 * P_all / n_chips) * n_accum,
            # optimizer: read p,m,v + write p,m,v (m,v f32)
            "optimizer": (2 + 4 + 4 + 2 + 4 + 4) * P_all / n_chips,
            # activations: residual stream traffic, ACT_ALPHA touches
            "activations": ACT_ALPHA * (T / n_chips) * D * 2.0 *
                           arch.n_layers / max(n_accum, 1) * n_accum,
            # attention KV streaming (flash passes over K,V)
            "attn_kv": 3.0 * _n_attn_layers(arch) * (T / n_chips) *
                       2 * arch.n_kv_heads * arch.head_dim * 2.0,
        }
    elif shape.kind == "prefill":
        bk["matmul"] = FWD_MM * P_mm * T
        bk["head"] = FWD_MM * V * D * B      # last position only
        bk["attention"] = _attn_flops_fwd_per_token(arch, S) * \
            _n_attn_layers(arch) * T
        bk["ssm_scan"] = _scan_flops_fwd_per_token(arch) * T
        bk["moe_dispatch"] = _moe_dispatch_flops_fwd(arch, T, tp_moe) / 2
        model_flops = 2.0 * arch.active_param_count() * T
        bk_mem = {
            "weights": 2.0 * P_all / n_chips,
            "activations": ACT_ALPHA * (T / n_chips) * D * 2.0 *
                           arch.n_layers,
            "cache_write": _cache_bytes(arch, shape) / n_chips,
        }
    else:  # decode
        bk["matmul"] = FWD_MM * P_mm * B
        bk["head"] = FWD_MM * V * D * B
        # attention over the whole cache, once per new token
        bk["attention"] = _attn_flops_fwd_per_token(arch, S) * 2 * \
            _n_attn_layers(arch) * B
        bk["ssm_scan"] = _scan_flops_fwd_per_token(arch) * B
        bk["moe_dispatch"] = 0.0
        model_flops = 2.0 * arch.active_param_count() * B
        bk_mem = {
            "weights": 2.0 * P_all / n_chips,
            # read the whole cache once; write one new token's worth
            "cache_read": _cache_bytes(arch, shape) / n_chips,
            "activations": 4.0 * (B / n_chips) * D * 2.0 *
                           arch.n_layers,
        }

    flops = float(sum(bk.values()))
    hbm = float(sum(bk_mem.values()))
    bk.update({f"mem_{k}": v for k, v in bk_mem.items()})
    return AnalyticCosts(flops, hbm, model_flops, bk)


def _cache_bytes(arch: ArchConfig, shape: ShapeConfig) -> float:
    """Total decode-state bytes across the batch."""
    B, S = shape.global_batch, shape.seq_len
    eff = min(S, arch.sliding_window) if arch.sliding_window else S
    total = 0.0
    if arch.family in ("dense", "audio", "moe", "vlm"):
        n_attn = arch.n_layers
        if arch.family == "vlm":
            n_attn -= arch.n_layers // arch.cross_attn_every
        total += 2.0 * n_attn * B * eff * arch.n_kv_heads * \
            arch.head_dim * 2.0
    if arch.family == "hybrid":
        n_sh = arch.n_layers // arch.shared_attn_every
        wide_hd = 2 * arch.d_model // arch.n_heads
        total += 2.0 * n_sh * B * eff * arch.n_kv_heads * wide_hd * 2.0
        nh = arch.d_inner // arch.ssm_head_dim
        total += arch.n_layers * B * nh * arch.ssm_head_dim * \
            arch.ssm_state * 4.0
    if arch.family == "ssm":
        total += arch.n_layers * B * arch.d_inner * arch.ssm_state * 4.0
        total += arch.n_layers * B * (arch.ssm_conv - 1) * \
            arch.d_inner * 2.0
    return total
