"""Sharded AGAS page pool (DESIGN.md §4c): locality-aware allocation,
(locality, slot) row encoding, migration name-stability, greedy-decode
parity across shard counts and across forced migrations, and the
device-backed mesh path (subprocess, 8 forced host devices)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import transformer as T
from repro.serving.engine import ChunkedPagedServingEngine, Request
from repro.serving.kvcache import PageExhausted, PagePool

RNG = np.random.default_rng(17)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(name="yi-6b"):
    return configs.get_reduced(name)


# -- the sharded allocator ---------------------------------------------

def test_pool_least_loaded_alloc_and_row_encoding():
    pool = PagePool(_cfg(), n_pages=8, page_size=4, n_shards=2)
    assert pool.pages["k"].shape[1:3] == (2, 5)      # (S, R)
    addrs = [pool.alloc() for _ in range(6)]
    # least-loaded-first keeps the shards balanced as allocs arrive
    assert pool.shard_used() == [3, 3]
    for a in addrs:
        loc, slot = pool.agas.lookup(a)
        assert pool.row(a) == loc * pool.rows_per_shard + slot
        assert slot < pool.pages_per_shard       # never the null slot
    # global free count stays the admission signal
    assert pool.free_pages == 2
    [pool.alloc() for _ in range(2)]
    with pytest.raises(PageExhausted):
        pool.alloc()


def test_pool_rejects_indivisible_shard_count():
    with pytest.raises(ValueError, match="multiple"):
        PagePool(_cfg(), n_pages=10, page_size=4, n_shards=3)


def test_migration_keeps_global_name_and_moves_content():
    cfg = _cfg()
    pool = PagePool(cfg, n_pages=4, page_size=4, n_shards=2)
    addr = pool.alloc()
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    span = jnp.full((L, 1, 4, kvh, hd), 7.0, pool.pages["k"].dtype)
    pool.write_pages([pool.row(addr)], span, span)
    gid, row0 = addr.gid, pool.row(addr)
    src = pool.agas.locality_of(addr)
    pool.migrate_pages({addr: 1 - src})
    # the AGAS promise: the name survives, only (locality, slot) moved
    assert addr.gid == gid
    assert pool.agas.locality_of(addr) == 1 - src
    assert pool.row(addr) != row0
    loc, slot = pool.agas.lookup(addr)
    got = np.asarray(pool.pages["k"])[0, loc, slot]
    np.testing.assert_array_equal(got, 7.0)
    assert pool.page_migrations == 1


def test_plan_rebalance_moves_only_unpinned_pages():
    pool = PagePool(_cfg(), n_pages=12, page_size=4, n_shards=2)
    # skew shard 0 with explicit-locality allocations
    skew = [pool.alloc(0) for _ in range(5)]
    pool.incref(skew[0])                 # shared -> pinned to owner
    assert pool.shard_used() == [5, 0]
    moves = pool.plan_rebalance(tolerance=1)
    assert skew[0] not in moves          # prefix-shared pages stay put
    pool.migrate_pages(moves)
    used = pool.shard_used()
    assert max(used) - min(used) <= 1
    assert pool.agas.locality_of(skew[0]) == 0


# -- kernels on the sharded layout -------------------------------------

@pytest.mark.parametrize("window", [0, 6])
def test_kernels_sharded_layout_matches_flat(window):
    """The (S, R, ps, KV, D) layout with locality*R+slot rows must
    reproduce the flat (N, ps, KV, D) layout bit for bit — in the jnp
    oracles and in the Pallas kernels."""
    from repro.kernels.attention.ops import (paged_attention,
                                             paged_prefill_attention)
    from repro.kernels.attention.ref import (
        paged_attention_ref, paged_prefill_attention_ref)
    b, h, kvh, d, ps, S, R = 3, 4, 2, 16, 8, 2, 5
    kp = jnp.asarray(RNG.normal(size=(S, R, ps, kvh, d)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(S, R, ps, kvh, d)), jnp.float32)
    kp_f, vp_f = (x.reshape(S * R, ps, kvh, d) for x in (kp, vp))
    tables = jnp.asarray(RNG.integers(0, S * R, size=(b, 4)), jnp.int32)
    pos = jnp.asarray([3, 17, 30], jnp.int32)
    q = jnp.asarray(RNG.normal(size=(b, 1, h, d)), jnp.float32)
    ref = paged_attention_ref(q, kp_f, vp_f, tables, pos, window=window)
    got_ref = paged_attention_ref(q, kp, vp, tables, pos, window=window)
    np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(ref))
    got_pl = paged_attention(q, kp, vp, tables, pos, window=window)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(ref),
                               atol=1e-5)
    qq = jnp.asarray(RNG.normal(size=(b, 8, h, d)), jnp.float32)
    start = jnp.asarray([0, 8, 21], jnp.int32)
    pref = paged_prefill_attention_ref(qq, kp_f, vp_f, tables, start,
                                       window=window)
    pgot = paged_prefill_attention_ref(qq, kp, vp, tables, start,
                                       window=window)
    np.testing.assert_array_equal(np.asarray(pgot), np.asarray(pref))
    ppl = paged_prefill_attention(qq, kp, vp, tables, start,
                                  window=window)
    np.testing.assert_allclose(np.asarray(ppl), np.asarray(pref),
                               atol=1e-5)


# -- engine parity across shard counts and migrations ------------------

def _parity_requests(cfg, seed=3):
    rng = np.random.default_rng(seed)
    lens = [5, 40, 20, 12]               # < 1 page and > 1 chunk
    return [Request(rid, rng.integers(0, cfg.vocab_size, size=n)
                    .astype(np.int32), max_new_tokens=6)
            for rid, n in enumerate(lens)]


_KW = dict(slots=4, max_len=96, prefill_buckets=(64,), page_size=16,
           chunk_size=32)


def _run_engine(params, cfg, reqs, **kw):
    eng = ChunkedPagedServingEngine(params, cfg, **_KW, **kw)
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    return eng, {c.rid: c.tokens for c in eng.completions}


def test_greedy_parity_across_shard_counts():
    """Greedy decode is token-identical for n_shards in {1, 2, 4}: the
    shard layout relocates pages, never changes what a slot attends.
    (Same separately-compiled-executables seed caveat as the other
    parity tests.)"""
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _parity_requests(cfg)
    results = {}
    for ns in (1, 2, 4):
        eng, toks = _run_engine(params, cfg, reqs, kv_shards=ns)
        results[ns] = toks
        assert eng.kvc.pool.used_pages == 0
        s = eng.stats()
        assert s["kv_shards"] == ns
        assert len(s["shard_pages_used"]) == ns
    assert results[1] == results[2] == results[4]


def test_forced_mid_decode_migration_preserves_outputs():
    """Rotate every movable page to the next shard mid-decode: block
    tables re-resolve through the directory and every affected
    request's output is unchanged — the end-to-end rendering of the
    name-stability promise."""
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _parity_requests(cfg)
    _, baseline = _run_engine(params, cfg, reqs)
    eng = ChunkedPagedServingEngine(params, cfg, kv_shards=4, **_KW)
    futs = [eng.submit(r) for r in reqs]
    for _ in range(3):
        eng.step()                      # prompts resident, mid-decode
    assert eng.active
    moved = eng.force_migrate()
    assert moved > 0
    eng.run_to_completion()
    assert {c.rid: c.tokens for c in eng.completions} == baseline
    s = eng.stats()
    assert s["page_migrations"] >= moved
    for r, f in zip(reqs, futs):
        assert f.done() and f.get().rid == r.rid


def test_imbalance_triggers_rebalance_between_steps():
    """Pool-imbalance-triggered migration: skewing the shards past the
    tolerance makes the next step() migrate pages — and the trace's
    outputs stay identical to an undisturbed run."""
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _parity_requests(cfg)
    _, baseline = _run_engine(params, cfg, reqs)
    eng = ChunkedPagedServingEngine(params, cfg, kv_shards=2,
                                    rebalance_tolerance=2, **_KW)
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    # skew shard 0 well past the tolerance with held pages
    held = [eng.kvc.pool.alloc(0) for _ in range(6)]
    assert eng.kvc.pool.page_migrations == 0
    eng.step()                           # rebalances before admitting
    assert eng.kvc.pool.page_migrations > 0
    eng.run_to_completion()
    assert {c.rid: c.tokens for c in eng.completions} == baseline
    for a in held:
        eng.kvc.pool.decref(a)
    assert eng.kvc.pool.used_pages == 0


def test_stats_report_per_shard_occupancy_mid_run():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ChunkedPagedServingEngine(params, cfg, kv_shards=2, **_KW)
    for r in _parity_requests(cfg):
        eng.submit(r)
    for _ in range(3):
        eng.step()
    s = eng.stats()
    pool = eng.kvc.pool
    assert sum(s["shard_pages_used"]) == pool.used_pages > 0
    assert len(s["shard_occupancy"]) == 2
    assert all(0.0 <= o <= 1.0 for o in s["shard_occupancy"])
    eng.run_to_completion()
    assert sum(eng.stats()["shard_pages_used"]) == 0


# -- the device-backed mesh path (8 forced host devices) ---------------

def run_sub(code: str, timeout=900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_mesh_backed_shards_parity_and_ppermute_migration():
    """One locality per device along the "kv" mesh axis: the page
    arrays carry a NamedSharding over 8 simulated host devices, forced
    migration executes as lax.ppermute legs under shard_map, and greedy
    outputs match the single-locality engine token for token."""
    out = run_sub("""
        import numpy as np, jax
        import repro.configs as configs
        from repro.models import transformer as T
        from repro.serving.engine import (ChunkedPagedServingEngine,
                                          Request)
        from repro.distributed.sharding import kv_pool_mesh

        cfg = configs.get_reduced('yi-6b')
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(3)
        reqs = [Request(rid, rng.integers(0, cfg.vocab_size, size=n)
                        .astype(np.int32), max_new_tokens=6)
                for rid, n in enumerate([5, 40, 20, 12])]
        kw = dict(slots=4, max_len=96, prefill_buckets=(64,),
                  page_size=16, chunk_size=32)

        base = ChunkedPagedServingEngine(params, cfg, **kw)
        for r in reqs: base.submit(r)
        base.run_to_completion()
        ref = {c.rid: c.tokens for c in base.completions}

        mesh = kv_pool_mesh(4)
        assert mesh is not None and mesh.shape['kv'] == 4
        eng = ChunkedPagedServingEngine(params, cfg, kv_shards=4,
                                        mesh=mesh, **kw)
        spec = eng.kvc.pool.pages['k'].sharding.spec
        assert spec[1] == 'kv', spec     # locality axis on the mesh
        for r in reqs: eng.submit(r)
        for _ in range(3): eng.step()
        moved = eng.force_migrate()      # lax.ppermute under shard_map
        assert moved > 0
        eng.run_to_completion()
        got = {c.rid: c.tokens for c in eng.completions}
        assert got == ref
        s = eng.stats()
        assert s['page_migrations'] >= moved
        assert len(s['shard_occupancy']) == 4
        print('MESH_SHARDED_OK', moved)
    """)
    assert "MESH_SHARDED_OK" in out
