"""HLO-text analysis: collective bytes + roofline terms.

cost_analysis() gives FLOPs and bytes-accessed; collective traffic is
not in there, so we parse the optimized HLO (`compiled.as_text()`) and
sum operand bytes of every communication op, weighted by the algorithm
factor of each collective (ring all-reduce moves ~2x the shard bytes,
all-gather/reduce-scatter ~1x, etc.).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
    "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
    "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# Bytes moved on the wire per shard-byte of output/input, ring algos.
_ALGO_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "collective-broadcast": 1.0,
    "ragged-all-to-all": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nbytes
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]          # raw operand bytes per shard
    wire_bytes: float                      # algo-weighted on-the-wire

    @property
    def total_ops(self) -> int:
        return sum(self.counts.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = defaultdict(int)
    byts: Dict[str, int] = defaultdict(int)
    wire = 0.0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*\)|\S+)\s+"
                     r"([\w\-]+)\(", ls)
        if not m:
            continue
        out_shape, opname = m.group(1), m.group(2)
        kind = None
        for k in _COLLECTIVE_KINDS:
            if opname == k or opname.startswith(k + "-"):
                kind = k
                break
        if kind is None:
            continue
        if opname.endswith("-done"):        # async pair: count at start
            continue
        b = _shape_bytes(out_shape)
        counts[kind] += 1
        byts[kind] += b
        wire += b * _ALGO_FACTOR[kind]
    return CollectiveStats(dict(counts), dict(byts), wire)


@dataclasses.dataclass
class Roofline:
    flops: float                 # total HLO flops (whole program)
    hbm_bytes: float             # bytes accessed (whole program)
    wire_bytes: float            # algo-weighted collective bytes/shard
    n_chips: int
    model_flops: float           # 6*N*D useful flops
    kind: str = "train"          # train|prefill|decode|amr

    @property
    def t_compute(self) -> float:
        return self.flops / (self.n_chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.n_chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        # wire_bytes is per-shard traffic; each chip drives ~3 usable
        # ICI links on a v5e 2D torus in practice -> 3x link bw.
        return self.wire_bytes / (3 * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_fraction(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def ideal_time(self) -> float:
        """The unavoidable lower bound for this step kind.

        train/prefill: useful flops at peak MXU.  decode: the analytic
        HBM floor (weights + cache must stream once per token) — a
        decode step is memory-bound by construction, so grading it
        against the compute roof would be meaningless.
        """
        if self.kind == "decode":
            return self.hbm_bytes / (self.n_chips * HBM_BW)
        return self.model_flops / (self.n_chips * PEAK_FLOPS_BF16)

    @property
    def roofline_fraction(self) -> float:
        """(ideal lower bound) / (bound time): the §Perf score."""
        return self.ideal_time / self.bound_time if self.bound_time \
            else 0.0

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes, "n_chips": self.n_chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flop_fraction": self.useful_flop_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(arch, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode counts 1 token/seq,
    prefill counts forward only (2*N*D)."""
    n = arch.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens()
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens()
    # decode: one new token per sequence (+ attention over the cache,
    # excluded from the useful-flops definition by convention)
    return 2.0 * n * shape.global_batch
