"""Two-tier percolation serving (DESIGN.md §4d): offload/restore
round-trips, greedy parity with tiering on vs off, the forced-eviction
torture drill, prefix-cache spill, and copy/compute overlap."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import transformer as T
from repro.serving.engine import Request, make_engine
from repro.serving.kvcache import PagedKVCache, PageExhausted
from repro.serving.tiering import TieredPagePool

RNG = np.random.default_rng(23)


def _cfg(name="yi-6b"):
    return configs.get_reduced(name)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, lens, max_new=10, rid0=0, prefix=None):
    out = []
    for i, n in enumerate(lens):
        toks = RNG.integers(0, cfg.vocab_size, size=n).astype(np.int32)
        if prefix is not None:
            toks = np.concatenate([prefix, toks]).astype(np.int32)
        out.append(Request(rid0 + i, toks, max_new_tokens=max_new))
    return out


def _serve(eng, reqs, **rtc):
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion(**rtc)
    return {c.rid: c.tokens for c in eng.completions}


# -- kvcache offload / restore round trip ------------------------------

def test_offload_restore_roundtrip_bytes_and_state():
    cfg = _cfg()
    kvc = PagedKVCache(cfg, slots=2, max_len=64, n_pages=4,
                       page_size=16, host_pages=8)
    padded = RNG.integers(0, 100, size=40).astype(np.int32)
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    k = jnp.asarray(RNG.normal(size=(L, 40, kvh, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(L, 40, kvh, hd)), jnp.float32)
    kvc.attach(0, padded, k, v)
    rows_before = [kvc.pool.row(a) for a in kvc._state[0].addrs]
    content = np.asarray(kvc.pool.pages["k"])[:, rows_before].copy()
    tables_before = kvc.tables[0].copy()

    snap = kvc.offload_slot(0)
    assert snap is not None and len(snap.addrs) == 3
    assert snap.length == 40
    # slot is empty and reusable; the pages live on host, refcounted
    assert kvc.lengths[0] == 0
    assert all(not kvc.pool.on_device(a) for a in snap.addrs)
    assert all(kvc.pool.refcount(a) == 1 for a in snap.addrs)
    assert kvc.pool.host_used == 3
    assert kvc.pool.device_free_rows == 4

    kvc.restore_slot(0, snap)
    assert kvc.lengths[0] == 40
    rows_after = [kvc.pool.row(a) for a in kvc._state[0].addrs]
    got = np.asarray(kvc.pool.pages["k"])[:, rows_after]
    np.testing.assert_array_equal(got, content)   # byte-identical
    # names never changed, so the block table re-resolves consistently
    assert [a.gid for a in snap.addrs] == \
        [a.gid for a in kvc._state[0].addrs]
    np.testing.assert_array_equal(
        kvc.tables[0][:3],
        [kvc.pool.row(a) for a in snap.addrs])
    assert len(tables_before) == len(kvc.tables[0])
    kvc.release(0)


def test_handoff_detach_restore_cross_slot_bytes_and_state():
    """The §4f prefill->decode handoff unit: detach a finished slot's
    KV into a snapshot and restore it into a DIFFERENT slot (the
    decode worker's), asserting no page moves, no refcount changes,
    and byte-identity across the worker roles.  Works untiered —
    unlike offload, a handoff never crosses tiers."""
    cfg = _cfg()
    kvc = PagedKVCache(cfg, slots=2, max_len=64, n_pages=6,
                       page_size=16)
    padded = RNG.integers(0, 100, size=40).astype(np.int32)
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    k = jnp.asarray(RNG.normal(size=(L, 40, kvh, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(L, 40, kvh, hd)), jnp.float32)
    kvc.attach(0, padded, k, v)
    rows_before = [kvc.pool.row(a) for a in kvc._state[0].addrs]
    content = np.asarray(kvc.pool.pages["k"])[:, rows_before].copy()
    used_before = kvc.pool.used_pages

    snap = kvc.detach_slot(0)
    assert snap is not None and len(snap.addrs) == 3
    assert snap.length == 40
    # the prefill slot is empty and reusable; the pages NEVER moved —
    # the snapshot holds their refcounts, so nothing could evict them
    assert kvc.lengths[0] == 0
    assert kvc.pool.used_pages == used_before
    assert all(kvc.pool.refcount(a) == 1 for a in snap.addrs)

    kvc.restore_slot(1, snap)            # the decode worker's slot
    assert kvc.lengths[1] == 40
    rows_after = [kvc.pool.row(a) for a in kvc._state[1].addrs]
    got = np.asarray(kvc.pool.pages["k"])[:, rows_after]
    np.testing.assert_array_equal(got, content)   # byte-identical
    # global names survived the handoff; the receiving slot's block
    # table re-resolves them to the same physical rows
    assert [a.gid for a in snap.addrs] == \
        [a.gid for a in kvc._state[1].addrs]
    assert rows_after == rows_before
    np.testing.assert_array_equal(
        kvc.tables[1][:3], [kvc.pool.row(a) for a in snap.addrs])
    kvc.release(1)
    assert kvc.pool.used_pages == 0


def test_handoff_mid_prefill_chunk_boundary_roundtrip():
    """A handoff staged at a chunk boundary mid-prefill: detach after
    two chunks, restore into another slot, and RESUME chunking there —
    the snapshot's hash chain and position clock must satisfy
    `begin_chunk`'s resume contract exactly, and the pre-handoff pages
    must stay byte-identical under the new slot."""
    cfg = _cfg()
    ps = 16
    kvc = PagedKVCache(cfg, slots=2, max_len=64, n_pages=8,
                       page_size=ps)
    layout = RNG.integers(0, cfg.vocab_size, size=48).astype(np.int32)
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim

    def spans(n_rows, seed):
        r = np.random.default_rng(seed)
        return (jnp.asarray(r.normal(size=(L, n_rows, ps, kvh, hd)),
                            jnp.float32),
                jnp.asarray(r.normal(size=(L, n_rows, ps, kvh, hd)),
                            jnp.float32))

    rows1, _ = kvc.begin_chunk(0, layout, 0, 32)     # chunks 1+2
    assert len(rows1) == 2
    kvc.pool.write_pages(rows1, *spans(2, 7))
    content = np.asarray(kvc.pool.pages["k"])[:, rows1].copy()
    gids = [a.gid for a in kvc._state[0].addrs]

    snap = kvc.detach_slot(0)                        # chunk boundary
    assert snap.length == 32 and snap.chain is not None
    kvc.restore_slot(1, snap)
    # resume the remaining chunk IN THE RECEIVING SLOT: begin_chunk
    # validates start == resident length and extends the restored
    # chain (a wrong round-trip raises or breaks prefix keys)
    rows2, _ = kvc.begin_chunk(1, layout, 32, 48)
    assert len(rows2) == 1
    kvc.pool.write_pages(rows2, *spans(1, 11))
    assert kvc.lengths[1] == 48
    assert [a.gid for a in kvc._state[1].addrs[:2]] == gids
    got = np.asarray(kvc.pool.pages["k"])[
        :, [kvc.pool.row(a) for a in kvc._state[1].addrs[:2]]]
    np.testing.assert_array_equal(got, content)
    kvc.release(1)
    assert kvc.pool.used_pages == 0


def test_offload_keeps_shared_pages_on_device():
    """A preempted request's prefix-shared pages stay put (pinned by
    the other holder); only exclusive pages are written back."""
    cfg = _cfg()
    kvc = PagedKVCache(cfg, slots=2, max_len=64, n_pages=6,
                       page_size=16, host_pages=8)
    padded = RNG.integers(0, 100, size=32).astype(np.int32)
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    z = jnp.zeros((L, 32, kvh, hd), jnp.float32)
    kvc.attach(0, padded, z, z)
    kvc.attach(1, padded, z, z)          # shares both pages
    assert kvc.pool.shares == 2
    snap = kvc.offload_slot(0)
    assert snap is not None
    # nothing demoted: every page is refcount-2
    assert kvc.pool.host_used == 0
    assert all(kvc.pool.on_device(a) for a in snap.addrs)
    kvc.restore_slot(0, snap)            # no promotion needed either
    assert kvc.pool.tier_stats()["promoted_pages"] == 0
    kvc.release(0)
    kvc.release(1)


def test_offload_declines_when_host_full():
    cfg = _cfg()
    kvc = PagedKVCache(cfg, slots=2, max_len=64, n_pages=4,
                       page_size=16, host_pages=1)
    padded = RNG.integers(0, 100, size=40).astype(np.int32)
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    z = jnp.zeros((L, 40, kvh, hd), jnp.float32)
    kvc.attach(0, padded, z, z)          # 3 pages > 1 host row
    assert kvc.offload_slot(0) is None   # caller falls back to release
    assert kvc.lengths[0] == 40          # slot untouched
    kvc.release(0)


# -- engine-level parity: tiering on vs off ---------------------------

@pytest.mark.parametrize("engine", ["chunked", "paged"])
def test_greedy_parity_tiering_on_vs_off(setup, engine):
    """Token-identical with tiering on vs off on a no-pressure trace
    (same pool size, no preemption): the tiers must be invisible."""
    cfg, params = setup
    reqs = _requests(cfg, (12, 30, 45, 9), max_new=8)
    kw = dict(slots=4, max_len=128, prefill_buckets=(32,),
              page_size=16, n_pages=32)
    off = _serve(make_engine(params, cfg, engine=engine, **kw),
                 reqs)
    on_eng = make_engine(params, cfg, engine=engine, tiering=True,
                         host_pages=32, **kw)
    on = _serve(on_eng, reqs)
    assert on == off
    assert on_eng.stats()["tiering"] is True


def test_preempt_offload_restore_skips_prefill(setup):
    """The §4d headline: under page pressure a preempted request's KV
    is written back and RESTORED — greedy continuation identical to an
    ample pool that never preempted, with zero re-prefill work after
    the restore."""
    cfg, params = setup
    reqs = _requests(cfg, (40, 50, 60, 45), max_new=24)
    kw = dict(slots=4, max_len=128, prefill_buckets=(32,),
              page_size=16, chunk_size=32, step_tokens=68)
    truth = _serve(make_engine(params, cfg, engine="chunked",
                               n_pages=32, **kw), reqs)
    eng = make_engine(params, cfg, engine="chunked", n_pages=12,
                      tiering=True, host_pages=48, **kw)
    got = _serve(eng, reqs, max_steps=100000)
    st = eng.stats()
    assert st["preemptions"] > 0
    assert st["offloads"] > 0 and st["restores"] > 0
    assert st["offloads"] == st["restores"]
    assert st["offload_bytes"] > 0 and st["promote_bytes"] > 0
    assert got == truth
    # restored requests really did skip prefill: every offload was a
    # decode-phase write-back and the only prefill chunks ever run
    # cover each prompt exactly once
    chunk_tok = sum(c.get("prefill_chunk_tokens", 0)
                    for c in eng.counters)
    total_prompt = sum(-(-len(r.prompt) // 32) * 32 for r in reqs)
    assert chunk_tok <= total_prompt


def test_whole_prompt_engine_offload_restore(setup):
    """The whole-prompt paged engine rides the same restore path."""
    cfg, params = setup
    reqs = _requests(cfg, (40, 50, 60), max_new=24)
    kw = dict(slots=3, max_len=128, prefill_buckets=(32,),
              page_size=16)
    truth = _serve(make_engine(params, cfg, engine="paged",
                               n_pages=24, **kw), reqs)
    # pad-free layouts shrink the page footprint, so the pressure pool
    # shrinks with them: 8 pages force exactly the offload the test is
    # about
    eng = make_engine(params, cfg, engine="paged", n_pages=8,
                      tiering=True, host_pages=40, **kw)
    got = _serve(eng, reqs, max_steps=100000)
    st = eng.stats()
    assert st["restores"] > 0
    assert got == truth


def test_forced_eviction_torture_mid_decode(setup):
    """Demote every evictable page mid-decode, repeatedly, then let
    new requests promote what they share back — outputs identical to
    an undisturbed run (cold pages are refcount-0, so refcount
    pinning guarantees active slots never lose a page)."""
    cfg, params = setup
    # prompts share a 32-token real head: its two full pages hash
    # identically under the position-normalized keys
    prefix = RNG.integers(0, cfg.vocab_size, size=32).astype(np.int32)
    wave1 = _requests(cfg, (8, 8), max_new=6, rid0=0, prefix=prefix)
    wave2 = _requests(cfg, (8, 8), max_new=8, rid0=10, prefix=prefix)
    kw = dict(slots=4, max_len=128, prefill_buckets=(32,),
              page_size=16, chunk_size=32, step_tokens=68,
              n_pages=32)

    def trace(eng, drill):
        for r in wave1:
            eng.submit(r)
        eng.run_to_completion()      # wave-1 prefix pages now cold
        for r in wave2:              # shares the spilled prefix
            eng.submit(r)
        if drill:
            eng.force_demote()       # spill BEFORE wave 2 admits: its
        steps = 0                    # prefix hits must promote
        while (eng.active or eng.queue) and steps < 10000:
            eng.step()
            if drill:
                eng.force_demote()   # every evictable page, every step
            steps += 1
        return {c.rid: c.tokens for c in eng.completions}

    plain = trace(make_engine(params, cfg, engine="chunked", **kw),
                  drill=False)
    eng = make_engine(params, cfg, engine="chunked", tiering=True,
                      host_pages=32, **kw)
    tortured = trace(eng, drill=True)
    assert tortured == plain
    st = eng.stats()
    assert st["evictions"] > 0           # the drill actually demoted
    assert st["promoted_pages"] > 0      # and wave 2 promoted shares
    assert st["page_shares"] > 0


def test_prefix_spill_revival_and_lru_pinning():
    """Prefix-cache spill: pages retained cold at refcount 0 are
    revived by a later identical prefix; LRU eviction touches only
    refcount-0 pages."""
    cfg = _cfg()
    kvc = PagedKVCache(cfg, slots=2, max_len=64, n_pages=4,
                       page_size=16, host_pages=8)
    pool = kvc.pool
    padded = RNG.integers(0, 100, size=32).astype(np.int32)
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    z = jnp.asarray(RNG.normal(size=(L, 32, kvh, hd)), jnp.float32)
    kvc.attach(0, padded, z, z)
    kvc.release(0)                       # spill: retained cold
    assert pool.used_pages == 0
    assert pool.cold_count() == 2
    assert kvc.pages_needed(padded) == 0  # still a full prefix hit
    # an identical attach revives both pages without any page write
    allocs_before = pool.allocs
    kvc.attach(1, padded, z, z)
    assert pool.allocs == allocs_before
    assert pool.shares >= 2
    assert pool.cold_count() == 0
    assert all(pool.refcount(a) == 1 for a in kvc._state[1].addrs)
    kvc.release(1)


def test_copy_compute_overlap_reported(setup):
    """The overlap model: staged restores committed as prefetch hits
    show up in stats() as copy_compute_overlap > 0."""
    cfg, params = setup
    reqs = _requests(cfg, (40, 50, 60, 45, 55), max_new=24)
    eng = make_engine(params, cfg, engine="chunked", slots=4,
                      max_len=128, prefill_buckets=(32,),
                      page_size=16, chunk_size=32, step_tokens=68,
                      n_pages=12, tiering=True, host_pages=48)
    _serve(eng, reqs, max_steps=100000)
    st = eng.stats()
    assert st["restores"] > 0
    assert st["prefetch_hits"] + st["demand_promotes"] > 0
    assert 0.0 <= st["copy_compute_overlap"] <= 1.0
    assert st["prefetch_hits"] > 0       # staging really front-ran


def test_page_staging_never_clogs_the_double_buffer():
    """A page staged under its per-page key and then promoted by a
    DIFFERENT path (a snapshot restore, a cold drop) must retire its
    staging entry — otherwise two such events fill max_inflight=2 and
    disable prefetch for the life of the pool.  Promote bytes count
    committed copies, demand or staged."""
    cfg = _cfg()
    pool = TieredPagePool(cfg, n_pages=4, page_size=4, host_pages=8)
    addrs = []
    for i in range(3):
        a = pool.alloc()
        pool.register_prefix((b"k%d" % i, 4), a)
        addrs.append(a)
    for a in addrs:
        pool.decref(a)                   # cold, then spill them all
    pool.demote_all_cold()
    assert pool.host_used == 3
    for a in addrs[:2]:                  # fill the double buffer
        assert pool.stage_promote(("page", a.gid), [a])
    assert not pool.stage_promote(("page", addrs[2].gid), [addrs[2]])
    for a in addrs[:2]:
        pool.incref(a)
    pool.promote_pages(addrs[:2], staged_key=("restore", 99))
    # the per-page entries were retired: the buffer has room again
    assert pool.stage_promote(("page", addrs[2].gid), [addrs[2]])
    pool.incref(addrs[2])
    pool.ensure_device(addrs[2])
    assert pool.xfer.staged_keys() == []
    assert pool.tier_stats()["promote_bytes"] == \
        3 * pool.page_bytes()


def test_rollback_returns_shared_pages_to_the_cache():
    """attach rollback under exhaustion: fresh (unwritten) pages are
    freed outright, but prefix-shared hits return to the cache with
    their content — one failed admission must not evict the prefix."""
    cfg = _cfg()
    kvc = PagedKVCache(cfg, slots=2, max_len=96, n_pages=3,
                       page_size=16, host_pages=4)
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    head = RNG.integers(0, 100, size=32).astype(np.int32)
    z = jnp.asarray(RNG.normal(size=(L, 32, kvh, hd)), jnp.float32)
    kvc.attach(0, head, z, z)
    kvc.release(0)                       # 2 pages retained cold
    long = np.concatenate(
        [head, RNG.integers(0, 100, size=48).astype(np.int32)])
    zl = jnp.zeros((L, 80, kvh, hd), jnp.float32)
    with pytest.raises(PageExhausted):
        kvc.attach(1, long, zl, zl)      # shares 2, needs 3 fresh > 1
    # the shared prefix survived the rollback, still revivable
    assert kvc.pool.cold_count() == 2
    assert kvc.pages_needed(head) == 0
    kvc.attach(1, head, z, z)            # revives, no new alloc
    assert kvc.pool.shares >= 2
    kvc.release(1)


def test_sharded_pool_with_host_tier(setup):
    """§4c x §4d: the host tier behind a 2-shard device pool —
    offload/restore across simulated localities stays token-identical
    and shard accounting excludes the host locality."""
    cfg, params = setup
    reqs = _requests(cfg, (30, 45, 55, 38, 50, 42), max_new=16,
                     rid0=40)
    kw = dict(slots=4, max_len=128, prefill_buckets=(32,),
              page_size=16, chunk_size=32, step_tokens=68)
    truth = _serve(make_engine(params, cfg, engine="chunked",
                               n_pages=32, **kw), reqs)
    eng = make_engine(params, cfg, engine="chunked", n_pages=12,
                      kv_shards=2, tiering=True, host_pages=48, **kw)
    got = _serve(eng, reqs, max_steps=100000)
    st = eng.stats()
    assert got == truth
    assert st["restores"] > 0
    assert st["kv_shards"] == 2
    assert len(st["shard_pages_used"]) == 2   # host locality excluded


def test_migration_programs_cached_canonically():
    """DESIGN.md §9.4 closure: different migration plans in the same
    size class share one canonical permutation program (padded with
    null-row self-moves), and a page's content survives the padded
    permutation."""
    cfg = _cfg()
    from repro.serving.kvcache import PagePool
    pool = PagePool(cfg, n_pages=8, page_size=4, n_shards=2)

    def val(a):                          # sharded layout (L,S,R,...)
        loc, slot = pool.agas.lookup(a)
        return float(np.asarray(
            pool.pages["k"])[0, loc, slot, 0, 0, 0])

    addrs = [pool.alloc(locality=0) for _ in range(3)]
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    for i, a in enumerate(addrs):
        span = jnp.full((L, 1, 4, kvh, hd), float(i + 1),
                        pool.pages["k"].dtype)
        pool.write_pages([pool.row(a)], span, span)
    # plan 1: three moves 0 -> 1 (canonical size 4)
    pool.migrate_pages({a: 1 for a in addrs})
    assert pool._mig_sizes == {4}
    for i, a in enumerate(addrs):        # payload followed the name
        assert val(a) == i + 1
    # plan 2: three moves back — same size class, no new program
    pool.migrate_pages({a: 0 for a in addrs})
    assert pool._mig_sizes == {4}
    for i, a in enumerate(addrs):
        assert val(a) == i + 1
    for a in addrs:
        pool.decref(a)
