"""Pure-jnp oracle for the flash kernel (chunked online softmax)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import flash_jnp, repeat_kv


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray,
                        v: jnp.ndarray, *, causal: bool = True,
                        window: int = 0,
                        q_offset: int = 0) -> jnp.ndarray:
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D).  Returns (B, Sq, H, D)."""
    n_rep = q.shape[2] // k.shape[2]
    k = repeat_kv(k, n_rep)
    v = repeat_kv(v, n_rep)
    return flash_jnp(q, k, v, causal=causal, window=window,
                     q_offset=q_offset,
                     chunk_q=min(128, q.shape[1]),
                     chunk_k=min(128, k.shape[1]))
