"""chatglm3-6b: dense, 2d partial RoPE, extreme GQA (kv=2).
[arXiv:2406.12793; hf]

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_fraction=0.5,        # chatglm rotary on half the head dims
    rope_theta=1.0e4,
    microbatch_per_device=2,
)
