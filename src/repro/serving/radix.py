"""Radix longest-prefix index over page-key chains (DESIGN.md §4e).

The prefix cache's index used to be a flat ``(digest, fill) ->
GlobalAddress`` dict: correct, but structure-blind — it cannot answer
"what is the longest cached prefix of this prompt" without probing
key by key, it has no notion of a prefix being *hot*, and a dropped
interior page silently strands its descendants.  This module replaces
it with the vLLM/SGLang-style radix tree over page chains:

* **Nodes are pages.**  One `RadixNode` per registered page key; the
  parent edge follows the hash chain (key i's parent is key i-1), so
  a root-to-node path IS a prompt prefix.  Because every key is a
  *chained* digest — key i commits to the pad count and every real
  token through page i — a digest uniquely identifies its whole path,
  and the index keeps a flat digest -> node directory next to the
  tree.  Point lookups (`lookup`, the allocation-cost probe) stay
  O(1); the longest-prefix walk (`match`) is O(prompt pages), never
  O(index size).

* **Lifecycle is tied to the page's.**  `remove_gid` runs when a page
  leaves the pool (freed on decref, or dropped cold under host-tier
  pressure): the node's address is cleared in place — a *tombstone* —
  and childless tombstones are trimmed up the path.  A tombstone
  keeps live descendants reachable through the directory (a chunk
  extension can still hit page i+1 after page i dropped) while the
  tree walk correctly refuses to cover across the hole.

* **Hit statistics drive pinning.**  `match` stamps every node it
  traverses; a node that accumulates `pin_threshold` hits is pinned
  (capacity-bounded).  Pins are advisory: the tiered pool's LRU
  eviction (serving/tiering.py) demotes/drops *unpinned* cold pages
  first and touches pinned ones only when nothing else is evictable —
  hot shared prefixes stay device-resident, cold one-off tails
  percolate out, and correctness never deadlocks on a pin.

Everything is exported through `metrics()` under the ``prefix.*``
namespace and mirrored into the engine's MetricsRegistry (§10).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.agas import GlobalAddress

Key = Tuple[bytes, int]


class RadixNode:
    """One registered page key: a node on some prompt's page chain."""

    __slots__ = ("key", "addr", "parent", "children", "hits",
                 "last_hit", "pinned")

    def __init__(self, key: Optional[Key],
                 addr: Optional[GlobalAddress],
                 parent: Optional["RadixNode"]):
        self.key = key                   # None only for the root
        self.addr = addr                 # None = tombstone (or root)
        self.parent = parent
        self.children: Dict[bytes, RadixNode] = {}
        self.hits = 0
        self.last_hit = -1
        self.pinned = False

    @property
    def digest(self) -> bytes:
        return self.key[0]

    def __repr__(self) -> str:          # debugging aid only
        state = "root" if self.key is None else \
            ("tomb" if self.addr is None else f"gid={self.addr.gid}")
        return (f"RadixNode({state}, hits={self.hits}, "
                f"children={len(self.children)})")


class RadixPrefixIndex:
    """Longest-prefix index over chained page keys.

    ``pin_threshold`` hits on a node pin its page (0 disables
    pinning); at most ``pin_capacity`` pages are pinned at once.
    """

    def __init__(self, *, pin_threshold: int = 4,
                 pin_capacity: int = 8):
        self.root = RadixNode(None, None, None)
        self._nodes: Dict[bytes, RadixNode] = {}    # digest -> node
        self._by_gid: Dict[int, RadixNode] = {}     # live pages only
        self._pinned: Set[int] = set()              # pinned gids
        self.pin_threshold = int(pin_threshold)
        self.pin_capacity = int(pin_capacity)
        self._tick = 0
        # counters (prefix.* in metrics())
        self.inserts = 0
        self.rearms = 0          # tombstones revived by re-derivation
        self.removes = 0
        self.trims = 0           # nodes physically deleted
        self.node_hits = 0
        self.full_walks = 0      # match() covered every key
        self.partial_walks = 0   # match() covered a proper prefix
        self.miss_walks = 0      # match() covered nothing
        self.pins = 0
        self.unpins = 0
        self.forced_unpins = 0   # pin released under eviction duress
        self.orphan_inserts = 0  # parent digest unknown -> root

    # -- size / membership --------------------------------------------
    def __len__(self) -> int:
        """Live (non-tombstone) nodes."""
        return len(self._by_gid)

    @property
    def node_count(self) -> int:
        """All nodes, tombstones included (root excluded)."""
        return len(self._nodes)

    @property
    def tombstones(self) -> int:
        return len(self._nodes) - len(self._by_gid)

    # -- point lookups (O(1) via the digest directory) ----------------
    def lookup(self, key: Key) -> Optional[GlobalAddress]:
        """The live page registered under `key`, or None (unknown key
        or tombstone).  Chained digests uniquely identify paths, so a
        directory probe answers without a walk."""
        node = self._nodes.get(key[0])
        if node is None or node.addr is None or node.key != key:
            return None
        return node.addr

    def node_for_gid(self, gid: int) -> Optional[RadixNode]:
        return self._by_gid.get(gid)

    def key_for_gid(self, gid: int) -> Optional[Key]:
        node = self._by_gid.get(gid)
        return None if node is None else node.key

    def owns_gid(self, gid: int) -> bool:
        """True while `gid` is the live owner of some prefix key —
        the tiered pool's cold-retention predicate."""
        return gid in self._by_gid

    # -- registration --------------------------------------------------
    def insert(self, key: Key, addr: GlobalAddress,
               parent: Optional[bytes] = None) -> None:
        """Register `addr` under `key`, as a child of the node owning
        digest `parent` (root when None — the chain's first page).

        One key per page and one page per key: registering a taken
        digest or an already-keyed gid is a no-op, EXCEPT that a
        tombstone re-derived by a fresh prefill is re-armed in place —
        the new page adopts the old node, keeping its subtree and hit
        history.
        """
        node = self._nodes.get(key[0])
        if node is not None:
            if node.addr is None and node.key == key \
                    and addr.gid not in self._by_gid:
                node.addr = addr
                self._by_gid[addr.gid] = node
                self.rearms += 1
            return
        if addr.gid in self._by_gid:
            return
        pnode = self.root
        if parent is not None:
            pnode = self._nodes.get(parent)
            if pnode is None:           # chain head dropped entirely:
                pnode = self.root       # keep the node reachable via
                self.orphan_inserts += 1  # the directory at least
        node = RadixNode(key, addr, pnode)
        pnode.children[key[0]] = node
        self._nodes[key[0]] = node
        self._by_gid[addr.gid] = node
        self.inserts += 1

    # -- longest-prefix match (O(len(keys))) --------------------------
    def match(self, keys: List[Key]) -> List[RadixNode]:
        """The longest leading run of `keys` forming a LIVE root path:
        one tree step per key, stopping at the first miss, tombstone,
        or fill mismatch.  Stamps hit statistics on every matched node
        (this is the admission-time probe; `lookup` stays stat-free)
        and auto-pins nodes that cross the hit threshold.
        """
        out: List[RadixNode] = []
        cur = self.root
        self._tick += 1
        for key in keys:
            child = cur.children.get(key[0])
            if child is None or child.addr is None or child.key != key:
                break
            child.hits += 1
            child.last_hit = self._tick
            self.node_hits += 1
            self._maybe_pin(child)
            out.append(child)
            cur = child
        if not out:
            self.miss_walks += 1
        elif len(out) == len(keys):
            self.full_walks += 1
        else:
            self.partial_walks += 1
        return out

    # -- pinning -------------------------------------------------------
    def _maybe_pin(self, node: RadixNode) -> None:
        if node.pinned or self.pin_threshold <= 0:
            return
        if node.hits < self.pin_threshold:
            return
        if len(self._pinned) >= self.pin_capacity:
            return
        node.pinned = True
        self._pinned.add(node.addr.gid)
        self.pins += 1

    def is_pinned(self, gid: int) -> bool:
        return gid in self._pinned

    @property
    def pinned_gids(self) -> Set[int]:
        return self._pinned

    def unpin_gid(self, gid: int, *, forced: bool = False) -> None:
        """Release a pin (eviction found no other candidate, or the
        page left the pool)."""
        node = self._by_gid.get(gid)
        if node is not None and node.pinned:
            node.pinned = False
        if gid in self._pinned:
            self._pinned.discard(gid)
            self.unpins += 1
            if forced:
                self.forced_unpins += 1

    # -- removal (page left the pool) ---------------------------------
    def remove_gid(self, gid: int) -> None:
        """Tombstone the node owning `gid` and trim childless
        tombstones up the path.  No-op for unkeyed gids."""
        node = self._by_gid.pop(gid, None)
        if node is None:
            return
        if node.pinned:
            node.pinned = False
            self._pinned.discard(gid)
            self.unpins += 1
        node.addr = None
        self.removes += 1
        while node is not self.root and node.addr is None \
                and not node.children:
            parent = node.parent
            if parent is not None:
                parent.children.pop(node.digest, None)
            self._nodes.pop(node.digest, None)
            node.parent = None
            self.trims += 1
            node = parent if parent is not None else self.root

    # -- telemetry -----------------------------------------------------
    def metrics(self) -> Dict[str, Any]:
        return {
            "prefix.nodes": len(self._by_gid),
            "prefix.tombstones": self.tombstones,
            "prefix.inserts": self.inserts,
            "prefix.rearms": self.rearms,
            "prefix.removes": self.removes,
            "prefix.node_hits": self.node_hits,
            "prefix.full_walks": self.full_walks,
            "prefix.partial_walks": self.partial_walks,
            "prefix.miss_walks": self.miss_walks,
            "prefix.pinned": len(self._pinned),
            "prefix.pins": self.pins,
            "prefix.unpins": self.unpins,
            "prefix.forced_unpins": self.forced_unpins,
        }

    # -- invariants (the property suite's oracle) ---------------------
    def check(self) -> None:
        """Assert structural invariants; raises AssertionError."""
        seen_gids: Set[int] = set()
        # every directory node is reachable from the root by parent
        # edges, consistent both ways
        for digest, node in self._nodes.items():
            assert node.key is not None and node.digest == digest
            parent = node.parent
            assert parent is not None, f"detached node {node!r}"
            assert parent.children.get(digest) is node, \
                f"parent/child edge broken at {node!r}"
            if node.addr is not None:
                assert self._by_gid.get(node.addr.gid) is node
                seen_gids.add(node.addr.gid)
            else:
                assert node.children, \
                    f"childless tombstone survived trim: {node!r}"
                assert not node.pinned
        assert seen_gids == set(self._by_gid), "gid directory drift"
        for gid in self._pinned:
            node = self._by_gid.get(gid)
            assert node is not None and node.pinned, \
                f"pinned gid {gid} has no live pinned node"
        for node in self._by_gid.values():
            assert node.pinned == (node.addr.gid in self._pinned)
        assert len(self._pinned) <= self.pin_capacity
        # children maps only contain directory members
        stack = [self.root]
        reachable = 0
        while stack:
            n = stack.pop()
            for d, c in n.children.items():
                assert self._nodes.get(d) is c
                assert c.parent is n
                reachable += 1
                stack.append(c)
        assert reachable == len(self._nodes), \
            "directory and tree disagree on membership"
