"""Task granularity control (paper Figs 3 and 4(b)).

"In the ParalleX based AMR code explored here the user selects the task
granularity. The task granularity can even be as small as a single
point. ... In a work queue based execution model, the optimal task
granularity may be much smaller than that suggested by a clustering
algorithm." (paper, Sec. III)

The grain g (points per task) trades per-task overhead sigma against
available parallelism and load-balance slack:

  n_tasks(g)      = ceil(N / g)
  t_task(g)       = c_point * g + sigma        (+ halo cost 2*r*c_halo)
  lower bound     = max(work/P, span)          (Brent)

`sweep` evaluates real schedules across grains; `auto_tune` returns the
argmin.  amr/* uses g to build blocks, models/* reuses the same knob as
the microbatch size for LM pipelines.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class GrainModel:
    """Analytic cost model for one task at grain g."""

    c_point: float          # seconds of useful work per point update
    sigma: float            # per-task management overhead (Fig 9: 3-5e-6)
    halo_points: int = 2    # ghost points exchanged per task side
    c_halo: float = 0.0     # per-halo-point pack/unpack/parcel cost

    def task_cost(self, g: int) -> float:
        return self.c_point * g + 2 * self.halo_points * self.c_halo

    def total_overhead(self, n_points: int, g: int) -> float:
        return self.sigma * n_tasks(n_points, g)


def n_tasks(n_points: int, g: int) -> int:
    return -(-n_points // g)


def efficiency(model: GrainModel, g: int) -> float:
    """Useful-work fraction of one task: cost / (cost + sigma)."""
    c = model.task_cost(g)
    return c / (c + model.sigma) if c + model.sigma > 0 else 0.0


@dataclasses.dataclass
class GrainSweepPoint:
    grain: int
    n_tasks: int
    makespan: float
    idle_fraction: float
    overhead_fraction: float


def sweep(
    grains: Sequence[int],
    build_and_schedule: Callable[[int], "object"],
    graph_work: Optional[Callable[[int], float]] = None,
) -> List[GrainSweepPoint]:
    """Evaluate schedules across grain sizes.

    `build_and_schedule(g)` must return a ScheduleResult-like object with
    .makespan/.idle_fraction/.busy/.overhead/.n_workers; `graph_work(g)`
    optionally returns the useful work at that grain for the overhead
    fraction (defaults to busy-sum minus overhead estimate).
    """
    out = []
    for g in grains:
        res = build_and_schedule(int(g))
        busy = float(np.sum(res.busy))
        ntask = int(np.sum(res.worker >= 0))
        ovh = res.overhead * ntask
        work = graph_work(int(g)) if graph_work else busy - ovh
        denom = work + ovh
        out.append(GrainSweepPoint(
            grain=int(g),
            n_tasks=ntask,
            makespan=res.makespan,
            idle_fraction=res.idle_fraction,
            overhead_fraction=(ovh / denom if denom > 0 else 0.0),
        ))
    return out


def auto_tune(
    grains: Sequence[int],
    build_and_schedule: Callable[[int], "object"],
) -> int:
    """Paper Fig 3's experiment as a tuner: argmin-makespan grain."""
    pts = sweep(grains, build_and_schedule)
    best = min(pts, key=lambda p: p.makespan)
    return best.grain


def optimal_grain_analytic(n_points: int, n_workers: int,
                           model: GrainModel) -> int:
    """Closed-form estimate, used as the tuner's starting bracket.

    Balance overhead (sigma*N/g) against load-balance slack (one task of
    size g per worker): d/dg [sigma*N/(g*P) + c_point*g] = 0
      =>  g* = sqrt(sigma * N / (P * c_point)).
    """
    if model.c_point <= 0:
        return max(1, n_points // max(1, n_workers))
    g = np.sqrt(model.sigma * n_points / (n_workers * model.c_point))
    return int(max(1.0, g))
