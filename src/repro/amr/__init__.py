"""The paper's AMR application: semilinear wave, Berger-Oliger +
tapering, barrier vs. barrier-free (dataflow) engines."""

from repro.amr.engines import (BarrierEngine, CompiledDataflowEngine,
                               DataflowEngine, EngineConfig, RunResult,
                               compare_engines)
from repro.amr.hierarchy import (TAPER, HierarchyError, LevelSpec,
                                 LevelState, default_specs,
                                 enumerate_window_ops, make_hierarchy,
                                 run_ops_lockstep)
from repro.amr.taskgraph import (CostModel, WindowGraph, assign_owners,
                                 build_window_graph, run_window,
                                 timestep_front)
from repro.amr.wave import (H, NFIELDS, WaveProblem, energy,
                            fused_rk3_block, global_step, grid,
                            initial_data, linf)

__all__ = [
    "BarrierEngine", "CompiledDataflowEngine", "DataflowEngine",
    "EngineConfig", "RunResult", "compare_engines", "TAPER",
    "HierarchyError", "LevelSpec", "LevelState", "default_specs",
    "enumerate_window_ops", "make_hierarchy", "run_ops_lockstep",
    "CostModel", "WindowGraph", "assign_owners", "build_window_graph",
    "run_window", "timestep_front", "H", "NFIELDS", "WaveProblem",
    "energy", "fused_rk3_block", "global_step", "grid", "initial_data",
    "linf",
]
