"""Roofline table generator: dryrun_results.json -> EXPERIMENTS table.

Reads the dry-run sweep cache (launch/dryrun.py) and renders the
per-cell three-term roofline with dominant bottleneck, useful-flop
ratio, and the one-line "what would move the dominant term" note.
"""

from __future__ import annotations

import json
import os
from typing import Optional

from benchmarks.common import emit

DEFAULT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "dryrun_results.json")

NOTES = {
    "collective": ("shrink TP/FSDP traffic: fewer per-layer "
                   "all-gathers/all-reduces (sharding constraints, "
                   "bf16 grads, overlap)"),
    "memory": "cut HBM streaming: fuse cache update, smaller remat set",
    "compute": "raise MXU utilization: bigger tiles, less recompute",
}


def load(path: str = DEFAULT) -> dict:
    with open(path) as f:
        return json.load(f)


def table(path: str = DEFAULT, mesh: str = "1pod",
          verbose: bool = True) -> list:
    res = load(path)
    rows = []
    for key, v in sorted(res.items()):
        if not key.endswith(mesh):
            continue
        if v.get("status") == "skipped":
            rows.append((key, "skipped", v.get("reason", "")))
            continue
        if v.get("status") != "ok" or "roofline" not in v:
            continue
        rl = v["roofline"]
        rows.append((
            key, rl["dominant"],
            dict(t_compute=rl["t_compute_s"], t_memory=rl["t_memory_s"],
                 t_collective=rl["t_collective_s"],
                 useful=rl["useful_flop_fraction"],
                 frac=rl["roofline_fraction"],
                 mem_gib=v.get("memory", {}).get("per_device_gib"))))
    if verbose:
        print(f"# roofline ({mesh})")
        print("# %-40s %10s %10s %10s %-10s %7s %7s %7s" % (
            "cell", "t_comp(s)", "t_mem(s)", "t_coll(s)", "dominant",
            "useful", "RLfrac", "GiB"))
        for key, dom, d in rows:
            if dom == "skipped":
                print(f"# {key:<40s} SKIPPED: {d}")
                continue
            print("# %-40s %10.4f %10.4f %10.4f %-10s %7.3f %7.3f %7.2f"
                  % (key, d["t_compute"], d["t_memory"],
                     d["t_collective"], dom, d["useful"], d["frac"],
                     d["mem_gib"] or 0))
    ok_rows = [r for r in rows if r[1] != "skipped"]
    if ok_rows:
        worst = min(ok_rows, key=lambda r: r[2]["frac"])
        emit("roofline_cells_ok", float(len(ok_rows)), f"mesh={mesh}")
        emit("roofline_worst_cell", worst[2]["frac"],
             worst[0].replace(",", ";"))
    return rows


def run(verbose=True):
    if not os.path.exists(DEFAULT):
        print("# roofline: no dryrun_results.json yet — run "
              "`python -m repro.launch.dryrun --all`")
        return []
    return table(verbose=verbose)


if __name__ == "__main__":
    run()
