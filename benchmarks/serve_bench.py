"""Serving benchmark: chunked prefill vs whole-prompt paged vs dense.

Two comparisons, each on the trace it is valid for:

* dense vs paged (PR 1): a short single-bucket trace — the dense
  engine's one shared ``len/cursor/abs`` clock is only correct when
  every concurrent request shares a prefill bucket and the cursor
  never outruns ``max_len``, so the bulk-ownership baseline is
  measured inside its own validity envelope.  At equal peak KV bytes
  the paged engine runs more concurrent requests, because short
  requests only hold the pages they touched.
* whole-prompt vs chunked prefill (DESIGN.md §4b): a mixed short/long
  trace with the long prompts queued FIRST — the head-of-line shape
  chunked prefill exists to break.  At EQUAL page budget, splitting
  prefill into page-aligned chunks under a per-step token budget must
  hold p50 time-to-first-token strictly below the whole-prompt engine
  at a total-throughput cost within 10%.

``--kv-shards N`` additionally serves the mixed trace from a pool
sharded over N AGAS localities (DESIGN.md §4c) — device-backed when
the runtime has one device per shard (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` like
tests/test_distributed.py), simulated otherwise — with a forced
mid-trace page migration, and asserts the greedy outputs are
token-identical to the single-locality chunked engine.

``--tiering`` serves a pressure trace (long-ish prompts, more
requests than the device pool can hold) twice at the SAME device page
budget: once untiered — preemptions forfeit pages and re-prefill —
and once with the two-tier percolation pool (DESIGN.md §4d,
``--host-pages`` sizes the host tier), where preempted KV is written
back to host and restored on re-admission.  The tiered run must hold
>= 2x the concurrently resident requests outside ``--smoke``, stay
token-identical to an ample-pool reference, and reports the
offload/promote byte counters plus the copy/compute overlap
fraction.

``--prefix-heavy`` serves the dominant production shape — a long
shared system prompt with short per-request user suffixes — twice at
the SAME page budget on the tiered chunked engine: once with the
prefix cache saving memory only, and once with
``prefix_cache_compute=True`` (DESIGN.md §4e), where covered prompts
skip the covered prefill compute and fully-covered repeats admit
straight to decode from their cached activation checkpoint.  TWO
warm waves run back to back: a fixed-suffix-length wave (the
regression baseline — equal totals were the only shape the old
padded-layout keys could ever share) and a MIXED-suffix-length wave,
where every request has a different total length behind the same
head — the traffic the position-normalized keys and the radix
longest-prefix index exist for.  Outside ``--smoke`` the fixed wave
must show >= 3.5x lower p50 TTFT and >= 80% of its prefill tokens
skipped, the mixed wave >= 3x and >= 70% (the TTFT floors are set
~20% under the quietest-machine measurement: the skip-OFF numerator
swings with host load, and a floor that trips on scheduler noise
guards nothing); greedy outputs are asserted token-identical between
the two runs, both waves.

``--disagg`` serves the mixed trace a third time on the
disaggregated prefill/decode engine (DESIGN.md §4f): prefill chunks
dispatched as parcels to the prefix-owner locality over a 2-shard
pool, finished KV handed to the decode role through the percolation
snapshot machinery.  Greedy outputs must be token-identical to the
single-locality chunked engine, a warm shared-prefix wave must send
>= 90% of its prefill parcels to the prefix-owner locality (asserted
even under ``--smoke`` — dispatch is deterministic), and the run
reports handoff bytes moved plus the fraction of handoffs whose
staged copy overlapped a decode batch.  Outside ``--smoke`` the
disagg engine must hold >= 50% of the single-locality chunked
throughput on the same trace.  Calibration: repeated quiet-ish runs
measure the ratio at ~1.0x median with an observed 0.66-1.13x spread
(both numerator and denominator are short wall-clock timings, so
host load can hit either side) — the floor sits ~25% under the WORST
observed sample, per the PR 7 lesson that floors set near the quiet
median trip on scheduler noise and guard nothing.

``--chaos`` runs the locality-loss drill (DESIGN.md §4g): the
pressure trace on the disagg 2-shard tiered stack, once failure-free
and once with KV shard 1 killed mid-wave by a ``FailurePlan``.  Every
in-flight future must resolve with tokens identical to the
failure-free run — per rid over the whole wave, not sampled — via
host-tier page rebuild where a percolation copy exists and
drain + re-prefill where it does not; the dead shard then re-joins
and a second wave must be identical on the healed pool.

``--seed`` reseeds every trace generator, so mixed-trace runs are
reproducible (and comparable) across machines.

Engines are warmed up (prefill buckets, the chunk step, and the decode
step compiled) on a throwaway trace before timing, so the latency
split reflects scheduling, not XLA compilation.

Emits the run.py ``name,us_per_call,derived`` CSV contract plus one
``# json {...}`` line (and ``--out FILE`` to persist the JSON).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import emit

ARCH = "yi-6b"

# -- dense vs paged (PR 1): short trace, one shared bucket -------------
SLOTS_DENSE = 4
DENSE_MAX_LEN = 96          # dense peak: 4 * 96 = 384 KV token rows
PAGE_SIZE = 16
DENSE_N_PAGES = SLOTS_DENSE * DENSE_MAX_LEN // PAGE_SIZE     # 24 pages
SLOTS_PAGED = 8             # paged runs 2x the decode width, same bytes

# -- whole-prompt vs chunked (PR 2): mixed trace, equal pages ----------
MIXED_MAX_LEN = 128
MIXED_N_PAGES = 32          # 512 KV token rows for both paged engines
CHUNK = 32
STEP_TOKENS = SLOTS_PAGED + 2 * CHUNK
N_SHORT = 14
N_LONG = 2
MAX_NEW = 16

# -- tiered percolation (DESIGN.md §4d): pressure trace, tiny device --
TIER_DEVICE_PAGES = 16      # 256 KV token rows of HBM
TIER_HOST_PAGES = 64        # the ~4x host DRAM tier behind it
SLOTS_TIERED = 16           # slot count beyond what the device holds
N_PRESSURE = 16             # long decode tails: ~6-7 pages each at
TIER_MAX_NEW = 48           # completion, vs a 16-page device pool

# -- disaggregated prefill/decode (DESIGN.md §4f) ---------------------
DISAGG_SHARDS = 2           # one prefill worker per KV shard
DISAGG_AFFINITY_FLOOR = 0.9
DISAGG_TPUT_FLOOR = 0.5     # vs single-locality chunked; see docstring

# -- prefix-heavy shared-system-prompt trace (DESIGN.md §4e) ----------
PREFIX_SYS = 112            # shared system prompt: exactly 7 full
                            # pages under the pad-free layout, so every
                            # warm request's covered head is 112 tokens
PREFIX_USER = 16            # fixed-length user suffix (the baseline
                            # wave): equal totals were the ONLY shape
                            # the old padded-layout keys could share,
                            # so this wave guards the original §4e win
PREFIX_USER_MIX = (4, 8, 12, 20, 28, 36, 44)
                            # mixed-length suffixes: different TOTAL
                            # lengths behind the same head — the
                            # traffic position-normalized keys exist
                            # for (112 + 44 stays under PREFIX_MAX_LEN)
PREFIX_N = 12               # warm wave (incl. PREFIX_REPEATS)
PREFIX_REPEATS = 2          # exact repeats of the seed prompt: fully
                            # covered, admit straight to decode
PREFIX_MAX_NEW = 8
PREFIX_PAGES = 64           # same page budget for both runs
PREFIX_HOST_PAGES = 64
PREFIX_MAX_LEN = 160


def _short_requests(cfg, n, max_new=MAX_NEW, rid0=0, seed=0):
    rng = np.random.default_rng(seed)
    from repro.serving.engine import Request
    return [Request(rid0 + i, rng.integers(
        0, cfg.vocab_size, size=int(rng.integers(8, 30)))
        .astype(np.int32), max_new_tokens=max_new)
        for i in range(n)]


def _mixed_requests(cfg, n_short=N_SHORT, n_long=N_LONG,
                    max_new=MAX_NEW, seed=0):
    """Long prompts FIRST, shorts queued behind them."""
    rng = np.random.default_rng(seed)
    from repro.serving.engine import Request
    longs = [Request(rid, rng.integers(
        0, cfg.vocab_size, size=int(rng.integers(80, 96)))
        .astype(np.int32), max_new_tokens=max_new)
        for rid in range(n_long)]
    return longs + _short_requests(cfg, n_short, max_new=max_new,
                                   rid0=n_long, seed=seed + 1)


def _pressure_requests(cfg, n=N_PRESSURE, max_new=TIER_MAX_NEW,
                       seed=0):
    """Medium prompts + LONG decode tails: every request grows to 6-7
    pages before finishing, so a 16-page device pool preempts
    constantly mid-decode — the shape write-back offload exists for."""
    rng = np.random.default_rng(seed + 7)
    from repro.serving.engine import Request
    return [Request(i, rng.integers(
        0, cfg.vocab_size, size=int(rng.integers(40, 64)))
        .astype(np.int32), max_new_tokens=max_new)
        for i in range(n)]


def _prefix_traces(cfg, n=PREFIX_N, repeats=PREFIX_REPEATS,
                   max_new=PREFIX_MAX_NEW, seed=0, mixed=False):
    """(seed request, warm wave): one cold request carrying the shared
    system prompt, then a wave of partial covers (same system prompt,
    fresh user suffixes) plus `repeats` exact repeats of the seed
    prompt (full covers).  ``mixed=True`` cycles the suffix lengths
    through PREFIX_USER_MIX, so every wave member has a different
    total length behind the shared head."""
    rng = np.random.default_rng(seed + 29)
    from repro.serving.engine import Request
    sys_p = rng.integers(0, cfg.vocab_size,
                         size=PREFIX_SYS).astype(np.int32)

    def req(rid, user):
        return Request(rid, np.concatenate([sys_p, user])
                       .astype(np.int32), max_new_tokens=max_new)

    seed_user = rng.integers(0, cfg.vocab_size, size=PREFIX_USER)
    seed_req = req(900, seed_user)
    lens = (PREFIX_USER_MIX if mixed
            else (PREFIX_USER,)) * (n - repeats)
    wave = [req(i, rng.integers(0, cfg.vocab_size, size=lens[i]))
            for i in range(n - repeats)]
    wave += [req(800 + j, seed_user) for j in range(repeats)]
    return seed_req, wave


def _warmup(eng, cfg, lens):
    """Compile every executable the timed trace will hit, then wipe
    the engine's telemetry so timings reflect scheduling only."""
    from repro.serving.engine import Request
    rng = np.random.default_rng(2)
    for rid, n in enumerate(lens):
        eng.submit(Request(-1 - rid, rng.integers(
            0, cfg.vocab_size, size=n).astype(np.int32),
            max_new_tokens=4))
    eng.run_to_completion()
    eng.completions.clear()
    eng.reset_metrics()
    if hasattr(eng, "counters"):
        eng.counters.clear()
        eng.preemptions = 0
        eng.prefix_skips = 0
        eng.prefix_partial_hits = 0
        eng.prefill_tokens_skipped = 0
        pool = eng.kvc.pool
        pool.allocs = pool.shares = pool.cow_copies = 0
        if getattr(pool, "tiered", False):
            # the timed trace starts from an empty pool, an empty
            # staging buffer, and clean percolation counters (warmup
            # prefixes would otherwise sit cold on device, and
            # warmup-staged promotions would clog the double buffer)
            from repro.core.percolation import TransferEngine
            pool.drop_all_cold()
            pool.evictions = pool.cold_drops = 0
            pool.offloaded = pool.promoted = 0
            pool.xfer = TransferEngine(
                max_inflight=pool.xfer.max_inflight)
            # the fresh transfer engine must keep tracing into the
            # pool's stream (set_tracer before warmup would be undone
            # here otherwise)
            pool.xfer.trace = pool.trace
            pool.xfer.queue.trace = pool.trace
            eng.offloads = eng.restores = 0
        if hasattr(eng, "handoff_queue"):
            # disagg (§4f): warmup handoffs/parcels are compilation
            # traffic, not the measured trace's
            eng.handoffs = eng.handoff_bytes = 0
            eng.handoff_overlapped = 0
            role = eng._prefill_role
            role.parcels = role.owner_parcels = 0
            role.cold_parcels = role.inter_locality = 0
            role.dispatch_sizes.clear()
            eng._port.sent = eng._port.local_applied = 0


def _serve(eng, reqs):
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    new_tokens = sum(len(c.tokens) for c in eng.completions)
    assert len(eng.completions) == len(reqs)
    return dt, new_tokens


def _eng_stats(st, slots, tok, wall):
    return {"slots": slots, "tok_s": tok / wall, "wall_s": wall,
            "peak_active": st["peak_active"],
            "peak_page_occupancy": st["peak_page_occupancy"],
            "preemptions": st["preemptions"],
            "page_shares": st["page_shares"],
            "cow_copies": st["cow_copies"],
            "ttft_p50_ms": st["ttft_p50_ms"],
            "ttft_p95_ms": st["ttft_p95_ms"],
            "ttft_p99_ms": st["ttft_p99_ms"],
            "itl_p50_ms": st["itl_p50_ms"],
            "itl_p95_ms": st["itl_p95_ms"],
            "itl_p99_ms": st["itl_p99_ms"]}


def _serve_sharded(params, cfg, kw_mixed, warm_lens, mixed, kv_shards,
                   baseline_tokens):
    """Mixed trace over a kv_shards-locality pool + a forced mid-trace
    migration; greedy outputs must match the single-locality engine
    token for token (the AGAS name-stability promise, end to end)."""
    from repro.distributed.sharding import kv_pool_mesh
    from repro.serving.engine import make_engine

    mesh = kv_pool_mesh(kv_shards)
    eng = make_engine(params, cfg, engine="chunked", chunk_size=CHUNK,
                      step_tokens=STEP_TOKENS, kv_shards=kv_shards,
                      mesh=mesh, **kw_mixed)
    _warmup(eng, cfg, warm_lens)
    eng.kvc.pool.page_migrations = 0
    for r in mixed:
        eng.submit(r)
    t0 = time.perf_counter()
    for _ in range(4):                  # into the trace, then force a
        eng.step()                      # mid-trace migration
    eng.force_migrate()
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    new_tokens = sum(len(c.tokens) for c in eng.completions)
    st = eng.stats()
    toks = {c.rid: c.tokens for c in eng.completions}
    assert toks == baseline_tokens, (
        f"kv_shards={kv_shards} outputs diverge from the "
        "single-locality engine")
    out = _eng_stats(st, eng.slots, new_tokens, dt)
    out.update(kv_shards=kv_shards,
               backing="mesh" if mesh is not None else "simulated",
               shard_occupancy=st["shard_occupancy"],
               page_migrations=st["page_migrations"])
    return out


def _disagg_affinity_wave(eng, cfg, seed):
    """Warm shared-prefix wave on a drained disagg engine: one cold
    seed request plants the prefix and KEEPS DECODING (an untiered
    pool de-indexes prefix pages at refcount zero), then a wave
    sharing its head dispatches.  Returns (owner, total) prefill
    parcels over the wave alone — dispatch is deterministic, so the
    >= 90% affinity floor holds even under --smoke."""
    from repro.serving.engine import Request
    rng = np.random.default_rng(seed + 61)
    head = rng.integers(0, cfg.vocab_size, size=64)      # 4 pages
    eng.submit(Request(700, np.concatenate([
        head, rng.integers(0, cfg.vocab_size, size=8)])
        .astype(np.int32), max_new_tokens=32))
    while not eng.active or any(st["phase"] != "decode"
                                for st in eng.active.values()):
        eng.step()                     # seed resident, prefix planted
    before = eng.stats()
    wave = [Request(710 + i, np.concatenate([
        head, rng.integers(0, cfg.vocab_size, size=4 + 4 * i)])
        .astype(np.int32), max_new_tokens=4) for i in range(8)]
    for r in wave:
        eng.submit(r)
    eng.run_to_completion()
    after = eng.stats()
    return (after["prefill_parcels_owner"]
            - before["prefill_parcels_owner"],
            after["prefill_parcels"] - before["prefill_parcels"])


def _prefix_run(params, cfg, seed_req, wave, skip):
    """One warm shared-system-prompt wave at the standard page budget:
    seed the prefix cache with one cold request, then measure the wave
    with compute skip on or off.  Returns (metrics, rid -> tokens)."""
    from repro.serving.engine import Request, make_engine
    eng = make_engine(params, cfg, engine="chunked",
                      slots=SLOTS_PAGED, max_len=PREFIX_MAX_LEN,
                      prefill_buckets=(32,), page_size=PAGE_SIZE,
                      n_pages=PREFIX_PAGES, chunk_size=CHUNK,
                      step_tokens=STEP_TOKENS, tiering=True,
                      host_pages=PREFIX_HOST_PAGES,
                      prefix_cache_compute=skip)
    _warmup(eng, cfg, (156, 120, 33, 12))
    # seed the cache (the cold request the wave shares), then one
    # throwaway warm repeat so the resume executable compiles outside
    # the timed wave; telemetry resets but the cold pages STAY — warm
    # is the point
    eng.submit(seed_req)
    eng.run_to_completion()
    cold_ttft_ms = eng.completions[0].ttft_s * 1e3
    eng.submit(Request(901, seed_req.prompt, max_new_tokens=2))
    eng.run_to_completion()
    eng.completions.clear()
    eng.counters.clear()
    eng.reset_metrics()
    eng.prefix_skips = 0
    eng.prefix_partial_hits = 0
    eng.prefill_tokens_skipped = 0
    dt, tok = _serve(eng, wave)
    st = eng.stats()
    run_tok = sum(c.get("prefill_chunk_tokens", 0)
                  for c in eng.counters)
    skipped = st["prefill_tokens_skipped"]
    out = dict(_eng_stats(st, eng.slots, tok, dt),
               compute_skip=skip,
               cold_ttft_ms=cold_ttft_ms,
               prefix_skips=st["prefix_skips"],
               prefix_partial_hits=st["prefix_partial_hits"],
               prefill_tokens_skipped=skipped,
               prefill_tokens_run=run_tok,
               skip_fraction=skipped / max(skipped + run_tok, 1),
               radix=eng.kvc.pool.prefix.metrics())
    return out, {c.rid: c.tokens for c in eng.completions}


def _traced_run(params, cfg, trace_path, smoke, seed, verbose,
                disagg=False):
    """Tentpole measurement (DESIGN.md §10): serve a pressure trace on
    the full stack — chunked prefill, 2 KV shards, two-tier
    percolation, a forced mid-trace migration — twice from identical
    warmed engines: once untraced (the wall-clock baseline) and once
    with the causal tracer attached to every subsystem.  Exports the
    Chrome trace, validates span nesting + request->slot->page causal
    links, decomposes step wall-clock into compute vs runtime overhead,
    and bounds the tracer's own cost (<= 5% enabled outside --smoke;
    <= 1% disabled, estimated from the measured null-tracer call cost
    times the observed records-per-step rate)."""
    import os

    from repro.obs.attribution import (attribute, check_causal,
                                       check_nesting, subsystems)
    from repro.obs.trace import NULL_TRACER, Tracer, set_global
    from repro.serving.engine import make_engine

    kw = dict(slots=SLOTS_PAGED, max_len=MIXED_MAX_LEN,
              prefill_buckets=(32,), page_size=PAGE_SIZE,
              n_pages=TIER_DEVICE_PAGES, chunk_size=CHUNK,
              step_tokens=STEP_TOKENS, kv_shards=2, tiering=True,
              host_pages=48, disagg=disagg)
    reqs = _pressure_requests(cfg, n=6, max_new=8 if smoke else 48,
                              seed=seed)
    warm = (97, 90, 33, 12)
    reps = 3 if smoke else 5

    def _drive(eng, rid_off):
        """Submissions, steps, and a forced migration — identical for
        the baseline and traced engines, so wall-clocks compare.  rids
        are offset per repetition so futures never collide."""
        import dataclasses
        rs = [dataclasses.replace(r, rid=r.rid + rid_off)
              for r in reqs]
        n0 = len(eng.completions)
        for r in rs[:2]:
            eng.submit(r)
        for _ in range(3):
            eng.step()
        eng.force_migrate()            # parcels: plan + AGAS moves
        for r in rs[2:]:
            eng.submit(r)
        eng.run_to_completion()
        return {c.rid - rid_off: c.tokens
                for c in eng.completions[n0:]}

    def _timed_drive(eng, rid_off):
        t0 = time.perf_counter()
        toks = _drive(eng, rid_off)
        return time.perf_counter() - t0, toks

    # a scratch engine absorbs process-level compiles _warmup does not
    # cover (the forced migration's permutation program), so the two
    # timed drives below compare scheduling, not XLA compilation
    scratch = make_engine(params, cfg, engine="chunked", **kw)
    _warmup(scratch, cfg, warm)
    _drive(scratch, 0)

    base = make_engine(params, cfg, engine="chunked", **kw)
    _warmup(base, cfg, warm)

    tracer = Tracer(capacity=1 << 18)
    eng = make_engine(params, cfg, engine="chunked", **kw)
    _warmup(eng, cfg, warm)
    eng.set_tracer(tracer)             # engine + pool + xfer

    # interleaved pairs: each repetition times the untraced and traced
    # twins back to back under the same system state, so load/frequency
    # drift cancels; min wall per side is the noise-robust statistic
    # the enabled-cost budget is judged on (one GC pause or scheduler
    # hiccup dwarfs the tracer at these run lengths).  The module
    # global (lco / parcels / agas) is live only during traced drives
    # so the baseline stays untraced and the ring stays causally
    # self-contained.
    base_walls, traced_walls = [], []
    base_toks, traced_toks = [], []
    try:
        for k in range(reps):
            w, t = _timed_drive(base, 100 * k)
            base_walls.append(w)
            base_toks.append(t)
            set_global(tracer)
            w, t = _timed_drive(eng, 100 * k)
            traced_walls.append(w)
            traced_toks.append(t)
            set_global(None)
    finally:
        set_global(None)
    base_s, traced_s = min(base_walls), min(traced_walls)
    base_total_s = sum(base_walls)
    base_steps = max(len(base.counters), 1)
    assert traced_toks == base_toks, (
        "tracing changed the served tokens — instrumentation must be "
        "observation only")

    records = tracer.records()
    assert tracer.dropped == 0, (
        f"ring dropped {tracer.dropped} records; causal validation "
        "needs the complete stream (raise the tracer capacity)")
    subs = subsystems(records)
    need = {"engine", "kvcache", "percolation", "parcels", "lco"}
    assert need <= subs, f"trace missing subsystems: {need - subs}"
    nest = check_nesting(records)
    assert not nest, f"span nesting violations: {nest[:3]}"
    causal = check_causal(records)
    assert not causal, f"dangling causal links: {causal[:3]}"

    report = attribute(records)
    assert report["steps"] > 0
    assert report["sum_residual"] <= 0.05, (
        f"attribution does not reconcile with step wall-clock: "
        f"residual {report['sum_residual']:.3f}")
    if disagg:
        # §4f handoffs must land in the parcel/copy attribution
        # buckets, not vanish into the residual
        names = {r.name for r in records}
        assert {"handoff_stage", "handoff_commit"} <= names, (
            "disagg trace carries no handoff spans")
        assert report["categories_ms"].get("copy", 0.0) > 0.0

    # tracer cost, enabled: wall-clock vs the untraced twin
    enabled_frac = traced_s / base_s - 1.0
    if not smoke:
        assert enabled_frac <= 0.05, (
            f"enabled tracing costs {enabled_frac:.1%} throughput "
            "(budget 5%)")
    # tracer cost, disabled: the null tracer's measured per-call cost
    # times the records-per-step rate this run actually produced,
    # against the untraced per-step wall — an upper bound on what the
    # instrumentation costs every untraced serve
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        with NULL_TRACER.span("engine", "x", kind="compute"):
            NULL_TRACER.instant("engine", "y", rid=0)
    per_record_s = (time.perf_counter() - t0) / (2 * n)
    records_per_step = len(records) / max(report["steps"], 1)
    disabled_frac = (per_record_s * records_per_step
                     / (base_total_s / base_steps))
    assert disabled_frac <= 0.01, (
        f"disabled tracing costs {disabled_frac:.2%} of a step "
        "(budget 1%)")

    tracer.export_chrome(trace_path)
    overhead = {
        "records": len(records),
        "subsystems": sorted(subs),
        "overhead": report,
        "enabled_overhead_fraction": enabled_frac,
        "disabled_overhead_fraction": disabled_frac,
        "baseline_wall_s": base_s,
        "traced_wall_s": traced_s,
    }
    report_path = os.path.splitext(trace_path)[0] + ".report.json"
    with open(report_path, "w") as f:
        json.dump(overhead, f, indent=2)
    if verbose:
        c = report["categories_ms"]
        split = " ".join(f"{k}={c[k]:.1f}ms" for k in sorted(c)
                        if c[k] > 0.0)
        print(f"# serve_bench traced  {len(records)} records, "
              f"{len(subs)} subsystems, "
              f"compute={report['compute_fraction']:.0%} "
              f"overhead={report['overhead_fraction']:.0%} [{split}] "
              f"residual={report['sum_residual']:.1%} "
              f"cost on/off={enabled_frac:+.1%}/{disabled_frac:.2%} "
              f"-> {trace_path}")
    emit("serve_trace_records", len(records), "events")
    emit("serve_trace_overhead_fraction",
         report["overhead_fraction"], "of_step_wall")
    emit("serve_trace_cost_enabled", enabled_frac * 100, "percent")
    return overhead


def _slo_run(params, cfg, smoke, seed, verbose, report_path=None):
    """SLO/goodput observability run (DESIGN.md §10): serve a
    deadline-carrying pressure trace on the full stack — disaggregated
    prefill/decode over a 2-shard tiered pool — with the flight
    recorder on, and check the three promises the recorder makes:

    * verdicts stream into the ``slo.*`` registry names and the
      goodput report reconciles with them (every missed request gets
      exactly one blame bucket; goodput is deterministic because the
      deadline mix is load-independent: tight deadlines no machine
      can meet, loose ones none can miss);
    * the recorder's exec/handoff durations reconcile with the §10
      causal-trace attribution measured on the SAME drive (summed
      flight-recorder durs vs summed span durs of the same
      boundaries, residual <= 5% each);
    * recorder cost stays inside the tracing budgets — <= 5% enabled
      (recorder-on vs recorder-off twins, interleaved, min-wall,
      outside ``--smoke``) and <= 1% disabled (the measured
      null-recorder guard cost times the observed events-per-step
      rate, against the recorder-off per-step wall).

    Also round-trips the end-of-run registry through both exporters
    (Prometheus text and one JSONL snapshot) against ``snapshot()``.
    """
    import dataclasses
    import os
    import tempfile

    from repro.obs.attribution import attribute_roles
    from repro.obs.export import (JsonlExporter, read_jsonl,
                                  verify_roundtrip)
    from repro.obs.slo import (EXEC_EVENTS, HANDOFF_EVENTS,
                               NULL_RECORDER, build_report)
    from repro.obs.trace import Tracer, set_global
    from repro.serving.engine import make_engine

    kw = dict(slots=SLOTS_PAGED, max_len=MIXED_MAX_LEN,
              prefill_buckets=(32,), page_size=PAGE_SIZE,
              n_pages=TIER_DEVICE_PAGES, chunk_size=CHUNK,
              step_tokens=STEP_TOKENS, kv_shards=2, tiering=True,
              host_pages=48, disagg=True)
    base_reqs = _pressure_requests(cfg, n=6, max_new=8 if smoke else 24,
                                   seed=seed)
    # the deadline mix is load-independent so goodput is exact on any
    # machine: every 3rd request gets a TTFT deadline nothing can meet
    # (tighter than one decode step), the next a same-tight ITL
    # deadline, the rest 10-minute deadlines nothing can miss
    TIGHT_MS, LOOSE_MS = 0.05, 600_000.0
    n_loose = sum(1 for i in range(len(base_reqs)) if i % 3 == 2)

    def _with_deadlines(off):
        return [dataclasses.replace(
            r, rid=r.rid + off,
            ttft_deadline_ms=TIGHT_MS if i % 3 == 0 else LOOSE_MS,
            itl_deadline_ms=TIGHT_MS if i % 3 == 1 else LOOSE_MS)
            for i, r in enumerate(base_reqs)]

    warm = (97, 90, 33, 12)
    reps = 2 if smoke else 6

    def _drive(eng, rid_off):
        rs = _with_deadlines(rid_off)
        n0 = len(eng.completions)
        for r in rs[:2]:
            eng.submit(r)
        for _ in range(3):
            eng.step()
        eng.force_migrate()
        for r in rs[2:]:
            eng.submit(r)
        eng.run_to_completion()
        return {c.rid - rid_off: c.tokens
                for c in eng.completions[n0:]}

    def _timed_drive(eng, rid_off):
        t0 = time.perf_counter()
        toks = _drive(eng, rid_off)
        return time.perf_counter() - t0, toks

    # scratch engine absorbs process-level compiles (the forced
    # migration's permutation program) so the twins compare scheduling
    scratch = make_engine(params, cfg, engine="chunked", **kw)
    _warmup(scratch, cfg, warm)
    _drive(scratch, 0)

    base = make_engine(params, cfg, engine="chunked", **kw)
    _warmup(base, cfg, warm)
    eng = make_engine(params, cfg, engine="chunked",
                      flight_recorder=True, **kw)
    _warmup(eng, cfg, warm)

    # recorder cost, enabled: recorder-off vs recorder-on twins (both
    # classify — deadlines ride on every request — so the delta is the
    # recorder alone), interleaved back to back so each pair shares
    # system state.  The budget is judged on the min per-pair ratio,
    # not min(rec)/min(base): one lucky-fast baseline rep would
    # inflate the cross-pair ratio by the machine's full noise band
    # (±3-4% at these run lengths), while a real systematic cost
    # shows up in every pair and survives the min
    base_walls, rec_walls = [], []
    base_toks, rec_toks = [], []
    for k in range(reps):
        w, t = _timed_drive(base, 100 * k)
        base_walls.append(w)
        base_toks.append(t)
        w, t = _timed_drive(eng, 100 * k)
        rec_walls.append(w)
        rec_toks.append(t)
    assert rec_toks == base_toks, (
        "the flight recorder changed the served tokens — "
        "instrumentation must be observation only")
    base_s, rec_s = min(base_walls), min(rec_walls)
    enabled_frac = min(r / b for r, b in zip(rec_walls,
                                             base_walls)) - 1.0
    if not smoke:
        assert enabled_frac <= 0.05, (
            f"enabled flight recording costs {enabled_frac:.1%} "
            "throughput (budget 5%)")

    # recorder cost, disabled: every hook site is one attribute load +
    # branch on NULL_RECORDER.enabled; measure it and scale by the
    # events-per-step rate this run actually produced
    n_events = sum(len(eng.recorder.timeline(r))
                   for r in eng.recorder.rids())
    n_steps = max(len(eng.counters), 1)
    n = 200_000
    rec = NULL_RECORDER
    t0 = time.perf_counter()
    for _ in range(n):
        if rec.enabled:
            rec.event(0, "x", dur=0.0)
    per_guard_s = (time.perf_counter() - t0) / n
    base_step_s = sum(base_walls) / max(len(base.counters), 1)
    disabled_frac = per_guard_s * (n_events / n_steps) / base_step_s
    assert disabled_frac <= 0.01, (
        f"disabled flight recording costs {disabled_frac:.2%} of a "
        "step (budget 1%)")

    # reconciliation drive: tracer AND recorder on the same engine,
    # telemetry wiped first so both views cover exactly one drive
    eng.completions.clear()
    eng.reset_metrics()              # registry + recorder + verdicts
    eng.counters.clear()
    tracer = Tracer(capacity=1 << 18)
    eng.set_tracer(tracer)
    set_global(tracer)
    try:
        _drive(eng, 100 * reps)
    finally:
        set_global(None)
    records = tracer.records()
    assert tracer.dropped == 0

    # flight-recorder exec/handoff durs vs the causal-trace spans that
    # wrap the same boundaries: summed over the drive they must agree
    # (same clock, same edges — the budget absorbs the per-op hook
    # skew and stage copies the recorder skips on snapshot misses)
    fr_exec = fr_handoff = 0.0
    for rid in eng.recorder.rids():
        ph = eng.recorder.phases(rid)
        fr_exec += ph.get("prefill_exec", 0.0) \
            + ph.get("prefill_exec_post", 0.0)
        fr_handoff += ph.get("handoff", 0.0)
    # subsystem-filtered: kvcache/"restore" is the page-level child
    # nested INSIDE engine/"restore" and percolation handoff commits —
    # name-only summation would double-count it
    span_exec = sum(r.dur for r in records
                    if r.subsystem == "engine"
                    and r.name in EXEC_EVENTS and r.dur is not None)
    span_handoff = sum(r.dur for r in records
                       if r.subsystem == "percolation"
                       and r.name in HANDOFF_EVENTS
                       and r.dur is not None)
    # the FR hook brackets the span (two extra clock reads + the ring
    # append land inside the FR dur), so each op carries a small fixed
    # skew — for µs-scale ops (handoff commits are table rebuilds)
    # that fixed part dominates a purely relative budget, so each
    # bucket gets 5% relative OR 50µs-per-op absolute slack
    _SKEW_S = 50e-6
    n_exec_ops = sum(1 for r in records if r.subsystem == "engine"
                     and r.name in EXEC_EVENTS and r.dur is not None)
    n_hand_ops = sum(1 for r in records
                     if r.subsystem == "percolation"
                     and r.name in HANDOFF_EVENTS
                     and r.dur is not None)
    exec_residual = abs(fr_exec - span_exec) / max(span_exec, 1e-9)
    handoff_residual = (abs(fr_handoff - span_handoff)
                        / max(span_handoff, 1e-9))
    assert span_exec > 0.0 and span_handoff > 0.0
    assert (exec_residual <= 0.05
            or abs(fr_exec - span_exec) <= _SKEW_S * n_exec_ops), (
        f"flight-recorder prefill exec ({fr_exec * 1e3:.1f}ms) does "
        f"not reconcile with the traced {span_exec * 1e3:.1f}ms "
        f"(residual {exec_residual:.1%}, budget 5% or "
        f"{_SKEW_S * n_exec_ops * 1e3:.2f}ms)")
    assert (handoff_residual <= 0.05
            or abs(fr_handoff - span_handoff)
            <= _SKEW_S * n_hand_ops), (
        f"flight-recorder handoff ({fr_handoff * 1e3:.1f}ms) does "
        f"not reconcile with the traced {span_handoff * 1e3:.1f}ms "
        f"(residual {handoff_residual:.1%}, budget 5% or "
        f"{_SKEW_S * n_hand_ops * 1e3:.2f}ms)")
    roles = attribute_roles(records)
    assert roles["roles_ms"].get("prefill", 0.0) > 0.0
    assert set(roles["localities_ms"]) >= {"loc0", "loc1"}

    # goodput report vs the deterministic deadline mix
    report = build_report(eng)
    assert report["requests"] == len(base_reqs)
    assert report["met"] == n_loose
    assert abs(report["goodput"] - n_loose / len(base_reqs)) < 1e-9
    assert report["ttft_misses"] > 0 and report["itl_misses"] > 0
    blamed = sum(report["blame"].values())
    assert blamed == report["requests"] - report["met"], (
        "every missed request must land in exactly one blame bucket")
    assert report["blame"]["unattributed"] == 0, (
        "recorder was on: no miss should be unattributed")

    # exporter round-trips against the live registry
    problems = verify_roundtrip(eng.metrics)
    assert not problems, f"prometheus round-trip: {problems[:3]}"
    fd, jl_path = tempfile.mkstemp(suffix=".jsonl")
    os.close(fd)
    try:
        with JsonlExporter(eng.metrics, jl_path) as exp:
            exp.snap(step=n_steps)
        got = read_jsonl(jl_path)[-1]["metrics"]
        want = eng.metrics.snapshot()
        assert set(got) == set(want)
        assert all(abs(got[k] - want[k]) <= 1e-9 * max(
            1.0, abs(want[k])) for k in want)
    finally:
        os.unlink(jl_path)

    report["recorder"] = {
        "enabled_overhead_fraction": enabled_frac,
        "disabled_overhead_fraction": disabled_frac,
        "events": n_events,
        "events_per_step": n_events / n_steps,
        "exec_residual": exec_residual,
        "handoff_residual": handoff_residual,
        "roles_ms": roles["roles_ms"],
        "localities_ms": roles["localities_ms"],
        "baseline_wall_s": base_s,
        "recorded_wall_s": rec_s,
    }
    if report_path:
        with open(report_path, "w") as f:
            json.dump(report, f, indent=2)
    if verbose:
        b = report["blame"]
        blame_s = " ".join(f"{k}={b[k]}" for k in sorted(b) if b[k])
        print(f"# serve_bench slo     goodput={report['goodput']:.2f} "
              f"({report['met']}/{report['requests']} met, "
              f"ttft_miss={report['ttft_misses']} "
              f"itl_miss={report['itl_misses']}) [{blame_s}] "
              f"recon exec/handoff="
              f"{exec_residual:.1%}/{handoff_residual:.1%} "
              f"cost on/off={enabled_frac:+.1%}/{disabled_frac:.2%}"
              + (f" -> {report_path}" if report_path else ""))
    emit("serve_slo_goodput", report["goodput"], "fraction")
    emit("serve_slo_recorder_cost_enabled", enabled_frac * 100,
         "percent")
    emit("serve_slo_recorder_cost_disabled", disabled_frac * 100,
         "percent")
    emit("serve_slo_exec_residual", exec_residual * 100, "percent")
    return report


def _chaos_run(params, cfg, smoke, seed, verbose):
    """Chaos drill (DESIGN.md §4g): serve the pressure trace on the
    full stack — disaggregated prefill/decode over a 2-shard tiered
    pool — twice from identically warmed engines: once failure-free
    (the token ground truth), and once with a failure plan that kills
    KV shard 1 mid-wave.  EVERY future must resolve, and every
    request's greedy tokens must be identical to the failure-free run
    — asserted per rid over the full wave, not sampled.  Pages with a
    host-tier percolation copy rebuild on the survivor; the rest
    drain their slots and re-prefill from the retained prompt +
    position clock.  The dead shard then re-joins elastically and a
    second wave must come back token-identical on the healed pool."""
    import dataclasses

    from repro.ft.failures import FailurePlan
    from repro.serving.engine import make_engine

    kw = dict(slots=SLOTS_PAGED, max_len=MIXED_MAX_LEN,
              prefill_buckets=(32,), page_size=PAGE_SIZE,
              n_pages=TIER_DEVICE_PAGES, chunk_size=CHUNK,
              step_tokens=STEP_TOKENS, kv_shards=2, tiering=True,
              host_pages=48, disagg=True)
    reqs = _pressure_requests(cfg, n=6, max_new=8 if smoke else 24,
                              seed=seed)
    warm = (97, 90, 33, 12)

    ref_eng = make_engine(params, cfg, engine="chunked", **kw)
    _warmup(ref_eng, cfg, warm)
    ref_futs = [ref_eng.submit(r) for r in reqs]
    ref_eng.run_to_completion()
    truth = {f.get().rid: f.get().tokens for f in ref_futs}

    eng = make_engine(params, cfg, engine="chunked", **kw)
    _warmup(eng, cfg, warm)
    # armed AFTER warmup: the plan counts engine steps, and warmup
    # wipes the counters it counts against
    eng.failure_plan = FailurePlan.kill_locality(1, at_step=CHAOS_AT)
    futs = [eng.submit(dataclasses.replace(r)) for r in reqs]
    t0 = time.perf_counter()
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    tok = sum(len(c.tokens) for c in eng.completions)

    unresolved = [f for f in futs if not f.done()]
    assert not unresolved, (
        f"{len(unresolved)} futures never resolved after the kill — "
        "recovery must re-admit, not error")
    got = {f.get().rid: f.get().tokens for f in futs}
    assert got == truth, (
        "chaos outputs diverge from the failure-free run — rebuild "
        "and re-prefill must not change a token")
    st = eng.stats()
    rec = st["recovery"]
    assert rec["localities_killed"] == 1, "the failure plan never fired"
    assert rec["drained_slots"] + rec["pages_rebuilt"] > 0, (
        "the kill landed on an idle pool — the drill proves nothing")
    assert eng.kvc.pool.used_pages == 0

    # elastic re-join, then a second wave on the healed 2-shard pool
    moved = eng.join_locality(1)
    assert eng.kvc.pool.agas.is_active(1)
    futs2 = [eng.submit(dataclasses.replace(r, rid=r.rid + 100))
             for r in reqs]
    eng.run_to_completion()
    got2 = {f.get().rid - 100: f.get().tokens for f in futs2}
    assert got2 == truth, (
        "post-rejoin outputs diverge — the healed pool must serve "
        "identically")
    assert eng.kvc.pool.used_pages == 0

    out = dict(_eng_stats(st, eng.slots, tok, dt),
               kill_shard=1, kill_step=CHAOS_AT,
               n_requests=len(reqs),
               localities_killed=rec["localities_killed"],
               pages_rebuilt=rec["pages_rebuilt"],
               pages_lost=rec["pages_lost"],
               drained_slots=rec["drained_slots"],
               re_prefills=rec["re_prefills"],
               recovery_restarts=rec["recovery_restarts"],
               rejoin_moves=moved)
    if verbose:
        print(f"# serve_bench chaos   {tok / dt:8.1f} tok/s "
              f"(pressure, shard 1 killed at step {CHAOS_AT}) "
              f"rebuilt={rec['pages_rebuilt']} "
              f"lost={rec['pages_lost']} "
              f"drained={rec['drained_slots']} "
              f"re_prefills={rec['re_prefills']} "
              "token-identical to failure-free run "
              "(and again after re-join)")
    emit("serve_chaos_tok_s", tok / dt, "tok_per_s")
    emit("serve_chaos_pages_rebuilt", rec["pages_rebuilt"], "pages")
    emit("serve_chaos_pages_lost", rec["pages_lost"], "pages")
    emit("serve_chaos_drained_slots", rec["drained_slots"], "slots")
    emit("serve_chaos_re_prefills", rec["re_prefills"], "requests")
    return out


#: Step the --chaos failure plan fires at: far enough in that the
#: wave is mid-flight (slots bound, handoffs staged), early enough
#: that nothing has finished.
CHAOS_AT = 4

#: Bench-trajectory identity: BENCH_<n>.json files carry this id so
#: tools/bench_compare.py can order them and diff against the
#: previous one.
BENCH_ID = 9

#: Floors embedded in the committed BENCH_9.json, checked by
#: tools/bench_compare.py on full (non ``--smoke``) runs.  Throughput
#: floors sit ~20% under the LOWEST of several full-run measurements
#: (the PR 7/8 lesson: floors near the quiet median trip on scheduler
#: noise and guard nothing; observed run-to-run spread on tok/s is
#: ~35%, e.g. tiered 415-628 tok/s over four runs on one machine);
#: skip fractions are deterministic at a fixed seed, and slo.goodput
#: is deterministic on any machine (the deadline mix is
#: load-independent), so those floors stay tight.
BENCH_FLOORS = {
    "chunked_mixed.tok_s": 900.0,
    "disagg_mixed.tok_s": 900.0,
    "tiered_pressure.tok_s": 300.0,
    "prefix_fixed.skip_fraction": 0.8,
    "prefix_mixed.skip_fraction": 0.7,
    "slo.goodput": 0.33,
}


def _bench_scenarios(result):
    """Flatten one serve_bench result dict into the schema'd scenario
    map BENCH_<n>.json carries: per-scenario latency percentiles,
    throughput, and the rates the floors guard.  Scenarios the run
    did not exercise are simply absent — bench_compare diffs the
    intersection."""
    def lat(d):
        return {k: d[k] for k in (
            "tok_s", "ttft_p50_ms", "ttft_p95_ms", "ttft_p99_ms",
            "itl_p50_ms", "itl_p95_ms", "itl_p99_ms", "preemptions")
            if k in d}

    sc = {}
    mt = result.get("mixed_trace")
    if mt:
        sc["paged_mixed"] = lat(mt["paged"])
        sc["chunked_mixed"] = lat(mt["chunked"])
        if "sharded" in mt:
            sc["sharded_mixed"] = dict(
                lat(mt["sharded"]),
                page_migrations=mt["sharded"]["page_migrations"])
    dt = result.get("disagg_trace")
    if dt:
        sc["disagg_mixed"] = dict(
            lat(dt), tput_vs_chunked=dt["tput_vs_chunked"],
            handoff_overlap=dt["handoff_overlap"],
            warm_wave_affinity=dt["warm_wave_affinity"])
    tt = result.get("tiered_trace")
    if tt:
        sc["tiered_pressure"] = dict(
            lat(tt["tiered"]), resident_ratio=tt["resident_ratio"],
            decode_penalty=tt["decode_penalty"],
            copy_compute_overlap=tt["tiered"]["copy_compute_overlap"])
    pt = result.get("prefix_trace")
    if pt:
        for kind in ("fixed", "mixed"):
            if kind in pt:
                w = pt[kind]
                sc[f"prefix_{kind}"] = dict(
                    lat(w["skip_on"]),
                    skip_fraction=w["skip_on"]["skip_fraction"],
                    ttft_p50_reduction_x=w["ttft_p50_reduction_x"])
    ch = result.get("chaos_trace")
    if ch:
        sc["chaos_pressure"] = dict(
            lat(ch), pages_rebuilt=ch["pages_rebuilt"],
            pages_lost=ch["pages_lost"],
            drained_slots=ch["drained_slots"],
            re_prefills=ch["re_prefills"])
    sl = result.get("slo")
    if sl:
        sc["slo"] = {
            "goodput": sl["goodput"],
            "requests": sl["requests"],
            "met": sl["met"],
            "ttft_misses": sl["ttft_misses"],
            "itl_misses": sl["itl_misses"],
            "recorder_cost_enabled":
                sl["recorder"]["enabled_overhead_fraction"],
            "recorder_cost_disabled":
                sl["recorder"]["disabled_overhead_fraction"],
            "exec_residual": sl["recorder"]["exec_residual"],
        }
    return sc


def run(verbose=True, out_path=None, smoke=False, kv_shards=0,
        tiering=False, host_pages=0, prefix_heavy=False, seed=0,
        trace_path=None, disagg=False, slo=False, slo_report=None,
        chaos=False, bench_out=None):
    import jax

    import repro.configs as configs
    from repro.models import transformer as T
    from repro.serving.engine import make_engine

    cfg = configs.get_reduced(ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    result = {"arch": ARCH, "page_size": PAGE_SIZE, "seed": seed}

    # -- dense vs paged on the short trace ----------------------------
    short = _short_requests(cfg, 4 if smoke else 16,
                            max_new=4 if smoke else MAX_NEW,
                            seed=seed)
    kw_short = dict(max_len=DENSE_MAX_LEN, prefill_buckets=(32,))
    dense = make_engine(params, cfg, engine="dense",
                        slots=SLOTS_DENSE, **kw_short)
    _warmup(dense, cfg, (12,))
    dense_s, dense_tok = _serve(dense, short)

    paged_s_eng = make_engine(params, cfg, engine="paged",
                              slots=SLOTS_PAGED, page_size=PAGE_SIZE,
                              n_pages=DENSE_N_PAGES, **kw_short)
    _warmup(paged_s_eng, cfg, (12,))
    pshort_s, pshort_tok = _serve(paged_s_eng, short)
    ps_st = paged_s_eng.stats()

    result["short_trace"] = {
        "kv_token_rows": SLOTS_DENSE * DENSE_MAX_LEN,
        "n_requests": len(short),
        "dense": {"slots": SLOTS_DENSE, "tok_s": dense_tok / dense_s,
                  "wall_s": dense_s, "peak_active": SLOTS_DENSE},
        "paged": _eng_stats(ps_st, SLOTS_PAGED, pshort_tok, pshort_s),
    }

    # -- whole-prompt vs chunked on the mixed trace -------------------
    mixed = _mixed_requests(cfg, n_short=4 if smoke else N_SHORT,
                            n_long=1 if smoke else N_LONG,
                            max_new=4 if smoke else MAX_NEW,
                            seed=seed)
    kw_mixed = dict(max_len=MIXED_MAX_LEN, prefill_buckets=(32,),
                    slots=SLOTS_PAGED, page_size=PAGE_SIZE,
                    n_pages=MIXED_N_PAGES)
    # cover every bucket a preempted request's re-admission can land
    # in (32/64/96/128), not just the fresh-prompt buckets — otherwise
    # a preemption drops an XLA compile inside the timed region
    warm_lens = (97, 90, 33, 12)

    paged = make_engine(params, cfg, engine="paged", **kw_mixed)
    _warmup(paged, cfg, warm_lens)
    paged_s, paged_tok = _serve(paged, mixed)
    pst = paged.stats()

    chunked = make_engine(params, cfg, engine="chunked",
                          chunk_size=CHUNK, step_tokens=STEP_TOKENS,
                          **kw_mixed)
    _warmup(chunked, cfg, warm_lens)
    chunked_s, chunked_tok = _serve(chunked, mixed)
    cst = chunked.stats()

    result["mixed_trace"] = {
        "pages": MIXED_N_PAGES, "chunk_size": CHUNK,
        "step_tokens": STEP_TOKENS,
        "n_long": 1 if smoke else N_LONG,
        "n_short": 4 if smoke else N_SHORT,
        "paged": _eng_stats(pst, SLOTS_PAGED, paged_tok, paged_s),
        "chunked": _eng_stats(cst, SLOTS_PAGED, chunked_tok,
                              chunked_s),
    }

    # -- sharded pool on the mixed trace (DESIGN.md §4c) --------------
    if kv_shards > 1:
        baseline = {c.rid: c.tokens for c in chunked.completions}
        sh = _serve_sharded(params, cfg, kw_mixed, warm_lens, mixed,
                            kv_shards, baseline)
        result["mixed_trace"]["sharded"] = sh
        if verbose:
            occ = ", ".join(f"{o:.2f}" for o in sh["shard_occupancy"])
            print(f"# serve_bench sharded {sh['tok_s']:8.1f} tok/s "
                  f"(mixed, {kv_shards} shards, {sh['backing']}) "
                  f"occ=[{occ}] migrations={sh['page_migrations']} "
                  "token-identical to single-locality")
        emit("serve_sharded_tok_s", sh["tok_s"], "tok_per_s")
        emit("serve_sharded_page_migrations", sh["page_migrations"],
             f"kv_shards_{kv_shards}")

    # -- disaggregated prefill/decode on the mixed trace (§4f) --------
    if disagg:
        baseline = {c.rid: c.tokens for c in chunked.completions}
        deng = make_engine(params, cfg, engine="chunked",
                           chunk_size=CHUNK, step_tokens=STEP_TOKENS,
                           kv_shards=DISAGG_SHARDS, disagg=True,
                           **kw_mixed)
        _warmup(deng, cfg, warm_lens)
        disagg_s, disagg_tok = _serve(deng, mixed)
        dst = deng.stats()
        got = {c.rid: c.tokens for c in deng.completions}
        assert got == baseline, (
            "disaggregated outputs diverge from the single-locality "
            "chunked engine — parcels and handoffs must not change a "
            "token")
        # every request that reached decode crossed one handoff
        assert dst["handoffs"] > 0 and dst["handoff_bytes"] > 0
        assert 0.0 <= dst["handoff_overlap"] <= 1.0
        owner, total = _disagg_affinity_wave(deng, cfg, seed)
        affinity = owner / max(total, 1)
        assert affinity >= DISAGG_AFFINITY_FLOOR, (
            f"warm wave sent only {affinity:.0%} of its prefill "
            f"parcels to the prefix-owner locality "
            f"({owner}/{total}, floor {DISAGG_AFFINITY_FLOOR:.0%})")
        tput_ratio = (disagg_tok / disagg_s) \
            / (chunked_tok / chunked_s)
        if not smoke:
            assert tput_ratio >= DISAGG_TPUT_FLOOR, (
                f"disagg throughput is {tput_ratio:.2f}x the "
                f"single-locality chunked engine "
                f"(floor {DISAGG_TPUT_FLOOR})")
        result["disagg_trace"] = dict(
            _eng_stats(dst, deng.slots, disagg_tok, disagg_s),
            kv_shards=DISAGG_SHARDS,
            prefill_workers=dst["prefill_workers"],
            decode_workers=dst["decode_workers"],
            tput_vs_chunked=tput_ratio,
            prefill_parcels=dst["prefill_parcels"],
            parcels_sent=dst["parcels_sent"],
            parcels_local=dst["parcels_local"],
            dispatch_sizes=dst["dispatch_sizes"],
            handoffs=dst["handoffs"],
            handoff_bytes=dst["handoff_bytes"],
            handoff_overlap=dst["handoff_overlap"],
            warm_wave_affinity=affinity,
            warm_wave_parcels=total)
        if verbose:
            print(f"# serve_bench disagg  "
                  f"{disagg_tok / disagg_s:8.1f} tok/s (mixed, "
                  f"{dst['prefill_workers']}P/"
                  f"{dst['decode_workers']}D, "
                  f"{tput_ratio:.2f}x chunked) "
                  f"handoffs={dst['handoffs']} "
                  f"({dst['handoff_bytes']}B, "
                  f"overlap={dst['handoff_overlap']:.2f}) "
                  f"affinity={affinity:.0%} "
                  "token-identical to single-locality")
        emit("serve_disagg_tok_s", disagg_tok / disagg_s, "tok_per_s")
        emit("serve_disagg_handoff_bytes", dst["handoff_bytes"],
             "bytes")
        emit("serve_disagg_handoff_overlap", dst["handoff_overlap"],
             "fraction")
        emit("serve_disagg_affinity", affinity, "of_warm_parcels")
        emit("serve_disagg_parcels", dst["prefill_parcels"],
             f"sent_{dst['parcels_sent']}_local_"
             f"{dst['parcels_local']}")

    # -- two-tier percolation on the pressure trace (§4d) -------------
    if tiering:
        hp = host_pages or TIER_HOST_PAGES
        press = _pressure_requests(cfg, n=5 if smoke else N_PRESSURE,
                                   max_new=8 if smoke else TIER_MAX_NEW,
                                   seed=seed)
        kw_tier = dict(max_len=MIXED_MAX_LEN, prefill_buckets=(32,),
                       slots=SLOTS_TIERED, page_size=PAGE_SIZE,
                       chunk_size=CHUNK,
                       step_tokens=SLOTS_TIERED + 2 * CHUNK)
        warm_tier = (97, 90, 33, 12)

        def _press_run(**kw):
            eng = make_engine(params, cfg, engine="chunked",
                              **kw_tier, **kw)
            _warmup(eng, cfg, warm_tier)
            dt, tok = _serve(eng, press)
            return eng, eng.stats(), dt, tok

        def _decode_tok_s(eng):
            """Decode throughput from the step counters: tokens the
            decode batch produced per second of decode-batch time.
            Unlike wall tok/s it excludes transfer stalls, which on a
            real accelerator overlap compute — this is the number the
            <= 15% tiering-penalty budget is about."""
            tok = sum(c.get("decode_tokens", c["active"])
                      for c in eng.counters)
            ms = sum(c["decode_ms"] for c in eng.counters)
            return tok / (ms / 1e3) if ms else 0.0

        # token ground truth: an ample pool that never preempts
        ample_pages = SLOTS_TIERED * MIXED_MAX_LEN // PAGE_SIZE
        ample_eng, _, _, _ = _press_run(n_pages=ample_pages)
        truth = {c.rid: c.tokens for c in ample_eng.completions}

        # same tiny device budget, tiering off vs on
        base_eng, bst, base_s, base_tok = _press_run(
            n_pages=TIER_DEVICE_PAGES)
        tier_eng, tst, tier_s, tier_tok = _press_run(
            n_pages=TIER_DEVICE_PAGES, tiering=True, host_pages=hp)
        got = {c.rid: c.tokens for c in tier_eng.completions}
        assert got == truth, (
            "tiered outputs diverge from the ample-pool reference — "
            "restore is supposed to be byte-exact")
        resident_x = tst["peak_resident"] / max(bst["peak_resident"], 1)
        if not smoke:
            assert resident_x >= 2.0, (
                f"tiering holds only {resident_x:.2f}x the resident "
                f"requests ({tst['peak_resident']} vs "
                f"{bst['peak_resident']}) at {TIER_DEVICE_PAGES} "
                "device pages")
        result["tiered_trace"] = {
            "device_pages": TIER_DEVICE_PAGES, "host_pages": hp,
            "n_requests": len(press),
            "untiered": dict(
                _eng_stats(bst, SLOTS_TIERED, base_tok, base_s),
                peak_resident=bst["peak_resident"],
                mean_resident=bst["mean_resident"]),
            "tiered": dict(
                _eng_stats(tst, SLOTS_TIERED, tier_tok, tier_s),
                peak_resident=tst["peak_resident"],
                mean_resident=tst["mean_resident"],
                offloads=tst["offloads"], restores=tst["restores"],
                offload_bytes=tst["offload_bytes"],
                promote_bytes=tst["promote_bytes"],
                prefetch_hits=tst["prefetch_hits"],
                demand_promotes=tst["demand_promotes"],
                copy_compute_overlap=tst["copy_compute_overlap"],
                evictions=tst["evictions"]),
            "resident_ratio": resident_x,
            "decode_tok_s_untiered": _decode_tok_s(base_eng),
            "decode_tok_s_tiered": _decode_tok_s(tier_eng),
            "decode_penalty": 1.0 - _decode_tok_s(tier_eng)
            / max(_decode_tok_s(base_eng), 1e-9),
        }
        if verbose:
            t = result["tiered_trace"]
            print(f"# serve_bench tiered  {tier_tok / tier_s:8.1f} tok/s "
                  f"(pressure, {TIER_DEVICE_PAGES}+{hp} pages) "
                  f"resident={tst['peak_resident']} "
                  f"({resident_x:.1f}x untiered) "
                  f"offload={tst['offload_bytes']}B "
                  f"promote={tst['promote_bytes']}B "
                  f"overlap={tst['copy_compute_overlap']:.2f} "
                  f"penalty={t['decode_penalty'] * 100:+.1f}% "
                  "token-identical to ample pool")
        emit("serve_tiered_tok_s", tier_tok / tier_s, "tok_per_s")
        emit("serve_untiered_tok_s", base_tok / base_s, "tok_per_s")
        emit("serve_tiered_decode_tok_s", _decode_tok_s(tier_eng),
             "tok_per_s")
        emit("serve_untiered_decode_tok_s", _decode_tok_s(base_eng),
             "tok_per_s")
        emit("serve_tiered_peak_resident", tst["peak_resident"],
             f"untiered_{bst['peak_resident']}")
        emit("serve_tiered_offload_bytes", tst["offload_bytes"],
             "bytes")
        emit("serve_tiered_promote_bytes", tst["promote_bytes"],
             "bytes")
        emit("serve_tiered_overlap", tst["copy_compute_overlap"],
             "fraction")

    # -- prefix-heavy shared-system-prompt trace (DESIGN.md §4e) ------
    if prefix_heavy:
        n_wave = 4 if smoke else PREFIX_N
        n_reps = 1 if smoke else PREFIX_REPEATS
        wave_new = 4 if smoke else PREFIX_MAX_NEW
        result["prefix_trace"] = {
            "pages": PREFIX_PAGES, "host_pages": PREFIX_HOST_PAGES,
            "sys_tokens": PREFIX_SYS,
        }
        # (wave kind, suffix spec, skip-fraction floor, TTFT floor):
        # the fixed wave is the regression baseline the padded keys
        # could already share; the mixed wave is what they could NOT
        for kind, floor_skip, floor_x in (("fixed", 0.8, 3.5),
                                          ("mixed", 0.7, 3.0)):
            seed_req, wave = _prefix_traces(
                cfg, n=n_wave, repeats=n_reps, max_new=wave_new,
                seed=seed, mixed=(kind == "mixed"))
            off, off_toks = _prefix_run(params, cfg, seed_req, wave,
                                        False)
            on, on_toks = _prefix_run(params, cfg, seed_req, wave,
                                      True)
            assert on_toks == off_toks, (
                f"compute-skip outputs diverge from the skip-off "
                f"reference on the {kind} wave — the skipped prefill "
                "is supposed to be exact")
            ttft_x = off["ttft_p50_ms"] / max(on["ttft_p50_ms"], 1e-9)
            if not smoke:
                assert on["skip_fraction"] >= floor_skip, (
                    f"{kind} wave skipped only "
                    f"{on['skip_fraction']:.0%} of its prefill tokens "
                    f"(floor {floor_skip:.0%})")
                assert ttft_x >= floor_x, (
                    f"compute skip cut {kind}-wave p50 TTFT only "
                    f"{ttft_x:.1f}x ({off['ttft_p50_ms']:.1f}ms -> "
                    f"{on['ttft_p50_ms']:.1f}ms, floor {floor_x:.0f}x)")
                assert on["prefix_skips"] >= n_reps, (
                    "the exact-repeat requests did not admit straight "
                    "to decode")
                assert on["prefix_partial_hits"] >= n_wave - n_reps, (
                    f"{kind}-wave partial covers were not admitted "
                    "through the radix longest-prefix match")
            result["prefix_trace"][kind] = {
                "user_tokens": (list(PREFIX_USER_MIX)
                                if kind == "mixed" else PREFIX_USER),
                "n_requests": len(wave),
                "skip_off": off, "skip_on": on,
                "ttft_p50_reduction_x": ttft_x,
            }
            if verbose:
                print(f"# serve_bench prefix  {on['tok_s']:8.1f} "
                      f"tok/s (warm shared-prefix {kind} wave, "
                      f"{PREFIX_PAGES} pages) "
                      f"ttft_p50={on['ttft_p50_ms']:.1f}ms "
                      f"vs {off['ttft_p50_ms']:.1f}ms skip-off "
                      f"({ttft_x:.1f}x) "
                      f"skipped={on['skip_fraction']:.0%} "
                      f"full_skips={on['prefix_skips']} "
                      f"partial_hits={on['prefix_partial_hits']} "
                      "token-identical to skip-off")
            tag = "" if kind == "fixed" else "_mixed"
            emit(f"serve_prefix{tag}_warm_tok_s", on["tok_s"],
                 "tok_per_s")
            emit(f"serve_prefix{tag}_ttft_p50_on",
                 on["ttft_p50_ms"] * 1e3, "us")
            emit(f"serve_prefix{tag}_ttft_p50_off",
                 off["ttft_p50_ms"] * 1e3, "us")
            emit(f"serve_prefix{tag}_ttft_reduction", ttft_x, "x_p50")
            emit(f"serve_prefix{tag}_skip_fraction",
                 on["skip_fraction"], "fraction")
            emit(f"serve_prefix{tag}_full_skips", on["prefix_skips"],
                 "requests")
            emit(f"serve_prefix{tag}_partial_hits",
                 on["prefix_partial_hits"], "requests")

    # -- causal trace + overhead attribution (DESIGN.md §10) ----------
    if trace_path:
        result["traced"] = _traced_run(params, cfg, trace_path, smoke,
                                       seed, verbose, disagg=disagg)

    # -- request-level SLO/goodput observability (DESIGN.md §10) ------
    if slo or slo_report:
        result["slo"] = _slo_run(params, cfg, smoke, seed, verbose,
                                 report_path=slo_report)

    # -- locality-loss chaos drill (DESIGN.md §4g) --------------------
    if chaos:
        result["chaos_trace"] = _chaos_run(params, cfg, smoke, seed,
                                           verbose)
    if verbose:
        print(f"# serve_bench dense   {dense_tok / dense_s:8.1f} tok/s "
              f"(short trace, peak_active={SLOTS_DENSE})")
        print(f"# serve_bench paged   {pshort_tok / pshort_s:8.1f} tok/s "
              f"(short trace, peak_active={ps_st['peak_active']})")
        print(f"# serve_bench paged   {paged_tok / paged_s:8.1f} tok/s "
              f"(mixed) ttft_p50={pst['ttft_p50_ms']:.1f}ms "
              f"itl_p50={pst['itl_p50_ms']:.2f}ms "
              f"preempt={pst['preemptions']}")
        print(f"# serve_bench chunked {chunked_tok / chunked_s:8.1f} tok/s "
              f"(mixed) ttft_p50={cst['ttft_p50_ms']:.1f}ms "
              f"itl_p50={cst['itl_p50_ms']:.2f}ms "
              f"preempt={cst['preemptions']}")
        print("# json " + json.dumps(result))
    # serve_dense/paged_tok_s stay the SAME short trace as PR 1 (the
    # equal-KV-bytes pair); the mixed-trace engines get their own names
    emit("serve_dense_tok_s", dense_tok / dense_s, "tok_per_s")
    emit("serve_paged_tok_s", pshort_tok / pshort_s, "tok_per_s")
    emit("serve_paged_mixed_tok_s", paged_tok / paged_s, "tok_per_s")
    emit("serve_chunked_tok_s", chunked_tok / chunked_s, "tok_per_s")
    emit("serve_paged_peak_active", ps_st["peak_active"],
         f"dense_slots_{SLOTS_DENSE}_equal_kv_bytes")
    emit("serve_paged_ttft_p50", pst["ttft_p50_ms"] * 1e3, "us")
    emit("serve_chunked_ttft_p50", cst["ttft_p50_ms"] * 1e3, "us")
    emit("serve_paged_itl_p50", pst["itl_p50_ms"] * 1e3, "us")
    emit("serve_chunked_itl_p50", cst["itl_p50_ms"] * 1e3, "us")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
    if bench_out:
        from benchmarks.common import write_bench
        doc = write_bench(
            bench_out, BENCH_ID, _bench_scenarios(result),
            floors=BENCH_FLOORS,
            meta={"arch": ARCH, "seed": seed, "smoke": bool(smoke),
                  "page_size": PAGE_SIZE})
        if verbose:
            print(f"# serve_bench bench trajectory: "
                  f"{len(doc['scenarios'])} scenarios -> {bench_out}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny traces (CI): exercises all three engines"
                         " without asserting the latency split")
    ap.add_argument("--kv-shards", type=int, default=0,
                    help="also serve the mixed trace from a pool "
                         "sharded over N AGAS localities (with a "
                         "forced migration) and assert token parity "
                         "with the single-locality engine")
    ap.add_argument("--tiering", action="store_true",
                    help="also serve the pressure trace untiered vs "
                         "two-tier (DESIGN.md §4d): write-back "
                         "offload, restore-not-reprefill, percolation "
                         "overlap; asserts token parity with an "
                         "ample-pool reference")
    ap.add_argument("--host-pages", type=int, default=0,
                    help="host-tier pages for --tiering "
                         f"(0 = {TIER_HOST_PAGES})")
    ap.add_argument("--prefix-heavy", action="store_true",
                    help="also serve the warm shared-system-prompt "
                         "waves with compute skip off vs on (DESIGN.md "
                         "§4e) at the same page budget: the fixed-"
                         "suffix wave asserts >= 3.5x p50 TTFT "
                         "reduction and >= 80% prefill tokens skipped "
                         "outside --smoke, the mixed-suffix-length "
                         "wave >= 3x and >= 70%, plus token parity "
                         "always")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="run the full stack (chunked + 2 KV shards + "
                         "tiering + forced migration) with the causal "
                         "tracer attached; writes a perfetto-viewable "
                         "Chrome trace to PATH and an overhead report "
                         "to PATH's .report.json sibling; asserts "
                         "span nesting, request->slot->page causal "
                         "links, and the tracer cost budgets")
    ap.add_argument("--disagg", action="store_true",
                    help="also serve the mixed trace on the "
                         "disaggregated prefill/decode engine "
                         "(DESIGN.md §4f): parcel-dispatched prefill "
                         "chunks over a 2-shard pool + percolation KV "
                         "handoffs; asserts token parity, >= 90% "
                         "prefix-owner dispatch affinity on a warm "
                         "wave, and reports handoff bytes/overlap. "
                         "With --trace, the traced run uses the "
                         "disagg engine too")
    ap.add_argument("--slo", action="store_true",
                    help="also run the SLO/goodput observability "
                         "drive (DESIGN.md §10): a deadline-carrying "
                         "pressure trace on the disagg 2-shard tiered "
                         "stack with the flight recorder on; asserts "
                         "deterministic goodput, blame/attribution "
                         "reconciliation (<= 5% residual), recorder "
                         "cost budgets (<= 5% on, <= 1% off), and the "
                         "Prometheus/JSONL exporter round-trips")
    ap.add_argument("--slo-report", default=None, metavar="PATH",
                    help="write the --slo goodput report (registry "
                         "aggregates, per-request verdicts + phase "
                         "decompositions, recorder overhead) to PATH "
                         "as JSON; implies --slo")
    ap.add_argument("--chaos", action="store_true",
                    help="also run the locality-loss chaos drill "
                         "(DESIGN.md §4g): the pressure trace on the "
                         "disagg 2-shard tiered stack with KV shard 1 "
                         f"killed at step {CHAOS_AT}; asserts every "
                         "future resolves token-identically to the "
                         "failure-free run (per rid, not sampled), "
                         "reports pages rebuilt from the host tier vs "
                         "lost, slots drained, and re-prefills, then "
                         "re-joins the shard and asserts a second "
                         "wave is identical too")
    ap.add_argument("--bench-out", default=None, metavar="PATH",
                    help=f"write the schema'd bench trajectory "
                         f"(BENCH_{BENCH_ID}.json: per-scenario "
                         "latency percentiles, throughput, goodput, "
                         "skip/handoff rates, floors) to PATH; diff "
                         "against the previous BENCH_*.json with "
                         "tools/bench_compare.py")
    ap.add_argument("--seed", type=int, default=0,
                    help="trace-generation seed: every trace "
                         "(short/mixed/pressure/prefix) derives from "
                         "it, so runs are reproducible across "
                         "machines")
    args = ap.parse_args()
    run(out_path=args.out, smoke=args.smoke, kv_shards=args.kv_shards,
        tiering=args.tiering, host_pages=args.host_pages,
        prefix_heavy=args.prefix_heavy, seed=args.seed,
        trace_path=args.trace, disagg=args.disagg, slo=args.slo,
        slo_report=args.slo_report, chaos=args.chaos,
        bench_out=args.bench_out)
