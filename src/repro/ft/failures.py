"""Failure injection for fault-tolerance tests and drills.

`FailurePlan` deterministically raises `InjectedFailure` at configured
steps — the supervisor (ft/supervisor.py) must recover from every one
of them by restarting from the last checkpoint (tests/test_ft.py).

The serving stack consumes the same plan through a different trigger:
`kill_locality(shard, at_step)` schedules the loss of one KV-cache
locality mid-serve.  Nothing is raised for those — the serving engine
polls `shard_to_kill` at the top of each step and runs its drain /
rebuild / re-admit protocol (DESIGN.md §4g) instead of unwinding the
stack, because in-flight requests must finish, not restart.
"""

from __future__ import annotations

import dataclasses
from typing import FrozenSet, Iterable, Optional, Tuple


class InjectedFailure(RuntimeError):
    """Stands in for a lost node / preemption / hardware fault."""


@dataclasses.dataclass(frozen=True)
class FailurePlan:
    fail_at_steps: FrozenSet[int] = frozenset()
    kind: str = "node_loss"
    #: (step, locality) pairs: at the top of `step`, serving locality
    #: `locality` dies (its KV pages are swept; see PagePool.kill_locality)
    kill_at: FrozenSet[Tuple[int, int]] = frozenset()

    @staticmethod
    def at(*steps: int) -> "FailurePlan":
        return FailurePlan(frozenset(steps))

    @staticmethod
    def kill_locality(shard: int, at_step: int) -> "FailurePlan":
        """A serving-facing plan: kill one KV locality at one step."""
        return FailurePlan(kill_at=frozenset({(int(at_step), int(shard))}))

    def check(self, step: int, already_failed: set) -> None:
        if step in self.fail_at_steps and step not in already_failed:
            already_failed.add(step)
            raise InjectedFailure(
                f"injected {self.kind} at step {step}")

    def shard_to_kill(self, step: int, already_killed: set
                      ) -> Optional[int]:
        """The serving-side trigger: which locality (if any) dies at
        `step`.  Fires once per (step, shard) pair; does not raise —
        the engine's recovery path keeps every request alive."""
        for at, shard in sorted(self.kill_at):
            if at == step and (at, shard) not in already_killed:
                already_killed.add((at, shard))
                return shard
        return None
