"""AGAS-managed paged KV cache (DESIGN.md §4a).

The ParalleX reading of KV memory: instead of a dense ``(slots,
max_len)`` cache statically owned by each decode slot, KV storage is a
pool of fixed-size *pages*, each a first-class globally-named object
allocated and freed through the AGAS directory (`core/agas.py`).  A
page's `GlobalAddress` is its immutable name; the AGAS slot it resolves
to is the physical row in the device-side page arrays, so a block-table
lookup compiles to a gather index — the same "nothing dynamic survives
to run time" rendering used for AMR blocks.

Three layers live here:

* `PagePool` — the allocator: AGAS-backed gid -> physical-row mapping,
  per-page refcounts, a prompt-prefix hash index enabling pages shared
  between requests (copy-on-write on first divergent append), and the
  device arrays themselves (``pages["k"]/pages["v"]`` of shape
  ``(L, n_pages + 1, page_size, KV, D)``; the extra trailing row is the
  *null page*, the write target of idle decode slots — never read
  because the per-slot masks exclude it).

* `PagedKVCache` — the per-engine view: one block table per decode
  slot mapping token position ``p`` to the physical row of page
  ``p // page_size``, plus **per-slot** position counters (replacing
  the dense cache's shared ``len/cursor/abs`` clock).  Prompts attach
  either whole (`attach`) or one page-aligned chunk at a time
  (`begin_chunk`, DESIGN.md §4b) — the prefix hash-chain is computed
  over the full prefix either way, so the two paths share pages with
  each other.

* `PageExhausted` — the backpressure signal: raised when the pool has
  no free page; the serving engine reacts by preempting a request back
  to the queue (the LCO analogue of a parcel being deferred).
"""

from __future__ import annotations

import dataclasses
import hashlib
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agas import AGAS, AGASError, GlobalAddress
from repro.core.localities import LocalityDomain
from repro.models.config import ArchConfig
from repro.models.transformer import PAGED_FAMILIES, init_paged_cache


class PageExhausted(RuntimeError):
    """No free page in the pool; callers preempt or defer."""


def page_keys(tokens: np.ndarray, page_size: int
              ) -> List[Tuple[bytes, int]]:
    """Chained prefix hashes, one per page of a (padded) prompt.

    Key i commits to ALL tokens in pages 0..i plus the page's fill
    count, so two requests share page i iff their padded prompts agree
    on every token up to and including it.
    """
    h = hashlib.blake2b(digest_size=16)
    keys: List[Tuple[bytes, int]] = []
    for start in range(0, len(tokens), page_size):
        chunk = np.asarray(tokens[start:start + page_size], np.int32)
        h.update(chunk.tobytes())
        keys.append((h.digest(), len(chunk)))
    return keys


# Jitted + donated page mutations: on accelerators the update happens
# in place instead of copying the whole pool per call (CPU falls back
# to a copy with a one-time donation warning).
@partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(arr, idx, spans):
    return arr.at[:, idx].set(spans)


@partial(jax.jit, donate_argnums=(0,))
def _clone_row(arr, src, dst):
    return arr.at[:, dst].set(arr[:, src])


class PagePool:
    """Refcounted AGAS page allocator + the device page arrays."""

    def __init__(self, cfg: ArchConfig, n_pages: int, page_size: int,
                 dtype=None):
        if cfg.family not in PAGED_FAMILIES:
            raise ValueError(
                f"paged KV cache supports {PAGED_FAMILIES}, "
                f"not {cfg.family!r}")
        self.cfg = cfg
        self.capacity = int(n_pages)
        self.page_size = int(page_size)
        self.null_row = self.capacity          # reserved garbage row
        # One locality: the serving engine is a single-device demo; a
        # sharded pool would use one locality per KV shard.
        self.agas = AGAS(LocalityDomain.simulated(1), self.capacity,
                         space="kvpage")
        self._refs: Dict[int, int] = {}            # gid -> refcount
        self._prefix: Dict[Tuple[bytes, int], GlobalAddress] = {}
        self._key_of: Dict[int, Tuple[bytes, int]] = {}
        self.pages: Dict[str, Any] = init_paged_cache(
            cfg, self.capacity + 1, self.page_size, dtype)
        # performance counters (Fig 9 spirit: runtime overhead visible)
        self.allocs = 0
        self.shares = 0
        self.cow_copies = 0

    # -- allocation / refcounting -------------------------------------
    @property
    def free_pages(self) -> int:
        return self.capacity - len(self._refs)

    @property
    def used_pages(self) -> int:
        return len(self._refs)

    def occupancy(self) -> float:
        return self.used_pages / max(self.capacity, 1)

    def alloc(self) -> GlobalAddress:
        try:
            addr = self.agas.allocate(0)
        except AGASError:
            raise PageExhausted(
                f"page pool exhausted ({self.capacity} pages)") from None
        self._refs[addr.gid] = 1
        self.allocs += 1
        return addr

    def incref(self, addr: GlobalAddress) -> None:
        self._refs[addr.gid] += 1

    def decref(self, addr: GlobalAddress) -> None:
        self._refs[addr.gid] -= 1
        if self._refs[addr.gid] == 0:
            del self._refs[addr.gid]
            key = self._key_of.pop(addr.gid, None)
            if key is not None:
                cur = self._prefix.get(key)
                if cur is not None and cur.gid == addr.gid:
                    del self._prefix[key]
            self.agas.free(addr)

    def refcount(self, addr: GlobalAddress) -> int:
        return self._refs[addr.gid]

    def row(self, addr: GlobalAddress) -> int:
        return self.agas.slot_of(addr)

    # -- prefix sharing ------------------------------------------------
    def lookup_prefix(self, key: Tuple[bytes, int]
                      ) -> Optional[GlobalAddress]:
        return self._prefix.get(key)

    def register_prefix(self, key: Tuple[bytes, int],
                        addr: GlobalAddress) -> None:
        # one key per page: a second registration (either direction)
        # is a no-op, so freeing a page can never leave a stale key
        # behind in the prefix index
        if key not in self._prefix and addr.gid not in self._key_of:
            self._prefix[key] = addr
            self._key_of[addr.gid] = key

    # -- device-side page content -------------------------------------
    def write_pages(self, rows: List[int], k_spans, v_spans) -> None:
        """One batched scatter of whole pages: spans are
        (L, len(rows), page_size, KV, D)."""
        idx = jnp.asarray(rows, jnp.int32)
        self.pages["k"] = _scatter_rows(self.pages["k"], idx,
                                        k_spans.astype(
                                            self.pages["k"].dtype))
        self.pages["v"] = _scatter_rows(self.pages["v"], idx,
                                        v_spans.astype(
                                            self.pages["v"].dtype))

    def copy_page(self, src_row: int, dst_row: int) -> None:
        """COW: clone a page's contents under a fresh global name."""
        src = jnp.int32(src_row)
        dst = jnp.int32(dst_row)
        self.pages["k"] = _clone_row(self.pages["k"], src, dst)
        self.pages["v"] = _clone_row(self.pages["v"], src, dst)
        self.cow_copies += 1


@dataclasses.dataclass
class _SlotState:
    addrs: List[GlobalAddress]
    length: int                      # tokens stored = abs position clock
    # running blake2b prefix chain for chunked prefill: hashes exactly
    # the tokens already resident, so each chunk hashes only its own
    # tokens instead of re-walking the prefix (None = not chunking)
    chain: Optional[Any] = None


class PagedKVCache:
    """Per-slot block tables over a shared PagePool.

    Every decode slot carries its own position counter (`lengths`) —
    the per-slot clock that replaces the dense cache's shared
    ``len/cursor/abs`` triple — and a block table row mapping its token
    positions onto physical page rows.
    """

    def __init__(self, cfg: ArchConfig, slots: int, max_len: int,
                 n_pages: int, page_size: int, dtype=None):
        self.pool = PagePool(cfg, n_pages, page_size, dtype)
        self.slots = int(slots)
        self.max_len = int(max_len)
        self.max_pages_slot = -(-self.max_len // page_size)
        null = self.pool.null_row
        self.tables = np.full((slots, self.max_pages_slot), null,
                              np.int32)
        self.lengths = np.zeros(slots, np.int32)
        self.write_rows = np.full(slots, null, np.int32)
        self.write_offs = np.zeros(slots, np.int32)
        self._state: List[_SlotState] = [
            _SlotState([], 0) for _ in range(slots)]

    # -- admission-time accounting ------------------------------------
    def pages_needed(self, padded_tokens: np.ndarray) -> int:
        """Fresh pages a prefill would allocate (prefix hits excluded)."""
        ps = self.pool.page_size
        return sum(1 for key in page_keys(padded_tokens, ps)
                   if self.pool.lookup_prefix(key) is None)

    def pages_needed_chunk(self, padded_tokens: np.ndarray,
                           start: int, end: int) -> int:
        """Fresh pages one chunk [start, end) would allocate.

        The chain keys are computed over the full prefix up to `end`,
        so a chunk boundary never changes a page's identity: chunked
        and whole-prompt prefills of the same padded prompt hash to
        the same pages (prefix sharing works across the two paths).
        """
        ps = self.pool.page_size
        keys = page_keys(padded_tokens[:end], ps)[start // ps:]
        return sum(1 for key in keys
                   if self.pool.lookup_prefix(key) is None)

    # -- prefill attach ------------------------------------------------
    def attach(self, slot: int, padded_tokens: np.ndarray,
               k, v) -> None:
        """Install a prefilled prompt into `slot`.

        k/v: (L, S, KV, D) full-prompt KV (padded bucket included, so
        the paged path attends exactly what the dense path would).
        Shared pages (prefix-hash hits) are reused by refcount instead
        of rewritten.
        """
        ps = self.pool.page_size
        s = len(padded_tokens)
        if s > self.max_len:
            raise ValueError(f"prompt {s} exceeds max_len {self.max_len}")
        st = self._state[slot]
        assert not st.addrs, f"slot {slot} already attached"
        keys = page_keys(padded_tokens, ps)
        acquired: List[GlobalAddress] = []
        fresh: List[int] = []               # page indices to write
        try:
            for i, key in enumerate(keys):
                shared = self.pool.lookup_prefix(key)
                if shared is not None:
                    self.pool.incref(shared)
                    self.pool.shares += 1
                    acquired.append(shared)
                else:
                    addr = self.pool.alloc()
                    self.pool.register_prefix(key, addr)
                    acquired.append(addr)
                    fresh.append(i)
        except PageExhausted:
            for a in acquired:
                self.pool.decref(a)
            raise
        if fresh:
            # one batched whole-page scatter (zero-padded tail on the
            # partial page — never read: masks stop at the clock)
            pad = len(keys) * ps - s
            kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) \
                .reshape(k.shape[0], len(keys), ps, *k.shape[2:])
            vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) \
                .reshape(v.shape[0], len(keys), ps, *v.shape[2:])
            fi = jnp.asarray(fresh, jnp.int32)
            self.pool.write_pages(
                [self.pool.row(acquired[i]) for i in fresh],
                kp[:, fi], vp[:, fi])
        st.addrs = acquired
        st.length = s
        self.lengths[slot] = s
        for i, a in enumerate(acquired):
            self.tables[slot, i] = self.pool.row(a)

    # -- chunked prefill (DESIGN.md §4b) ------------------------------
    def begin_chunk(self, slot: int, padded_tokens: np.ndarray,
                    start: int, end: int) -> List[int]:
        """Acquire the pages covering chunk [start, end) of a chunked
        prefill and install them in `slot`'s block table.

        `start` must be page-aligned and equal the slot's resident
        length (chunks arrive in order); `end` is page-aligned except
        on the prompt's final chunk, which may leave the last page
        partially filled — the slot holds that partial page between
        the chunk and its first decode write.  Prefix-shared pages are
        reused by refcount.  Returns one physical write row per page
        of the chunk, with the pool's null row substituted for shared
        pages so the compiled scatter cannot clobber shared content.
        Atomic under PageExhausted: either every page of the chunk is
        acquired or none (the caller preempts a victim and retries).
        """
        ps = self.pool.page_size
        st = self._state[slot]
        if start % ps:
            raise ValueError(f"chunk start {start} not page-aligned")
        if start != st.length:
            raise ValueError(
                f"slot {slot}: chunk starts at {start} but {st.length} "
                f"tokens are resident")
        if end > self.max_len:
            raise ValueError(f"chunk end {end} exceeds {self.max_len}")
        # extend the slot's running prefix chain (committed only on
        # success, so a PageExhausted retry re-hashes just this chunk);
        # digests match page_keys over the whole prompt exactly —
        # update() chunking never changes a blake2b digest
        if st.chain is not None:
            chain = st.chain.copy()
        else:
            chain = hashlib.blake2b(digest_size=16)
            if start:                # resident tokens came via attach()
                chain.update(np.asarray(padded_tokens[:start],
                                        np.int32).tobytes())
        keys: List[Tuple[bytes, int]] = []
        for pstart in range(start, end, ps):
            span = np.asarray(padded_tokens[pstart:min(pstart + ps, end)],
                              np.int32)
            chain.update(span.tobytes())
            keys.append((chain.digest(), len(span)))
        acquired: List[GlobalAddress] = []
        rows: List[int] = []
        try:
            for key in keys:
                shared = self.pool.lookup_prefix(key)
                if shared is not None:
                    self.pool.incref(shared)
                    self.pool.shares += 1
                    acquired.append(shared)
                    rows.append(self.pool.null_row)
                else:
                    addr = self.pool.alloc()
                    self.pool.register_prefix(key, addr)
                    acquired.append(addr)
                    rows.append(self.pool.row(addr))
        except PageExhausted:
            for a in acquired:
                self.pool.decref(a)
            raise
        base = start // ps
        for i, a in enumerate(acquired):
            st.addrs.append(a)
            self.tables[slot, base + i] = self.pool.row(a)
        st.chain = chain
        st.length = end
        self.lengths[slot] = end
        return rows

    # -- decode-step bookkeeping --------------------------------------
    def prepare_decode(self, slot: int) -> None:
        """Reserve the write target for this slot's next token.

        Allocates a fresh page at page boundaries; clones (COW) a
        shared page before the first divergent append.  Idempotent, so
        the engine can retry after preempting a victim on
        PageExhausted.
        """
        st = self._state[slot]
        ps = self.pool.page_size
        pos = st.length
        page_idx, off = divmod(pos, ps)
        if page_idx >= self.max_pages_slot:
            raise RuntimeError(
                f"slot {slot} overflows max_len {self.max_len}")
        if page_idx == len(st.addrs):
            addr = self.pool.alloc()
            st.addrs.append(addr)
        else:
            addr = st.addrs[page_idx]
            if self.pool.refcount(addr) > 1:
                fresh = self.pool.alloc()
                self.pool.copy_page(self.pool.row(addr),
                                    self.pool.row(fresh))
                self.pool.decref(addr)
                st.addrs[page_idx] = fresh
                addr = fresh
        row = self.pool.row(addr)
        self.tables[slot, page_idx] = row
        self.write_rows[slot] = row
        self.write_offs[slot] = off

    def needs_alloc(self, slot: int) -> bool:
        """Will this slot's next prepare_decode take a page from the
        pool?  True at page boundaries (fresh page) and on shared
        partial pages (COW clone) — the admission watermark."""
        st = self._state[slot]
        page_idx, _ = divmod(st.length, self.pool.page_size)
        if page_idx >= len(st.addrs):
            return True
        return self.pool.refcount(st.addrs[page_idx]) > 1

    def advance(self, slot: int) -> None:
        st = self._state[slot]
        st.length += 1
        self.lengths[slot] = st.length

    def release(self, slot: int) -> None:
        st = self._state[slot]
        for a in st.addrs:
            self.pool.decref(a)
        st.addrs = []
        st.length = 0
        st.chain = None
        null = self.pool.null_row
        self.tables[slot, :] = null
        self.lengths[slot] = 0
        self.write_rows[slot] = null
        self.write_offs[slot] = 0

    # -- the compiled-step view ---------------------------------------
    def batch_inputs(self) -> Dict[str, Any]:
        """Fixed-shape arrays for decode_step_paged (one compile)."""
        return {
            "block_tables": jnp.asarray(self.tables),
            "positions": jnp.asarray(self.lengths),
            "write_rows": jnp.asarray(self.write_rows),
            "write_offs": jnp.asarray(self.write_offs),
        }
