"""Straggler mitigation: AGAS migration driven by measured load.

The paper's work-queue balances load *within* a step; across steps the
compiled engine is static, so persistent stragglers (a slow host, a
hot AMR region) need explicit rebalancing: measure per-locality cost,
re-place blocks (LPT), commit the move as an AGAS migration plan whose
payload permutation runs between compiled steps (core/parcels.py).

`StragglerMonitor` implements the standard detection rule (cost >
median * threshold) and `rebalance` produces the migration plan.  For
DP training the same monitor drives the decision to drop a slow rank's
microbatch (redundant-batch policy) — see ft/supervisor.py.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.agas import AGAS, GlobalAddress, balanced_placement
from repro.core.parcels import MigrationPlan, migration_plan


@dataclasses.dataclass
class StragglerReport:
    per_locality_cost: np.ndarray
    stragglers: List[int]
    imbalance: float                 # max/mean


class StragglerMonitor:
    def __init__(self, n_localities: int, threshold: float = 1.5,
                 ema: float = 0.5):
        self.n = n_localities
        self.threshold = threshold
        self.ema = ema
        self._cost = np.zeros(n_localities)

    def observe(self, per_locality_cost: Sequence[float]
                ) -> StragglerReport:
        c = np.asarray(per_locality_cost, float)
        self._cost = self.ema * c + (1 - self.ema) * self._cost \
            if self._cost.any() else c
        med = np.median(self._cost)
        stragglers = [int(i) for i in range(self.n)
                      if med > 0 and self._cost[i] > self.threshold * med]
        imb = float(self._cost.max() / max(self._cost.mean(), 1e-12))
        return StragglerReport(self._cost.copy(), stragglers, imb)


def rebalance(agas: AGAS, block_costs: Dict[GlobalAddress, float],
              speed: Optional[Sequence[float]] = None
              ) -> Tuple[MigrationPlan, np.ndarray]:
    """Re-place all blocks by LPT weighted by locality speed.

    `speed[i]` scales locality i's capacity (a persistent straggler has
    speed < 1, so it receives proportionally less work).  Returns the
    committed MigrationPlan and the predicted per-locality load.
    """
    n = len(agas.domain)
    speed = np.asarray(speed if speed is not None else np.ones(n),
                       float)
    addrs = sorted(block_costs, key=lambda a: -block_costs[a])
    load = np.zeros(n)
    target: Dict[GlobalAddress, int] = {}
    for a in addrs:
        i = int(np.argmin((load + block_costs[a]) / speed))
        target[a] = i
        load[i] += block_costs[a]
    moves = {a: t for a, t in target.items()
             if agas.locality_of(a) != t}
    plan = migration_plan(agas, moves)
    return plan, load / speed
