"""Pallas kernel package."""
