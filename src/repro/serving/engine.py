"""Batched serving engine: prefill/decode split with continuous
batching over a fixed slot pool.

The ParalleX reading of serving (DESIGN.md §4): each request is a
first-class object in a slot pool (an AGAS allocation); arriving
requests are parcels that trigger a prefill task; decode is a dataflow
chain per slot, and the engine's scheduler packs ready slots into
batched decode steps (the work-queue at token granularity).

Design points that matter at scale and are implemented here:
* fixed-shape decode batch (slot pool) -> one compiled decode_step;
* prefill runs per request at bucketed lengths (pad-to-bucket) to
  bound compilation count;
* slots free on EOS/length and refill from the queue (continuous
  batching);
* per-slot sampling state (greedy or temperature).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ArchConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]
    prefill_s: float
    decode_s: float


class ServingEngine:
    def __init__(self, params: Any, cfg: ArchConfig, *, slots: int = 4,
                 max_len: int = 512, prefill_buckets=(64, 128, 256)):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.buckets = tuple(sorted(prefill_buckets))
        self.queue: List[Request] = []
        self.active: Dict[int, dict] = {}      # slot -> request state
        self.free_slots = list(range(slots))
        self.completions: List[Completion] = []
        # one shared batched cache across slots
        self.cache = T.init_cache(cfg, slots, max_len)
        self._decode = jax.jit(
            lambda p, c, b: T.decode_step(p, c, b, cfg))
        self._prefills = {}

    # -- request intake (a parcel arriving at the engine locality) ----
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefills:
            cfg = self.cfg

            def fn(params, tokens):
                batch = {"tokens": tokens}
                hidden, cache = T.prefill(params, batch, cfg)
                return T.logits_fn(params, hidden), cache
            self._prefills[bucket] = jax.jit(fn)
        return self._prefills[bucket]

    def _admit(self) -> None:
        while self.queue and self.free_slots:
            req = self.queue.pop(0)
            slot = self.free_slots.pop(0)
            t0 = time.perf_counter()
            n = len(req.prompt)
            bucket = self._bucket(n)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, bucket - n:] = req.prompt    # left-pad
            logits, pcache = self._prefill_fn(bucket)(
                self.params, jnp.asarray(toks))
            # splice this request's prefill cache into the slot pool
            self._splice_cache(slot, pcache, bucket)
            first = self._sample(logits[0], req)
            self.active[slot] = {
                "req": req, "tokens": [int(first)],
                "prefill_s": time.perf_counter() - t0,
                "t0": time.perf_counter(),
                "pos": bucket,
            }

    def _splice_cache(self, slot: int, pcache: dict, plen: int) -> None:
        def splice(pool, part):
            if pool.ndim == 0 or part is None:
                return pool
            # find the batch axis: pool (…, slots, …) vs part (…,1,…)
            for ax in range(pool.ndim):
                if part.shape[ax] == 1 and pool.shape[ax] == self.slots:
                    break
            else:
                return pool
            # seq axes differ (plen vs max_len): pad part
            pads = []
            for d in range(pool.ndim):
                if d == ax:
                    pads.append((0, 0))
                else:
                    pads.append((0, pool.shape[d] - part.shape[d]))
            part = jnp.pad(part, pads)
            idx = [slice(None)] * pool.ndim
            idx[ax] = slice(slot, slot + 1)
            return pool.at[tuple(idx)].set(part)

        for k in self.cache:
            if k in ("len", "cursor", "abs"):
                continue
            self.cache[k] = splice(self.cache[k], pcache.get(k))
        # shared counters: the pool cache uses one clock; keep max
        self.cache["len"] = jnp.maximum(self.cache["len"],
                                        pcache["len"])
        self.cache["cursor"] = jnp.maximum(self.cache["cursor"],
                                           pcache["cursor"])
        self.cache["abs"] = jnp.maximum(self.cache["abs"],
                                        pcache["abs"])

    def _sample(self, logits: jnp.ndarray, req: Request) -> int:
        if req.temperature <= 0:
            return int(jnp.argmax(logits))
        key = jax.random.PRNGKey(req.rid * 7919 + len(
            self.active.get(req.rid, {}).get("tokens", [])))
        return int(jax.random.categorical(key,
                                          logits / req.temperature))

    # -- the decode work-queue ----------------------------------------
    def step(self) -> int:
        """One batched decode step over all active slots."""
        self._admit()
        if not self.active:
            return 0
        tokens = np.zeros((self.slots, 1), np.int32)
        for slot, st in self.active.items():
            tokens[slot, 0] = st["tokens"][-1]
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (self.slots, self.cfg.n_frontend_tokens,
                 32 if self.cfg.d_model < 1024 else 1280),
                jnp.dtype(self.cfg.dtype))
        logits, self.cache = self._decode(self.params, self.cache,
                                          batch)
        done = []
        for slot, st in self.active.items():
            req = st["req"]
            tok = self._sample(logits[slot], req)
            st["tokens"].append(tok)
            if (req.eos_id is not None and tok == req.eos_id) or \
                    len(st["tokens"]) >= req.max_new_tokens:
                done.append(slot)
        for slot in done:
            st = self.active.pop(slot)
            self.completions.append(Completion(
                st["req"].rid, st["tokens"], st["prefill_s"],
                time.perf_counter() - st["t0"]))
            self.free_slots.append(slot)
        return len(self.active) + len(done)

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            self._admit()
            if not self.active and not self.queue:
                return
            self.step()
