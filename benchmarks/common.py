"""Shared benchmark utilities."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import numpy as np


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The run.py contract: name,us_per_call,derived CSV lines."""
    print(f"{name},{us_per_call:.3f},{derived}")


def emit_registry(registry, derived: str = "registry") -> None:
    """Emit every scalar in an obs.MetricsRegistry snapshot through
    emit(), so benchmark metrics flow through the same CSV contract
    as hand-picked numbers (DESIGN.md §10)."""
    for name, value in sorted(registry.snapshot().items()):
        emit(name, float(value), derived)
