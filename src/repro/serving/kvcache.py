"""AGAS-managed paged KV cache (DESIGN.md §4a, sharded in §4c).

The ParalleX reading of KV memory: instead of a dense ``(slots,
max_len)`` cache statically owned by each decode slot, KV storage is a
pool of fixed-size *pages*, each a first-class globally-named object
allocated and freed through the AGAS directory (`core/agas.py`).  A
page's `GlobalAddress` is its immutable name; the AGAS (locality,
slot) it resolves to is the physical row in the device-side page
arrays, so a block-table lookup compiles to a gather index — the same
"nothing dynamic survives to run time" rendering used for AMR blocks.

Three layers live here:

* `PagePool` — the allocator: AGAS-backed gid -> physical-row mapping,
  per-page refcounts, a radix prefix index over position-normalized
  page-key chains (`serving/radix.py`) enabling pages shared between
  requests of *different lengths* (copy-on-write on first divergent
  append) — and,
  alongside each indexed page, the post-norm hidden state of the
  page's last position (the activation checkpoint prefix-cache
  compute skip resumes from, DESIGN.md §4e) — and the device arrays
  themselves.  Single locality (``n_shards == 1``):
  ``pages["k"]/pages["v"]`` of shape ``(L, n_pages + 1, page_size, KV,
  D)``; the extra trailing row is the *null page*, the write target of
  idle decode slots — never read because the per-slot masks exclude
  it.  Sharded (``n_shards > 1``, DESIGN.md §4c): one AGAS locality
  per KV shard, arrays of shape ``(L, n_shards, pages_per_shard + 1,
  page_size, KV, D)`` (each shard carries its own local null page),
  block-table rows encoded ``locality * rows_per_shard + slot``,
  allocation least-loaded-shard-first with prefix-shared pages pinned
  to their owner, and pool-imbalance-triggered page migration lowered
  through `core/parcels.migration_plan` into ppermute legs — a page's
  global name survives the move (the AGAS promise), only its
  (locality, slot) changes.

* `PagedKVCache` — the per-engine view: one block table per decode
  slot mapping token position ``p`` to the physical row of page
  ``p // page_size``, plus **per-slot** position counters (replacing
  the dense cache's shared ``len/cursor/abs`` clock).  Prompts attach
  either whole (`attach`) or one page-aligned chunk at a time
  (`begin_chunk`, DESIGN.md §4b) — the prefix hash-chain is computed
  over the full prefix either way, so the two paths share pages with
  each other.

* `PageExhausted` — the backpressure signal: raised when the pool has
  no free page; the serving engine reacts by preempting a request back
  to the queue (the LCO analogue of a parcel being deferred).
"""

from __future__ import annotations

import dataclasses
import hashlib
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agas import AGAS, AGASError, GlobalAddress
from repro.core.localities import LocalityDomain
from repro.core.parcels import MigrationPlan, canonical_size, \
    migration_plan, plan_move_arrays
from repro.models.config import ArchConfig
from repro.models.transformer import PAGED_FAMILIES, init_paged_cache
from repro.obs.trace import NULL_TRACER
from repro.serving.radix import RadixPrefixIndex


class PageExhausted(RuntimeError):
    """No free page in the pool; callers preempt or defer."""


def _chain_new(pad: int = 0) -> Any:
    """A fresh page-key chain for a layout with `pad` leading padding
    rows.  The pad count seeds the chain — RoPE positions differ
    across layouts, so a padded layout's pages must never alias a
    pad-free one's even when the real tokens agree."""
    h = hashlib.blake2b(digest_size=16)
    h.update(int(pad).to_bytes(4, "little", signed=True))
    return h


def _chain_extend(chain: Any, tokens: np.ndarray, start: int,
                  end: int, page_size: int, pad: int = 0
                  ) -> List[Tuple[bytes, int]]:
    """Extend a page-key chain over layout rows [start, end) (`start`
    page-aligned), returning one (digest, fill) key per page.

    Each page hashes its start row (so keys stay distinct even for
    pages holding zero real tokens) followed by its REAL tokens —
    `tokens` is the full layout and rows below `pad` are padding,
    excluded from the digest.  Byte-for-byte the continuation of
    `page_keys` over the same layout: update() chunking never changes
    a blake2b digest, and the per-page update sequence here is
    identical.
    """
    keys: List[Tuple[bytes, int]] = []
    # one serialization of the layout, byte-sliced per page: this runs
    # on every admission attempt, so it must stay microseconds
    buf = np.ascontiguousarray(tokens, np.int32).tobytes()
    for pstart in range(start, end, page_size):
        pend = min(pstart + page_size, end)
        chain.update(int(pstart).to_bytes(4, "little", signed=True))
        chain.update(buf[4 * max(pstart, pad):4 * pend])
        keys.append((chain.digest(), pend - pstart))
    return keys


def _chain_seed(tokens: np.ndarray, start: int, page_size: int,
                pad: int = 0) -> Any:
    """A chain with rows [0, start) already consumed — what a slot's
    running chain would hold after attaching that prefix."""
    chain = _chain_new(pad)
    if start:
        _chain_extend(chain, tokens, 0, start, page_size, pad)
    return chain


def page_keys(tokens: np.ndarray, page_size: int, pad: int = 0
              ) -> List[Tuple[bytes, int]]:
    """Position-normalized chained prefix hashes, one per layout page.

    Key i commits to the layout's pad count plus every REAL token
    through page i (and the page's row count as its fill), so two
    layouts share page i iff they agree on the pad count and on every
    real token up to and including it.  `tokens` is the full layout;
    `pad` declares how many of its leading rows are padding (excluded
    from the digests — their values are irrelevant, only their count
    names the position shift).  Pad-free layouts (``pad=0``, the paged
    engines') therefore share prefix pages across prompts of
    *different total lengths* — the mixed-length traffic DESIGN.md
    §4e's compute skip exists for.
    """
    return _chain_extend(_chain_new(pad), tokens, 0, len(tokens),
                         page_size, pad)


# Jitted + donated page mutations: on accelerators the update happens
# in place instead of copying the whole pool per call (CPU falls back
# to a copy with a one-time donation warning).  The *_sharded variants
# operate on the (L, n_shards, rows_per_shard, ...) layout with the
# flat row already decoded into (locality, slot) index arrays.
@partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(arr, idx, spans):
    return arr.at[:, idx].set(spans)


@partial(jax.jit, donate_argnums=(0,))
def _clone_row(arr, src, dst):
    return arr.at[:, dst].set(arr[:, src])


@partial(jax.jit, donate_argnums=(0,))
def _scatter_rows_sharded(arr, loc, slot, spans):
    return arr.at[:, loc, slot].set(spans)


@partial(jax.jit, donate_argnums=(0,))
def _clone_row_sharded(arr, src_loc, src_slot, dst_loc, dst_slot):
    return arr.at[:, dst_loc, dst_slot].set(arr[:, src_loc, src_slot])


# Migration payload permutation: the RHS gather is evaluated against
# the pre-update operand, so every payload is read before any
# destination is written regardless of move order (the snapshot
# semantics core/parcels.plan_move_arrays documents).
@partial(jax.jit, donate_argnums=(0,))
def _permute_rows_sharded(arr, src_loc, src_slot, dst_loc, dst_slot):
    return arr.at[:, dst_loc, dst_slot].set(arr[:, src_loc, src_slot])


class PagePool:
    """Refcounted AGAS page allocator + the device page arrays.

    ``n_shards > 1`` shards the pool across AGAS localities (DESIGN.md
    §4c): allocation is least-loaded-shard-first, every physical row is
    named ``locality * rows_per_shard + slot``, and `migrate_pages`
    moves pages between shards without changing their global names.
    ``mesh`` (optional, with a ``kv_axis`` axis of size n_shards)
    device-backs the localities: the page arrays are placed one shard
    per device and migration legs execute as `lax.ppermute` under
    `shard_map`; without a mesh the same legs lower to a single-device
    row permutation (simulated localities — bit-identical results).
    """

    def __init__(self, cfg: ArchConfig, n_pages: int, page_size: int,
                 dtype=None, *, n_shards: int = 1, mesh=None,
                 kv_axis: str = "kv", tracer=None,
                 pin_threshold: int = 4, pin_capacity: int = 0):
        if cfg.family not in PAGED_FAMILIES:
            raise ValueError(
                f"paged KV cache supports {PAGED_FAMILIES}, "
                f"not {cfg.family!r}")
        if n_shards < 1 or n_pages % n_shards:
            raise ValueError(
                f"n_pages {n_pages} must be a positive multiple of "
                f"n_shards {n_shards}")
        self.cfg = cfg
        self.capacity = int(n_pages)
        self.page_size = int(page_size)
        self.n_shards = int(n_shards)
        self.sharded = self.n_shards > 1
        self.pages_per_shard = self.capacity // self.n_shards
        # every shard carries its own null page, so a shard's rows are
        # pages_per_shard + 1 and the flat encoding below never
        # collides between shards
        self.rows_per_shard = self.pages_per_shard + 1
        # shard 0's local null row; any shard's null works as a write
        # sink (no mask ever reads one) and 0 * rows_per_shard +
        # pages_per_shard keeps the single-shard value n_pages
        self.null_row = self.pages_per_shard
        self.mesh = mesh
        self.kv_axis = kv_axis
        if mesh is not None and \
                mesh.shape.get(kv_axis) != self.n_shards:
            raise ValueError(
                f"mesh axis {kv_axis!r} must have size {self.n_shards}")
        # One AGAS locality per KV shard; per-locality capacity is the
        # shard's page count (the directory's free lists ARE the
        # least-loaded allocation signal).
        self.agas = AGAS(LocalityDomain.simulated(self.n_shards),
                         self.pages_per_shard, space="kvpage")
        self._refs: Dict[int, int] = {}            # gid -> refcount
        # the prefix index: a radix tree over page-key chains
        # (serving/radix.py) — longest-prefix covers walk it, point
        # lookups go through its O(1) digest directory, and its hit
        # statistics pin hot prefixes against tiered eviction
        self.prefix = RadixPrefixIndex(
            pin_threshold=pin_threshold,
            pin_capacity=pin_capacity or max(1, n_pages // 4))
        # gid -> last-position activation checkpoint (np, (D,)): lives
        # and dies with the page's prefix-index membership (§4e)
        self._hidden: Dict[int, np.ndarray] = {}
        self.pages: Dict[str, Any] = init_paged_cache(
            cfg, self.rows_per_shard, self.page_size, dtype,
            n_shards=self.n_shards)
        if mesh is not None:
            from repro.distributed.sharding import page_pool_shardings
            sh = page_pool_shardings(mesh, kv_axis)
            self.pages = {k: jax.device_put(v, sh)
                          for k, v in self.pages.items()}
        # performance counters (Fig 9 spirit: runtime overhead visible)
        self.allocs = 0
        self.shares = 0
        self.cow_copies = 0
        self.page_migrations = 0
        # recovery counters (DESIGN.md §4g)
        self.pages_rebuilt = 0       # dead-shard pages restored from a host copy
        self.pages_lost = 0          # dead-shard pages with no surviving copy
        self.localities_killed = 0
        self.trace = tracer if tracer is not None else NULL_TRACER
        # canonical migration programs (DESIGN.md §9.4): the flat path
        # pads move lists to power-of-two size classes; the mesh path
        # caches one compiled shard_map program per ppermute leg
        # structure with the slot indices as traced operands
        self._mig_cache: Dict[tuple, Any] = {}
        self._mig_sizes: set = set()

    # -- allocation / refcounting -------------------------------------
    @property
    def free_pages(self) -> int:
        # global count: least-loaded-first allocation keeps every shard
        # reachable, so n free pages really do admit n allocations —
        # summed over ACTIVE shards only, so a retired/dead shard's
        # empty pool never inflates the admission watermark (§4g)
        return sum(self.agas.free_count(l)
                   for l in self.active_shards())

    @property
    def used_pages(self) -> int:
        return len(self._refs)

    def occupancy(self) -> float:
        return self.used_pages / max(self.capacity, 1)

    def shard_used(self) -> List[int]:
        """Pages resident per shard (the load-balance signal)."""
        return [int(n) for n in self.agas.load()]

    def shard_occupancy(self) -> List[float]:
        per = max(self.pages_per_shard, 1)
        return [u / per for u in self.shard_used()]

    def metrics(self) -> Dict[str, Any]:
        """Counters under the unified ``subsystem.metric`` namespace
        (the engine mirrors these into its MetricsRegistry)."""
        return {
            "pool.capacity": self.capacity,
            "pool.page_size": self.page_size,
            "pool.kv_shards": self.n_shards,
            "pool.used_pages": self.used_pages,
            "pool.free_pages": self.free_pages,
            "pool.occupancy": self.occupancy(),
            "pool.allocs": self.allocs,
            "pool.shares": self.shares,
            "pool.cow_copies": self.cow_copies,
            "pool.page_migrations": self.page_migrations,
            "pool.pages_rebuilt": self.pages_rebuilt,
            "pool.pages_lost": self.pages_lost,
            "pool.localities_killed": self.localities_killed,
            **self.prefix.metrics(),
        }

    def alloc(self, locality: Optional[int] = None) -> GlobalAddress:
        """Allocate a page, least-loaded shard first.

        Prefix-shared pages are pinned to their owner by construction —
        sharing increfs an existing page wherever it lives; only FRESH
        pages go through placement.  An explicit `locality` pins the
        page (callers that want shard affinity); the default policy
        keeps the shards balanced without a planner.
        """
        if locality is None:
            # tier 0 = device: fresh pages always land in fast memory;
            # the host tier (tiered pools only) is reached exclusively
            # by percolation, never by allocation
            locality = self.agas.least_loaded(tier=0)
        try:
            addr = self.agas.allocate(locality)
        except AGASError:
            raise PageExhausted(
                f"page pool exhausted ({self.capacity} pages over "
                f"{self.n_shards} shard(s))") from None
        self._refs[addr.gid] = 1
        self.allocs += 1
        self.trace.instant("kvcache", "page_alloc", lane=locality,
                           gid=addr.gid)
        return addr

    def incref(self, addr: GlobalAddress) -> None:
        self._refs[addr.gid] += 1

    def _purge_index(self, gid: int) -> None:
        """Remove a departing page's prefix-index node AND its stored
        activation checkpoint in one step.  Every path a page leaves
        the pool by (decref-to-zero, rollback discard, cold drop under
        host-tier pressure) funnels through here, so `covered_prefix`
        can never observe a key whose page is freed but whose
        checkpoint — or index entry — lingers."""
        self._hidden.pop(gid, None)
        self.prefix.remove_gid(gid)

    def decref(self, addr: GlobalAddress) -> None:
        self._refs[addr.gid] -= 1
        if self._refs[addr.gid] == 0:
            del self._refs[addr.gid]
            self._purge_index(addr.gid)
            self.agas.free(addr)
            self.trace.instant("kvcache", "page_free", gid=addr.gid)

    def refcount(self, addr: GlobalAddress) -> int:
        return self._refs[addr.gid]

    def discard(self, addr: GlobalAddress) -> None:
        """Rollback decref for pages whose content was never written
        (attach/begin_chunk exception paths).  Identical to `decref`
        here; the tiered pool overrides it to bypass prefix-cache
        retention — a retained-but-unwritten page would serve garbage
        to a later prefix hit."""
        self.decref(addr)

    def ensure_device(self, addr: GlobalAddress) -> None:
        """Guarantee a page is resident in fast memory before its row
        is resolved.  Single-tier pools have nowhere else a page could
        be; the tiered pool (serving/tiering.py) promotes here."""

    def page_cost(self, key: Tuple[bytes, int]) -> int:
        """Fast-tier rows acquiring this prefix key will consume: 0 on
        a hit, 1 on a miss.  The tiered pool also charges 1 for a hit
        on a host-resident page (promotion takes a device row)."""
        return 0 if self.lookup_prefix(key) is not None else 1

    def row(self, addr: GlobalAddress) -> int:
        """Physical row of a page: ``locality * rows_per_shard + slot``
        (reduces to the plain AGAS slot when n_shards == 1).  The row
        changes when the page migrates; the global name never does."""
        loc, slot = self.agas.lookup(addr)
        return loc * self.rows_per_shard + slot

    def page_bytes(self) -> int:
        """Bytes one page occupies (k + v, all layers) — the payload
        unit of percolation copy parcels and §4f handoffs."""
        k = self.pages["k"]
        per_row = int(np.prod(k.shape[-3:])) * k.shape[0] \
            * k.dtype.itemsize
        return 2 * per_row

    def _split_rows(self, rows) -> Tuple[np.ndarray, np.ndarray]:
        r = np.asarray(rows, np.int32)
        return r // self.rows_per_shard, r % self.rows_per_shard

    # -- prefix sharing ------------------------------------------------
    def lookup_prefix(self, key: Tuple[bytes, int]
                      ) -> Optional[GlobalAddress]:
        return self.prefix.lookup(key)

    def register_prefix(self, key: Tuple[bytes, int],
                        addr: GlobalAddress,
                        parent: Optional[bytes] = None) -> None:
        # one key per page: a second registration (either direction)
        # is a no-op, so freeing a page can never leave a stale key
        # behind in the prefix index.  `parent` is the chain's
        # previous digest — the radix edge that makes root-to-node
        # paths prompt prefixes (None for a chain's first page).
        self.prefix.insert(key, addr, parent)

    # -- activation checkpoints (compute skip, DESIGN.md §4e) ---------
    def store_hidden(self, addr: GlobalAddress, hidden) -> None:
        """Attach the post-norm hidden state of a page's last position
        to a prefix-indexed page.  First write wins: the checkpoint is
        always the value the page's first writer computed, so repeated
        shares can never swap in a bit-different recomputation.  Pages
        outside the prefix index carry no checkpoint (nothing could
        ever look it up)."""
        gid = addr.gid
        if self.prefix.owns_gid(gid) and gid not in self._hidden:
            self._hidden[gid] = np.asarray(hidden)

    def hidden_for(self, key: Tuple[bytes, int]
                   ) -> Optional[np.ndarray]:
        """The activation checkpoint cached under a prefix key, or
        None (key unknown, or its page was written before compute
        skip could checkpoint it)."""
        addr = self.prefix.lookup(key)
        if addr is None:
            return None
        return self._hidden.get(addr.gid)

    def hidden_nbytes(self, addrs) -> int:
        """Bytes of activation checkpoints riding these pages — the
        tiered pool adds them to its percolation parcel byte counts,
        since a checkpoint moves (and dies) with its page chain."""
        return sum(self._hidden[a.gid].nbytes for a in addrs
                   if a.gid in self._hidden)

    # -- device-side page content -------------------------------------
    def write_pages(self, rows: List[int], k_spans, v_spans) -> None:
        """One batched scatter of whole pages: spans are
        (L, len(rows), page_size, KV, D)."""
        kd = k_spans.astype(self.pages["k"].dtype)
        vd = v_spans.astype(self.pages["v"].dtype)
        if self.sharded:
            loc, slot = self._split_rows(rows)
            loc, slot = jnp.asarray(loc), jnp.asarray(slot)
            self.pages["k"] = _scatter_rows_sharded(
                self.pages["k"], loc, slot, kd)
            self.pages["v"] = _scatter_rows_sharded(
                self.pages["v"], loc, slot, vd)
        else:
            idx = jnp.asarray(rows, jnp.int32)
            self.pages["k"] = _scatter_rows(self.pages["k"], idx, kd)
            self.pages["v"] = _scatter_rows(self.pages["v"], idx, vd)

    def copy_page(self, src_row: int, dst_row: int) -> None:
        """COW: clone a page's contents under a fresh global name (the
        clone may land on a different shard — on a mesh that copy is a
        parcel; GSPMD lowers the cross-shard read for us)."""
        if self.sharded:
            (sl, ss), (dl, ds) = (self._split_rows([src_row]),
                                  self._split_rows([dst_row]))
            self.pages["k"] = _clone_row_sharded(
                self.pages["k"], jnp.int32(sl[0]), jnp.int32(ss[0]),
                jnp.int32(dl[0]), jnp.int32(ds[0]))
            self.pages["v"] = _clone_row_sharded(
                self.pages["v"], jnp.int32(sl[0]), jnp.int32(ss[0]),
                jnp.int32(dl[0]), jnp.int32(ds[0]))
        else:
            src = jnp.int32(src_row)
            dst = jnp.int32(dst_row)
            self.pages["k"] = _clone_row(self.pages["k"], src, dst)
            self.pages["v"] = _clone_row(self.pages["v"], src, dst)
        self.cow_copies += 1
        self.trace.instant("kvcache", "cow_copy", src_row=src_row,
                           dst_row=dst_row)

    # -- locality failure / elastic membership (DESIGN.md §4g) --------
    def active_shards(self) -> List[int]:
        """Device shards currently accepting placement (not retired)."""
        return [l for l in range(self.n_shards)
                if self.agas.is_active(l)]

    def note_page_write(self, addr: GlobalAddress) -> None:
        """Hook: `addr` is about to receive an in-place decode write.

        Decode appends are the ONLY mutation of an existing page
        (attach/begin_chunk scatter into fresh pages; shared pages get
        the null row), so this is the one place a retained host-tier
        copy of a device page goes stale.  Single-tier pools retain no
        copies — no-op; the tiered pool invalidates its shadow."""

    def _rebuild_page(self, addr: GlobalAddress) -> bool:
        """Try to rebuild a dead locality's page on a surviving shard.
        The untiered pool holds no second copy of anything: False —
        the page is lost and its request re-prefills."""
        return False

    def _forget_dead_page(self, gid: int) -> None:
        """Hook: tier/staging bookkeeping for a page lost with its
        locality (the tiered pool drops any staged copy)."""

    def _drop_cold(self, gid: int) -> None:
        # refcount-0 residents only exist under the tiered pool's
        # cold-retention policy; the base pool frees at zero
        raise AssertionError(
            f"refcount-0 resident {gid} in an untiered pool")

    def kill_locality(self, locality: int) -> set:
        """Simulate the loss of one device shard (DESIGN.md §4g).

        The AGAS directory retires the locality — allocation,
        migration targets and least-loaded placement skip it until a
        later `activate` re-joins it — and every page homed there is
        swept: cold-retained prefix pages are dropped (nobody holds
        them), referenced pages are rebuilt on a surviving shard when
        a host-tier copy exists (`_rebuild_page`, tiered pools), and
        the rest are LOST — purged from the prefix index through
        `_purge_index` and freed.  Returns the lost gids: the serving
        engine drains every slot/snapshot referencing one and
        re-admits its request for re-prefill.  Block tables are NOT
        touched here; callers must drain and then `refresh_tables`.
        """
        if not 0 <= locality < self.n_shards:
            raise ValueError(f"no device shard {locality}")
        if not self.agas.is_active(locality):
            return set()
        self.agas.deactivate(locality)
        self.localities_killed += 1
        lost: set = set()
        rebuilt = 0
        for gid in sorted(self.agas.residents(locality)):
            if not self.agas.resident_on(gid, locality):
                continue      # a rebuild's own eviction moved/dropped it
            addr = GlobalAddress(gid, self.agas.space)
            if self._refs.get(gid, 0) == 0:
                self._drop_cold(gid)
                continue
            if self._rebuild_page(addr):
                rebuilt += 1
                continue
            del self._refs[gid]
            self._purge_index(gid)
            self._forget_dead_page(gid)
            self.agas.free(addr)
            lost.add(gid)
        self.pages_rebuilt += rebuilt
        self.pages_lost += len(lost)
        self.trace.instant("kvcache", "kill_locality",
                           locality=locality, rebuilt=rebuilt,
                           lost=len(lost))
        return lost

    def plan_evacuation(self, locality: int
                        ) -> Dict[GlobalAddress, int]:
        """Every resident page off `locality` (planned retire).

        Unlike `plan_rebalance`, refcounts don't gate movability — a
        retiring shard takes everything with it, so everything must
        move (block tables are one `refresh_tables` away either way).
        Raises `PageExhausted` when the surviving active shards cannot
        hold the residents; nothing is committed in that case.
        """
        dsts = [l for l in self.active_shards() if l != locality]
        if not dsts:
            raise PageExhausted(
                f"cannot retire locality {locality}: no surviving "
                f"active shard")
        free = {l: self.agas.free_count(l) for l in dsts}
        moves: Dict[GlobalAddress, int] = {}
        for gid in sorted(self.agas.residents(locality)):
            dst = max(dsts, key=lambda l: (free[l], -l))
            if free[dst] <= 0:
                raise PageExhausted(
                    f"cannot retire locality {locality}: surviving "
                    f"shards have no free rows")
            moves[GlobalAddress(gid, self.agas.space)] = dst
            free[dst] -= 1
        return moves

    # -- inter-shard page migration (DESIGN.md §4c) -------------------
    def plan_rebalance(self, tolerance: int
                       ) -> Dict[GlobalAddress, int]:
        """Moves that bring per-shard page counts within `tolerance`.

        Only movable pages (refcount == 1) migrate: a prefix-shared
        page stays pinned to its owner, so every block table pointing
        at it stays one refresh away from consistency.  Moves are
        simulated in commit (gid) order against the per-shard free
        lists, so the returned dict is always feasible.  Retired
        shards (§4g) neither donate nor receive — a dead shard's empty
        pool must not read as "the emptiest target".
        """
        act = self.active_shards()
        if len(act) < 2:
            return {}
        all_used = self.shard_used()
        used = {l: all_used[l] for l in act}
        free = {l: self.pages_per_shard - used[l] for l in act}
        movable = {l: sorted(g for g in self.agas.residents(l)
                             if self._refs.get(g, 0) == 1)
                   for l in act}
        moves: Dict[GlobalAddress, int] = {}
        while True:
            hi = max(act, key=lambda l: (used[l], -l))
            lo = min(act, key=lambda l: (used[l], l))
            if used[hi] - used[lo] <= max(int(tolerance), 1):
                break
            if free[lo] <= 0 or not movable[hi]:
                break
            gid = movable[hi].pop(0)
            moves[GlobalAddress(gid, self.agas.space)] = lo
            used[hi] -= 1
            used[lo] += 1
            free[hi] += 1
            free[lo] -= 1
        return moves

    def plan_rotation(self) -> Dict[GlobalAddress, int]:
        """Every movable page to the next ACTIVE shard (round-robin):
        the forced-migration drill that verifies a page's global name —
        and therefore every request's output — survives relocation.
        Feasibility is simulated in gid order, matching the order
        `migration_plan` commits moves in."""
        act = self.active_shards()
        if len(act) < 2:
            return {}
        nxt = {l: act[(i + 1) % len(act)] for i, l in enumerate(act)}
        all_used = self.shard_used()
        free = {l: self.pages_per_shard - all_used[l] for l in act}
        moves: Dict[GlobalAddress, int] = {}
        where = {g: l for l in act for g in self.agas.residents(l)}
        for gid in sorted(where):
            if self._refs.get(gid, 0) != 1:
                continue
            src = where[gid]
            dst = nxt[src]
            if dst == src or free[dst] <= 0:
                continue
            moves[GlobalAddress(gid, self.agas.space)] = dst
            free[dst] -= 1
            free[src] += 1
        return moves

    def migrate_pages(self, moves: Dict[GlobalAddress, int]
                      ) -> MigrationPlan:
        """Migrate pages between shards: the AGAS directory commits the
        (locality, slot) updates — global names unchanged — and the
        payload permutation is lowered through
        `core/parcels.migration_plan` into ppermute legs, executed with
        `lax.ppermute` under `shard_map` when the pool is mesh-backed
        and as one gather-before-scatter row permutation of the same
        legs on a single device."""
        with self.trace.span("kvcache", "migrate_pages", kind="parcel",
                             moves=len(moves)) as sp:
            plan = migration_plan(self.agas, moves)
            if plan.moves:
                if self.mesh is not None:
                    self._apply_plan_mesh(plan)
                else:
                    self._apply_plan_flat(plan)
                self.page_migrations += len(plan.moves)
            sp.args["gids"] = [m[0] for m in plan.moves]
        return plan

    def _apply_plan_flat(self, plan: MigrationPlan) -> None:
        # only reachable sharded: a 1-shard pool has no inter-locality
        # moves, so migration_plan always returns an empty plan there.
        # Moves are padded to a canonical power-of-two count with
        # null-row self-copies, so `_permute_rows_sharded` compiles
        # once per size class, not once per exact move count.
        pad = canonical_size(len(plan.moves))
        self._mig_sizes.add(pad)
        args = tuple(jnp.asarray(a) for a in plan_move_arrays(
            plan, pad_to=pad, pad_move=(0, self.null_row)))
        self.pages["k"] = _permute_rows_sharded(self.pages["k"], *args)
        self.pages["v"] = _permute_rows_sharded(self.pages["v"], *args)

    def _mesh_plan_fn(self, perms: tuple):
        """The compiled ppermute program for one leg structure.

        `perms` (the per-leg (src, dst) pairs) must be compile-time
        constants — they become the ppermute wiring — but the slot
        indices are TRACED operands, so every plan with the same leg
        structure reuses one cached program: repeated drills and
        rebalances stop paying a recompile per call (DESIGN.md §9.4).
        """
        fn = self._mig_cache.get(perms)
        if fn is not None:
            return fn
        from jax import lax
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compat import shard_map
        spec = P(None, self.kv_axis, None, None, None, None)
        axis = self.kv_axis

        def body(cur, gs, ss, recv):
            i = lax.axis_index(axis)
            orig = cur                   # pre-plan snapshot
            for leg, perm in enumerate(perms):
                payload = jnp.take(orig[:, 0], gs[leg, i], axis=1)
                got = lax.ppermute(payload, axis, perm)
                cur = jnp.where(recv[leg, i],
                                cur.at[:, 0, ss[leg, i]].set(got), cur)
            return cur

        fn = jax.jit(shard_map(body, mesh=self.mesh,
                               in_specs=(spec, P(), P(), P()),
                               out_specs=spec))
        self._mig_cache[perms] = fn
        return fn

    def _apply_plan_mesh(self, plan: MigrationPlan) -> None:
        """Execute a plan's legs as `lax.ppermute` between devices.

        Every leg gathers its payloads from a snapshot of the pre-plan
        array (`orig`), so in-plan src/dst aliasing across legs cannot
        clobber a payload before it is read — the same snapshot
        semantics the flat lowering gets from gather-before-scatter.
        """
        perms = tuple(tuple(p) for p in plan.lowering.perms)
        gs = jnp.asarray(np.stack(plan.lowering.gather_slots))
        ss = jnp.asarray(np.stack(plan.lowering.scatter_slots))
        recv = np.zeros((len(perms), self.n_shards), bool)
        for leg, perm in enumerate(perms):
            for _, d in perm:
                recv[leg, d] = True
        recv = jnp.asarray(recv)
        fn = self._mesh_plan_fn(perms)
        self.pages["k"] = fn(self.pages["k"], gs, ss, recv)
        self.pages["v"] = fn(self.pages["v"], gs, ss, recv)


@dataclasses.dataclass
class _SlotState:
    addrs: List[GlobalAddress]
    length: int                      # tokens stored = abs position clock
    # running blake2b prefix chain for chunked prefill: hashes exactly
    # the tokens already resident, so each chunk hashes only its own
    # tokens instead of re-walking the prefix (None = not chunking)
    chain: Optional[Any] = None


@dataclasses.dataclass
class PrefixCover:
    """The longest cached prefix run of a prompt layout (DESIGN.md
    §4e): `keys` are the covered pages' chain keys (each currently a
    live radix root-path hit), `covered` the layout rows they hold.  `full` means
    every page of the prompt hit AND the final page carries an
    activation checkpoint (`hidden`, the post-norm last-position
    hidden state) — the prompt can admit straight to decode with zero
    prefill compute.  Partial covers are page-aligned by construction
    (a partially-filled page key can only ever be a prompt's final
    page, so matching one implies a full cover), which is exactly
    what lets chunked prefill resume at `covered`."""

    covered: int
    keys: List[Tuple[bytes, int]]
    full: bool
    hidden: Optional[np.ndarray] = None


@dataclasses.dataclass
class KVSnapshot:
    """A preempted slot's KV, written back to the host tier
    (DESIGN.md §4d).  Holds one refcount on every page — the pages'
    global names — plus the position clock and the chunked-prefill
    hash chain, so `PagedKVCache.restore_slot` rebuilds the slot
    exactly as preemption found it: re-admission resumes decoding (or
    mid-prompt chunking) without re-running prefill."""

    addrs: List[GlobalAddress]
    length: int
    chain: Optional[Any] = None


class PagedKVCache:
    """Per-slot block tables over a shared PagePool.

    Every decode slot carries its own position counter (`lengths`) —
    the per-slot clock that replaces the dense cache's shared
    ``len/cursor/abs`` triple — and a block table row mapping its token
    positions onto physical page rows.
    """

    def __init__(self, cfg: ArchConfig, slots: int, max_len: int,
                 n_pages: int, page_size: int, dtype=None, *,
                 n_shards: int = 1, mesh=None, kv_axis: str = "kv",
                 host_pages: int = 0, tracer=None,
                 pin_threshold: int = 4):
        if host_pages > 0:
            from repro.serving.tiering import TieredPagePool
            self.pool: PagePool = TieredPagePool(
                cfg, n_pages, page_size, dtype, n_shards=n_shards,
                mesh=mesh, kv_axis=kv_axis, host_pages=host_pages,
                tracer=tracer, pin_threshold=pin_threshold)
        else:
            self.pool = PagePool(cfg, n_pages, page_size, dtype,
                                 n_shards=n_shards, mesh=mesh,
                                 kv_axis=kv_axis, tracer=tracer,
                                 pin_threshold=pin_threshold)
        self.trace = self.pool.trace
        self.slots = int(slots)
        self.max_len = int(max_len)
        self.max_pages_slot = -(-self.max_len // page_size)
        null = self.pool.null_row
        self.tables = np.full((slots, self.max_pages_slot), null,
                              np.int32)
        self.lengths = np.zeros(slots, np.int32)
        self.write_rows = np.full(slots, null, np.int32)
        self.write_offs = np.zeros(slots, np.int32)
        self._state: List[_SlotState] = [
            _SlotState([], 0) for _ in range(slots)]

    # -- admission-time accounting ------------------------------------
    def pages_needed(self, tokens: np.ndarray, pad: int = 0) -> int:
        """Fresh pages a prefill would allocate (prefix hits excluded)."""
        ps = self.pool.page_size
        return sum(self.pool.page_cost(key)
                   for key in page_keys(tokens, ps, pad))

    def pages_needed_chunk(self, tokens: np.ndarray,
                           start: int, end: int, pad: int = 0) -> int:
        """Fresh pages one chunk [start, end) would allocate.

        The chain keys are computed over the full prefix up to `end`,
        so a chunk boundary never changes a page's identity: chunked
        and whole-prompt prefills of the same layout hash to the same
        pages (prefix sharing works across the two paths).
        """
        ps = self.pool.page_size
        keys = page_keys(tokens[:end], ps, pad)[start // ps:]
        return sum(self.pool.page_cost(key) for key in keys)

    # -- prefill attach ------------------------------------------------
    def attach(self, slot: int, tokens: np.ndarray,
               k, v, pad: int = 0) -> int:
        if not self.trace.enabled:
            return self._attach(slot, tokens, k, v, pad)
        with self.trace.span("kvcache", "attach", kind="pages",
                             slot=slot) as sp:
            covered = self._attach(slot, tokens, k, v, pad)
            sp.args["gids"] = [a.gid for a in self._state[slot].addrs]
            sp.args["covered"] = covered
            return covered

    def _attach(self, slot: int, tokens: np.ndarray,
                k, v, pad: int = 0) -> int:
        """Install a prefilled prompt layout into `slot`.

        k/v: (L, S, KV, D) KV for the full layout (the engines attach
        pad-free layouts, so S is the real prompt length).  Shared
        pages (prefix-hash hits) are reused by refcount instead of
        rewritten.  Returns the covered-token count of the longest
        cached prefix run (leading pages served by hits) — the memory
        the prefix cache saved, and the span compute skip could have
        skipped (DESIGN.md §4e).
        """
        ps = self.pool.page_size
        s = len(tokens)
        if s > self.max_len:
            raise ValueError(f"prompt {s} exceeds max_len {self.max_len}")
        st = self._state[slot]
        assert not st.addrs, f"slot {slot} already attached"
        keys = page_keys(tokens, ps, pad)
        acquired: List[GlobalAddress] = []
        fresh: List[int] = []               # page indices to write
        fresh_gids: set = set()
        covered = 0
        leading = True
        try:
            for i, key in enumerate(keys):
                shared = self.pool.lookup_prefix(key)
                if shared is not None:
                    # incref first (pin, and into `acquired` so a
                    # failed promotion rolls it back), THEN promote: a
                    # spilled page being promoted must not be
                    # eviction's candidate
                    self.pool.incref(shared)
                    acquired.append(shared)
                    self.pool.ensure_device(shared)
                    self.pool.shares += 1
                    if leading:
                        covered += key[1]
                else:
                    leading = False
                    addr = self.pool.alloc()
                    self.pool.register_prefix(
                        key, addr,
                        parent=keys[i - 1][0] if i else None)
                    acquired.append(addr)
                    fresh.append(i)
                    fresh_gids.add(addr.gid)
        except PageExhausted:
            # rollback: only THIS call's fresh (never-written) pages
            # must bypass retention; shared hits hold valid content
            # and go back to the cache via plain decref
            for a in acquired:
                if a.gid in fresh_gids:
                    self.pool.discard(a)
                else:
                    self.pool.decref(a)
            raise
        if fresh:
            # one batched whole-page scatter (zero-padded tail on the
            # partial page — never read: masks stop at the clock)
            tail = len(keys) * ps - s
            kp = jnp.pad(k, ((0, 0), (0, tail), (0, 0), (0, 0))) \
                .reshape(k.shape[0], len(keys), ps, *k.shape[2:])
            vp = jnp.pad(v, ((0, 0), (0, tail), (0, 0), (0, 0))) \
                .reshape(v.shape[0], len(keys), ps, *v.shape[2:])
            fi = jnp.asarray(fresh, jnp.int32)
            self.pool.write_pages(
                [self.pool.row(acquired[i]) for i in fresh],
                kp[:, fi], vp[:, fi])
        st.addrs = acquired
        st.length = s
        self.lengths[slot] = s
        for i, a in enumerate(acquired):
            self.tables[slot, i] = self.pool.row(a)
        return covered

    # -- prefix-cache compute skip (DESIGN.md §4e) --------------------
    def covered_prefix(self, tokens: np.ndarray,
                       pad: int = 0) -> PrefixCover:
        """The longest cached prefix run of a prompt layout.

        One radix-tree walk (`RadixPrefixIndex.match`, O(prompt
        pages)): the longest leading run of the chained page keys
        forming a live root path — the walk also stamps the hit
        statistics that drive hot-prefix pinning.  A full-cover result
        additionally requires the final page's activation checkpoint;
        when the KV is all cached but the checkpoint is missing (the
        pages were attached by a path that never computed hidden
        states), the final page is dropped from the cover so a resumed
        chunk recomputes it — the cover is then page-aligned and
        strictly inside the prompt, exactly what `begin_chunk` needs
        to resume.
        """
        keys = page_keys(tokens, self.pool.page_size, pad)
        nodes = self.pool.prefix.match(keys)
        ck: List[Tuple[bytes, int]] = [n.key for n in nodes]
        covered = sum(k[1] for k in ck)
        if covered == len(tokens) and ck:
            hidden = self.pool.hidden_for(ck[-1])
            if hidden is not None:
                return PrefixCover(covered, ck, True, hidden)
            last = ck.pop()
            covered -= last[1]
        return PrefixCover(covered, ck, False)

    def attach_covered(self, slot: int, tokens: np.ndarray,
                       keys: List[Tuple[bytes, int]]) -> None:
        if not self.trace.enabled:
            return self._attach_covered(slot, tokens, keys)
        with self.trace.span("kvcache", "attach_covered", kind="pages",
                             slot=slot) as sp:
            self._attach_covered(slot, tokens, keys)
            sp.args["gids"] = [a.gid for a in self._state[slot].addrs]
            sp.args["covered"] = sum(k[1] for k in keys)

    def _attach_covered(self, slot: int, tokens: np.ndarray,
                        keys: List[Tuple[bytes, int]]) -> None:
        """Install a covered prefix's cached pages into `slot` with
        ZERO prefill compute and zero KV writes: every key must
        currently hit the prefix index (the caller just computed the
        cover).  The slot is left exactly as a prefill of the covered
        span would have left it — block table and position clock — so
        `begin_chunk` resumes at the cover's end, or decode starts
        immediately on a full cover.  Atomic under PageExhausted
        (promoting a spilled page may need a device row, and a
        promotion-triggered cold drop can even evict a not-yet-pinned
        covered page): on failure every acquired page returns to the
        cache and the caller retries later.
        """
        st = self._state[slot]
        assert not st.addrs, f"slot {slot} already attached"
        pool = self.pool
        acquired: List[GlobalAddress] = []
        try:
            for key in keys:
                shared = pool.lookup_prefix(key)
                if shared is None:
                    raise PageExhausted(
                        "covered prefix page vanished before attach "
                        "(cold drop under promotion pressure)")
                pool.incref(shared)             # pin, then promote
                acquired.append(shared)
                pool.ensure_device(shared)
                pool.shares += 1
        except PageExhausted:
            for a in acquired:
                pool.decref(a)
            raise
        covered = sum(k[1] for k in keys)
        st.addrs = acquired
        st.length = covered
        self.lengths[slot] = covered
        for i, a in enumerate(acquired):
            self.tables[slot, i] = pool.row(a)

    def store_hidden_chunk(self, slot: int, start: int, end: int,
                           boundary: np.ndarray,
                           last: np.ndarray) -> None:
        """Checkpoint the page-boundary activations of chunk
        [start, end): ``boundary[j]`` is the post-norm hidden at
        chunk-local position ``(j + 1) * ps - 1``, ``last`` the hidden
        at ``end - 1`` (the partial final page of a prompt's last
        chunk).  First write wins (`PagePool.store_hidden`)."""
        ps = self.pool.page_size
        st = self._state[slot]
        base = start // ps
        for j in range(-(-(end - start) // ps)):
            addr = st.addrs[base + j]
            if start + (j + 1) * ps <= end:
                self.pool.store_hidden(addr, boundary[j])
            else:
                self.pool.store_hidden(addr, last)

    def store_hidden_prefill(self, slot: int, real: int,
                             boundary: np.ndarray,
                             last: np.ndarray) -> None:
        """Checkpoint a whole-prompt prefill's page-boundary
        activations — exactly the chunk case starting at 0 (attach
        created one addr per page of ``real``)."""
        self.store_hidden_chunk(slot, 0, real, boundary, last)

    # -- chunked prefill (DESIGN.md §4b) ------------------------------
    def begin_chunk(self, slot: int, tokens: np.ndarray,
                    start: int, end: int, pad: int = 0,
                    locality: Optional[int] = None
                    ) -> Tuple[List[int], int]:
        if not self.trace.enabled:
            return self._begin_chunk(slot, tokens, start, end, pad,
                                     locality)
        with self.trace.span("kvcache", "chunk_attach", kind="pages",
                             slot=slot, start=start, end=end) as sp:
            rows, covered = self._begin_chunk(slot, tokens,
                                              start, end, pad,
                                              locality)
            ps = self.pool.page_size
            base = start // ps
            sp.args["gids"] = [a.gid for a in
                               self._state[slot].addrs[base:]]
            return rows, covered

    def _begin_chunk(self, slot: int, tokens: np.ndarray,
                     start: int, end: int, pad: int = 0,
                     locality: Optional[int] = None
                     ) -> Tuple[List[int], int]:
        """Acquire the pages covering chunk [start, end) of a chunked
        prefill and install them in `slot`'s block table.

        `start` must be page-aligned and equal the slot's resident
        length (chunks arrive in order); `end` is page-aligned except
        on the prompt's final chunk, which may leave the last page
        partially filled — the slot holds that partial page between
        the chunk and its first decode write.  Prefix-shared pages are
        reused by refcount.  Returns ``(rows, covered)``: one physical
        write row per page of the chunk, with the pool's null row
        substituted for shared pages so the compiled scatter cannot
        clobber shared content, and the covered-token count of the
        chunk's leading run of prefix hits (DESIGN.md §4e telemetry).
        Atomic under PageExhausted: either every page of the chunk is
        acquired or none (the caller preempts a victim and retries).
        """
        ps = self.pool.page_size
        st = self._state[slot]
        if start % ps:
            raise ValueError(f"chunk start {start} not page-aligned")
        if start != st.length:
            raise ValueError(
                f"slot {slot}: chunk starts at {start} but {st.length} "
                f"tokens are resident")
        if end > self.max_len:
            raise ValueError(f"chunk end {end} exceeds {self.max_len}")
        # extend the slot's running prefix chain (committed only on
        # success, so a PageExhausted retry re-hashes just this chunk);
        # digests match page_keys over the whole layout exactly —
        # `_chain_extend` replays the identical per-page updates
        if st.chain is not None:
            chain = st.chain.copy()
        else:                        # resident tokens came via attach()
            chain = _chain_seed(tokens, start, ps, pad)
        # the radix parent of this chunk's first page: the digest of
        # the slot's resident prefix (root when the chunk starts the
        # prompt — the chain then holds only the pad-count seed, which
        # no node owns)
        prev = chain.digest() if start else None
        keys = _chain_extend(chain, tokens, start, end, ps, pad)
        acquired: List[GlobalAddress] = []
        rows: List[int] = []
        fresh_gids: set = set()
        covered = 0
        leading = True
        try:
            for key in keys:
                shared = self.pool.lookup_prefix(key)
                if shared is not None:
                    self.pool.incref(shared)        # pin, then promote
                    acquired.append(shared)
                    self.pool.ensure_device(shared)
                    self.pool.shares += 1
                    rows.append(self.pool.null_row)
                    if leading:
                        covered += key[1]
                else:
                    leading = False
                    # placement preference (§4f): a dispatched chunk
                    # allocates at its prefill worker's locality, so
                    # the prefix pages it registers make that worker
                    # the owner the NEXT matching prompt dispatches
                    # to.  Soft: an exhausted preferred shard falls
                    # back to the default least-loaded policy rather
                    # than preempting while other shards have room.
                    # a retired hint (§4g) falls back too: allocating
                    # on a dead shard would raise, and the resulting
                    # PageExhausted would read as pool pressure
                    loc = locality
                    if loc is not None and (
                            not self.pool.agas.is_active(loc)
                            or self.pool.agas.free_count(loc) == 0):
                        loc = None
                    addr = self.pool.alloc(loc)
                    self.pool.register_prefix(key, addr, parent=prev)
                    acquired.append(addr)
                    fresh_gids.add(addr.gid)
                    rows.append(self.pool.row(addr))
                prev = key[0]
        except PageExhausted:
            # fresh (unwritten) pages bypass retention; shared hits
            # return to the prefix cache with their content intact
            for a in acquired:
                if a.gid in fresh_gids:
                    self.pool.discard(a)
                else:
                    self.pool.decref(a)
            raise
        base = start // ps
        for i, a in enumerate(acquired):
            st.addrs.append(a)
            self.tables[slot, base + i] = self.pool.row(a)
        st.chain = chain
        st.length = end
        self.lengths[slot] = end
        return rows, covered

    # -- decode-step bookkeeping --------------------------------------
    def prepare_decode(self, slot: int) -> None:
        """Reserve the write target for this slot's next token.

        Allocates a fresh page at page boundaries; clones (COW) a
        shared page before the first divergent append.  Idempotent, so
        the engine can retry after preempting a victim on
        PageExhausted.
        """
        st = self._state[slot]
        ps = self.pool.page_size
        pos = st.length
        page_idx, off = divmod(pos, ps)
        if page_idx >= self.max_pages_slot:
            raise RuntimeError(
                f"slot {slot} overflows max_len {self.max_len}")
        if page_idx == len(st.addrs):
            addr = self.pool.alloc()
            st.addrs.append(addr)
        else:
            addr = st.addrs[page_idx]
            if self.pool.refcount(addr) > 1:
                fresh = self.pool.alloc()
                self.pool.copy_page(self.pool.row(addr),
                                    self.pool.row(fresh))
                self.pool.decref(addr)
                st.addrs[page_idx] = fresh
                addr = fresh
        # the write target mutates in place: any retained host-tier
        # copy of it is stale from here on (DESIGN.md §4g)
        self.pool.note_page_write(addr)
        row = self.pool.row(addr)
        self.tables[slot, page_idx] = row
        self.write_rows[slot] = row
        self.write_offs[slot] = off

    def needs_alloc(self, slot: int) -> bool:
        """Will this slot's next prepare_decode take a page from the
        pool?  True at page boundaries (fresh page) and on shared
        partial pages (COW clone) — the admission watermark."""
        st = self._state[slot]
        page_idx, _ = divmod(st.length, self.pool.page_size)
        if page_idx >= len(st.addrs):
            return True
        return self.pool.refcount(st.addrs[page_idx]) > 1

    def advance(self, slot: int) -> None:
        st = self._state[slot]
        st.length += 1
        self.lengths[slot] = st.length

    def release(self, slot: int) -> None:
        st = self._state[slot]
        if self.trace.enabled and st.addrs:
            self.trace.instant("kvcache", "release", slot=slot,
                               gids=[a.gid for a in st.addrs])
        for a in st.addrs:
            self.pool.decref(a)
        st.addrs = []
        st.length = 0
        st.chain = None
        null = self.pool.null_row
        self.tables[slot, :] = null
        self.lengths[slot] = 0
        self.write_rows[slot] = null
        self.write_offs[slot] = 0

    def drain_slot(self, slot: int, lost: set) -> None:
        """Release a slot some of whose pages died with their locality
        (DESIGN.md §4g): surviving pages decref normally; lost gids
        were already swept out of the pool by `kill_locality`, so the
        refcount this slot held on them died with the page and must
        NOT be returned again.  The slot is left empty for
        re-admission (its request re-prefills from the retained
        prompt + generated tokens)."""
        st = self._state[slot]
        if self.trace.enabled and st.addrs:
            self.trace.instant("kvcache", "drain_slot", slot=slot,
                               gids=[a.gid for a in st.addrs])
        for a in st.addrs:
            if a.gid not in lost:
                self.pool.decref(a)
        st.addrs = []
        st.length = 0
        st.chain = None
        null = self.pool.null_row
        self.tables[slot, :] = null
        self.lengths[slot] = 0
        self.write_rows[slot] = null
        self.write_offs[slot] = 0

    # -- prefill->decode handoff (DESIGN.md §4f) ----------------------
    def detach_slot(self, slot: int) -> Optional[KVSnapshot]:
        """Detach a slot's KV into a snapshot WITHOUT moving a page —
        the §4f handoff unit between a prefill worker and a decode
        worker.

        The snapshot keeps the slot's refcount on every page: the
        pages' global names are the handoff currency, and because
        both roles address the same AGAS directory no byte needs to
        move when the pages are already device-resident (a multi-host
        transport would stage the copy here; the tiered restore path
        commits it).  `restore_slot` rebuilds the receiving slot —
        block table, position clock, chunked-prefill hash chain —
        exactly as detach left it, mid-prefill chunk boundaries
        included.  Returns None for an empty slot."""
        st = self._state[slot]
        if not st.addrs:
            return None
        if self.trace.enabled:
            self.trace.instant("kvcache", "detach", slot=slot,
                               gids=[a.gid for a in st.addrs])
        snap = KVSnapshot(list(st.addrs), st.length,
                          st.chain.copy() if st.chain is not None
                          else None)
        st.addrs = []
        st.length = 0
        st.chain = None
        null = self.pool.null_row
        self.tables[slot, :] = null
        self.lengths[slot] = 0
        self.write_rows[slot] = null
        self.write_offs[slot] = 0
        return snap

    # -- percolation: offload / restore (DESIGN.md §4d) ---------------
    def offload_slot(self, slot: int) -> Optional[KVSnapshot]:
        st = self._state[slot]
        if not self.trace.enabled or not st.addrs:
            return self._offload_slot(slot)
        with self.trace.span("kvcache", "offload_slot", kind="copy",
                             slot=slot,
                             gids=[a.gid for a in st.addrs]) as sp:
            snap = self._offload_slot(slot)
            sp.args["offloaded"] = snap is not None
            return snap

    def _offload_slot(self, slot: int) -> Optional[KVSnapshot]:
        """Write back a preempted slot's KV to the host tier instead
        of freeing it.

        Exclusively-owned pages demote to host as one copy parcel;
        prefix-shared pages stay on device, pinned by their other
        holders — either way the snapshot keeps this slot's refcount
        on every page.  Returns None when the pool is untiered or the
        host tier cannot hold the write-back (the caller falls back to
        `release` + re-prefill).  The slot is left empty and reusable.
        """
        pool = self.pool
        st = self._state[slot]
        if not getattr(pool, "tiered", False) or not st.addrs:
            return None
        if pool.offload_pages(st.addrs, key=("offload", slot,
                                             st.length)) is None:
            return None
        snap = KVSnapshot(list(st.addrs), st.length,
                          st.chain.copy() if st.chain is not None
                          else None)
        st.addrs = []
        st.length = 0
        st.chain = None
        null = pool.null_row
        self.tables[slot, :] = null
        self.lengths[slot] = 0
        self.write_rows[slot] = null
        self.write_offs[slot] = 0
        return snap

    def restore_pages_needed(self, snap: KVSnapshot) -> int:
        """Device rows restoring this snapshot will consume (its
        host-resident pages; device-resident shared ones are free)."""
        return sum(1 for a in snap.addrs
                   if not self.pool.on_device(a))

    def stage_restore(self, key: Any, snap: KVSnapshot) -> bool:
        """Begin the host->device copy of a snapshot's pages NOW
        (double-buffered), so a later `restore_slot` commits a copy
        that already ran under compute."""
        return self.pool.stage_promote(key, snap.addrs)

    def restore_slot(self, slot: int, snap: KVSnapshot,
                     staged_key: Any = None) -> None:
        if not self.trace.enabled:
            return self._restore_slot(slot, snap, staged_key)
        with self.trace.span("kvcache", "restore", kind="pages",
                             slot=slot,
                             gids=[a.gid for a in snap.addrs]):
            return self._restore_slot(slot, snap, staged_key)

    def _restore_slot(self, slot: int, snap: KVSnapshot,
                      staged_key: Any = None) -> None:
        """Re-admit an offloaded request: promote its pages back to
        device (using the staged payload when one matches) and rebuild
        the slot — block table, position clock, hash chain — exactly
        as preemption left it.  Raises `PageExhausted` (snapshot still
        valid, retry later) when the device tier cannot hold it."""
        st = self._state[slot]
        assert not st.addrs, f"slot {slot} already attached"
        # untiered pools never have an off-device page (handoff
        # snapshots restore through this path too, DESIGN.md §4f)
        if getattr(self.pool, "tiered", False):
            self.pool.promote_pages(snap.addrs, staged_key=staged_key)
        st.addrs = list(snap.addrs)
        st.length = snap.length
        st.chain = snap.chain.copy() if snap.chain is not None else None
        self.lengths[slot] = snap.length
        for i, a in enumerate(st.addrs):
            self.tables[slot, i] = self.pool.row(a)

    def drop_snapshot(self, snap: KVSnapshot,
                      lost: Optional[set] = None) -> None:
        """Release a snapshot's refcounts (its request finished or
        failed while still queued) — host-resident pages free their
        host rows; prefix-owned ones may be retained cold.  `lost`
        (a dead locality's swept gids, §4g) are skipped: the refcount
        the snapshot held on them died with the page."""
        for a in snap.addrs:
            if lost is None or a.gid not in lost:
                self.pool.decref(a)
        snap.addrs = []

    def prefetch_chunk(self, slot: int, tokens: np.ndarray,
                       start: int, end: int, pad: int = 0) -> int:
        """Stage the promotion of any spilled prefix pages chunk
        [start, end) will share — percolation ahead of the chunk that
        needs them.  Returns pages staged (best effort: the double
        buffer may be full).

        Hashes only [start, end) by extending a copy of the slot's
        running chain (the begin_chunk trick), and bails immediately
        when nothing lives on the host tier — the common no-spill case
        costs one integer compare, not a prefix walk.
        """
        pool = self.pool
        if not getattr(pool, "tiered", False) or pool.host_used == 0:
            return 0
        ps = pool.page_size
        st = self._state[slot]
        if st.chain is not None:
            chain = st.chain.copy()
        else:
            chain = _chain_seed(tokens, start, ps, pad)
        staged = 0
        for key in _chain_extend(chain, tokens, start, end, ps, pad):
            addr = pool.lookup_prefix(key)
            if addr is not None and not pool.on_device(addr):
                if pool.stage_promote(("page", addr.gid), [addr]):
                    staged += 1
        return staged

    # -- inter-shard migration (DESIGN.md §4c) ------------------------
    def refresh_tables(self) -> None:
        """Re-resolve every block-table entry from the AGAS directory.

        After a migration a page's global name is unchanged but its
        (locality, slot) — and therefore its flat row — is not; one
        directory walk restores table consistency.  Write rows are NOT
        refreshed here: `prepare_decode` recomputes them before every
        decode write and `begin_chunk` returns fresh rows per chunk, so
        migration between steps can never race a stale write target.
        """
        for slot, st in enumerate(self._state):
            for i, a in enumerate(st.addrs):
                self.tables[slot, i] = self.pool.row(a)

    def migrate(self, moves: Dict[GlobalAddress, int]) -> int:
        """Migrate pages and restore table consistency; returns the
        number of pages actually moved."""
        plan = self.pool.migrate_pages(moves)
        if plan.moves:
            self.refresh_tables()
        return len(plan.moves)

    def maybe_rebalance(self, tolerance: int) -> int:
        """Imbalance-triggered migration: when per-shard page counts
        drift more than `tolerance` apart, move movable pages from the
        fullest shard to the emptiest (between engine steps)."""
        used = self.pool.shard_used()
        if max(used) - min(used) <= max(int(tolerance), 1):
            return 0
        return self.migrate(self.pool.plan_rebalance(tolerance))

    # -- the compiled-step view ---------------------------------------
    def batch_inputs(self) -> Dict[str, Any]:
        """Fixed-shape arrays for decode_step_paged (one compile)."""
        return {
            "block_tables": jnp.asarray(self.tables),
            "positions": jnp.asarray(self.lengths),
            "write_rows": jnp.asarray(self.write_rows),
            "write_offs": jnp.asarray(self.write_offs),
        }
