"""Pure-jnp oracle for the selective-scan kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(da: jnp.ndarray, dbx: jnp.ndarray,
                       c: jnp.ndarray) -> jnp.ndarray:
    """The sequential recurrence (same math as mamba1_scan_ref's core).

    da/dbx: (B, S, D, N); c: (B, S, N) -> y: (B, S, D) f32.
    """
    b, s, d, n = da.shape
    h0 = jnp.zeros((b, d, n), jnp.float32)

    def step(h, t):
        da_t, dbx_t, c_t = t
        h = da_t * h + dbx_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    _, ys = jax.lax.scan(
        step, h0, (da.swapaxes(0, 1), dbx.swapaxes(0, 1),
                   c.swapaxes(0, 1)))
    return ys.swapaxes(0, 1)
