"""ft subpackage."""
