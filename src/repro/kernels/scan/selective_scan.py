"""Pallas TPU kernel: Mamba-1 selective scan (chunked, channel-parallel).

The recurrence h_t = da_t * h_{t-1} + dbx_t ; y_t = <h_t, C_t> is
sequential in time but embarrassingly parallel over the d_inner
channels — the TPU-native layout keeps a (bd, n) state tile resident in
VMEM and walks time in chunks:

  grid = (B, n_dblocks, n_chunks); LAST axis sequential.
  in  : da, dbx (1, chunk, bd, n) VMEM;  c (1, chunk, n) VMEM
  out : y (1, chunk, bd) VMEM
  scratch : h (bd, n) f32 — persists across the chunk axis (the chunk
  carry is the dataflow future between chunk tasks, DESIGN.md §4).

HBM traffic is one read of (da, dbx, c) and one write of y — the
(S, d, n) state history never materializes, which is the point of the
Mamba scan kernel; the jnp oracle (ref.py) is the lax.scan recurrence.
d-block size bd should be a multiple of 8 (sublane) and n is the small
state dim (16); time steps inside a chunk run in a fori_loop over VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(da_ref, dbx_ref, c_ref, y_ref, h_ref, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        da_t = da_ref[0, t]          # (bd, n)
        dbx_t = dbx_ref[0, t]
        c_t = c_ref[0, t]            # (n,)
        h = da_t * h + dbx_t
        y_ref[0, t] = jnp.sum(h * c_t[None, :], axis=-1).astype(
            y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h


def selective_scan(da: jnp.ndarray, dbx: jnp.ndarray, c: jnp.ndarray,
                   *, chunk: int = 128, d_block: int = 256,
                   interpret: bool = True) -> jnp.ndarray:
    """da/dbx: (B, S, D, N) f32; c: (B, S, N) f32 -> y (B, S, D) f32."""
    b, s, d, n = da.shape
    chunk = min(chunk, s)
    d_block = min(d_block, d)
    nch = s // chunk
    ndb = d // d_block
    kern = functools.partial(_kernel, chunk=chunk)
    # layout: (B, S, D, N) -> blocks (1, chunk, d_block, n)
    return pl.pallas_call(
        kern,
        grid=(b, ndb, nch),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block, n),
                         lambda bi, di, ci: (bi, ci, di, 0)),
            pl.BlockSpec((1, chunk, d_block, n),
                         lambda bi, di, ci: (bi, ci, di, 0)),
            pl.BlockSpec((1, chunk, n),
                         lambda bi, di, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block),
                               lambda bi, di, ci: (bi, ci, di)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d_block, n), jnp.float32)],
        interpret=interpret,
    )(da, dbx, c)
