#!/usr/bin/env python
"""Fail CI when a property/fuzz suite silently skipped (or vanished).

``pytest.importorskip("hypothesis")`` makes the property suites pass
vacuously when the dependency is missing: tier-1 stays green with its
strongest tests not running, and nothing in the log shouts about it.
CI installs hypothesis, so in CI those suites must actually run — this
script reads the junit XML tier-1 produced and asserts every listed
module both contributed at least one test case AND reported zero
skips.  (Locally, without hypothesis, the suites still degrade to a
visible skip — that is the supported workflow; only CI enforces.)

junit shape (verified against pytest 7/8): a module-level skip emits
one testcase with an empty classname and the dotted module path as
its name; normally-collected tests carry the dotted module path in
classname.  Matching on both catches either form.

Usage:
    python tools/assert_no_skips.py tier1.xml mod1 mod2 ...
e.g.
    python tools/assert_no_skips.py tier1.xml \
        test_pagepool_properties test_tiering_properties \
        test_granularity_properties test_scheduler_agas \
        test_engine_fuzz
"""

import sys
import xml.etree.ElementTree as ET


def check(xml_path, modules):
    root = ET.parse(xml_path).getroot()
    seen = {m: 0 for m in modules}
    skipped = {m: 0 for m in modules}
    for tc in root.iter("testcase"):
        ident = "%s %s" % (tc.get("classname") or "",
                           tc.get("name") or "")
        for m in modules:
            if m in ident:
                seen[m] += 1
                if tc.find("skipped") is not None:
                    skipped[m] += 1
    bad = []
    for m in modules:
        state = "MISSING" if seen[m] == 0 else (
            "SKIPPED" if skipped[m] else "ok")
        print(f"  {m}: {seen[m]} case(s), {skipped[m]} skipped "
              f"[{state}]")
        if seen[m] == 0 or skipped[m]:
            bad.append(m)
    return bad


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 2
    bad = check(argv[1], argv[2:])
    if bad:
        print(f"FAIL: property/fuzz suites silently skipped or "
              f"missing: {', '.join(bad)} — is hypothesis installed?")
        return 1
    print("OK: every property/fuzz suite ran with zero skips")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
