"""Shared neural-net building blocks (pure functional JAX).

Conventions
-----------
* Every module is an (init, apply) pair of plain functions; params are
  nested dicts of jnp arrays.  No framework dependency.
* Weight layouts are chosen so the sharding rules in
  distributed/sharding.py apply by path name:
    wq,wk,wv : (d_model, heads*head_dim)   last dim -> "model"
    wo       : (heads*head_dim, d_model)   first dim -> "model"
    wi,wg    : (d_model, d_ff)             last dim -> "model"
    wdown    : (d_ff, d_model)             first dim -> "model"
    embed    : (vocab, d_model)            first dim -> "model"
* Computation dtype follows the input; params are stored in the config
  dtype (bf16 for the full archs, f32 for smoke tests); norms and
  softmax accumulate in f32.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


import contextlib

_CONSTRAINT_MESH = [None]


@contextlib.contextmanager
def constraint_mesh(mesh):
    """Registers the mesh used by `constrain_spec` during tracing.

    The launch layer wraps .lower() in this; model code can then place
    sharding constraints without threading mesh objects through every
    call.  Host/CPU tests never enter it, so constraints are no-ops
    there.
    """
    _CONSTRAINT_MESH.append(mesh)
    try:
        yield
    finally:
        _CONSTRAINT_MESH.pop()


def constrain_spec(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """with_sharding_constraint against the registered mesh, if any.

    `spec` entries: "U" = unconstrained, None = replicated, or a mesh
    axis name (skipped when the mesh lacks it).  No-op without a
    registered mesh.
    """
    mesh = _CONSTRAINT_MESH[-1]
    if mesh is None:
        return x
    P = jax.sharding.PartitionSpec

    def size_of(axes) -> int:
        axes = (axes,) if isinstance(axes, str) else axes
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n

    fixed = []
    for dim, s in zip(x.shape, spec):
        if s == "U":
            fixed.append(P.UNCONSTRAINED)
            continue
        if s == "DP":   # the data-parallel axes present in the mesh
            s = tuple(a for a in ("pod", "data")
                      if a in mesh.axis_names) or None
        elif isinstance(s, str) and s not in mesh.axis_names:
            fixed.append(P.UNCONSTRAINED)
            continue
        # indivisible dims cannot take the axis: leave unconstrained
        if s is not None and dim % size_of(s) != 0:
            fixed.append(P.UNCONSTRAINED)
        else:
            fixed.append(s)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*fixed)))


def _init_dense(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> Params:
    return {"w": _init_dense(key, d_in, d_out, dtype)}


def dense(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ params["w"]


def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-5
            ) -> jnp.ndarray:
    """RMSNorm with f32 statistics but an input-dtype data path.

    The variance is an einsum contraction with f32 ACCUMULATION — no
    f32 (B, S, D) copy of the residual stream ever materializes (the
    baseline `x.astype(f32); mean(x*x)` version produced f32
    activation-sized tensors whose gradients the partitioner then
    all-gathered/all-reduced at 2x bf16 bytes in every layer —
    EXPERIMENTS.md §Perf fix F1).
    """
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32)[..., None]
    var = var / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype) -> Params:
    emb = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    return {"embedding": emb.astype(dtype)}


def embed_lookup(params: Params, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["embedding"], ids, axis=0)


def swiglu_init(key, d: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": _init_dense(k1, d, d_ff, dtype),
        "wg": _init_dense(k2, d, d_ff, dtype),
        "wdown": _init_dense(k3, d_ff, d, dtype),
    }


def swiglu(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    return h @ params["wdown"]


def cross_entropy_chunked(x: jnp.ndarray, out_embed: jnp.ndarray,
                          labels: jnp.ndarray, chunk: int
                          ) -> jnp.ndarray:
    """Mean next-token CE with a chunked vocab projection.

    x: (B, S, D) final hidden states; out_embed: (V, D); labels: (B, S).
    The (B, chunk, V) logits tensor is the only vocab-sized buffer ever
    materialized — with V up to 256k this is what keeps the train step
    inside HBM (DESIGN.md §6).  Chunks are rematerialized on backward.
    """
    b, s, d = x.shape
    n_chunks = max(s // chunk, 1)
    chunk = s // n_chunks
    xs = x.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(carry, xc_lc):
        xc, lc = xc_lc
        logits = (xc @ out_embed.T.astype(xc.dtype)).astype(jnp.float32)
        m = jnp.max(logits, axis=-1, keepdims=True)
        lse = m[..., 0] + jnp.log(
            jnp.sum(jnp.exp(logits - m), axis=-1))
        # one-hot-free target logit extraction (keeps vocab sharded)
        v = logits.shape[-1]
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        tgt = jnp.sum(jnp.where(iota == lc[..., None], logits, 0.0),
                      axis=-1)
        return carry + jnp.sum(lse - tgt), None

    total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (xs, ls))
    return total / (b * n_chunks * chunk)
