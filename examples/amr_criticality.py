"""The paper's science driver: critical-amplitude search.

The semilinear wave equation (p=7) exhibits critical behaviour: small
amplitudes disperse, large ones blow up in finite time.  The paper's
simulations "explore the threshold of singularity formation"; this
example bisects the threshold amplitude with the barrier-free engine
doing the evolution.

  PYTHONPATH=src python examples/amr_criticality.py [--iters 8]
"""

import argparse

import numpy as np

from repro import amr


def evolves_to_blowup(prob, n_coarse=40, threshold=1e3):
    """Evolve and classify: True if the field blows up."""
    specs = amr.default_specs(prob, 2)
    eng = amr.DataflowEngine(prob, amr.EngineConfig(
        grain=16, n_workers=4))
    try:
        res = eng.run(specs, n_coarse, window=4)
    except FloatingPointError:
        return True
    chi_max = max(float(amr.linf(s.arr)) for s in res.states)
    return not np.isfinite(chi_max) or chi_max > threshold


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=6)
    ap.add_argument("--n-points", type=int, default=128)
    args = ap.parse_args()
    lo, hi = 0.01, 0.35        # disperses / blows up
    print("bisecting the critical amplitude A* "
          "(chi0 = A exp[-(r-8)^2]):")
    for i in range(args.iters):
        mid = 0.5 * (lo + hi)
        prob = amr.WaveProblem(n_points=args.n_points, rmax=20.0,
                               amplitude=mid)
        blew = evolves_to_blowup(prob)
        print(f"  iter {i}: A={mid:.5f} -> "
              f"{'blow-up' if blew else 'disperses'}")
        if blew:
            hi = mid
        else:
            lo = mid
    print(f"\ncritical amplitude A* in [{lo:.5f}, {hi:.5f}]")


if __name__ == "__main__":
    main()
