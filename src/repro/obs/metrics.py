"""Unified metrics registry: counters, gauges, streaming histograms.

Metric names follow a ``subsystem.metric`` scheme ("engine.ttft_ms",
"pool.allocs", "percolation.demote_bytes", "tier.evictions") so every
``stats()`` surface reads from one namespace.  Histograms are streaming
sketches — log-spaced sparse buckets, O(buckets) memory independent of
sample count — replacing the engines' unbounded per-completion latency
lists.  Count, sum (hence mean), min and max are tracked exactly;
quantiles interpolate within a bucket, so relative error is bounded by
the bucket growth factor (~1.5% at growth 1.03).
"""

import math

__all__ = ["Counter", "Gauge", "StreamingHistogram", "MetricsRegistry"]


class Counter:
    """Monotonic counter (reset only via reset())."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1):
        self.value += n

    def reset(self):
        self.value = 0


class Gauge:
    """Last-write-wins value; set_max() tracks a running peak."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, v):
        self.value = v

    def set_max(self, v):
        if v > self.value:
            self.value = v

    def reset(self):
        self.value = 0


class StreamingHistogram:
    """Quantile sketch over positive samples in O(buckets) memory.

    Bucket i covers [GROWTH**i, GROWTH**(i+1)); non-positive samples go
    to a dedicated underflow bucket and are represented by the exact
    minimum.  quantile(q) walks the cumulative counts and interpolates
    linearly inside the containing bucket, clamped to [min, max] — so it
    is monotone in q and exact at the extremes.
    """

    GROWTH = 1.03
    _LOG_GROWTH = math.log(GROWTH)

    __slots__ = ("count", "sum", "min", "max", "_buckets", "_under")

    def __init__(self):
        self.reset()

    def reset(self):
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._buckets = {}
        self._under = 0

    def record(self, v):
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= 0.0:
            self._under += 1
        else:
            i = math.floor(math.log(v) / self._LOG_GROWTH)
            self._buckets[i] = self._buckets.get(i, 0) + 1

    @property
    def mean(self):
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q):
        """q in [0, 100]."""
        if self.count == 0:
            return 0.0
        rank = (q / 100.0) * (self.count - 1)
        cum = self._under
        if rank < cum:
            return self.min
        lo_clamp, hi_clamp = self.min, self.max
        for i in sorted(self._buckets):
            n = self._buckets[i]
            if rank < cum + n:
                lo = self.GROWTH ** i
                hi = self.GROWTH ** (i + 1)
                frac = (rank - cum + 0.5) / n
                v = lo + (hi - lo) * frac
                return min(max(v, lo_clamp), hi_clamp)
            cum += n
        return self.max

    def snapshot(self):
        return {
            "count": self.count,
            "mean": self.mean,
            "p50": self.quantile(50.0),
            "p95": self.quantile(95.0),
            "p99": self.quantile(99.0),
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
        }


class MetricsRegistry:
    """Get-or-create registry keyed by ``subsystem.metric`` names."""

    def __init__(self):
        self._metrics = {}

    def _get(self, name, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls()
        elif type(m) is not cls:
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name):
        return self._get(name, Counter)

    def gauge(self, name):
        return self._get(name, Gauge)

    def histogram(self, name):
        return self._get(name, StreamingHistogram)

    def get(self, name):
        return self._metrics.get(name)

    def names(self):
        return sorted(self._metrics)

    def snapshot(self):
        """Flat name -> value dict; histograms expand to name.stat."""
        out = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, StreamingHistogram):
                for k, v in m.snapshot().items():
                    out[f"{name}.{k}"] = v
            else:
                out[name] = m.value
        return out

    def reset(self):
        for m in self._metrics.values():
            m.reset()
