"""Model zoo: per-arch smoke tests (reduced configs) + consistency."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import transformer as T
from repro.models.config import SHAPES, shape_applicable

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def make_batch(cfg, b=B, s=S, train=True):
    ks = jax.random.split(KEY, 4)
    batch = {"tokens": jax.random.randint(ks[0], (b, s), 0,
                                          cfg.vocab_size)}
    if train:
        batch["labels"] = jax.random.randint(ks[1], (b, s), 0,
                                             cfg.vocab_size)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[2], (b, cfg.n_frontend_tokens, 32))
    if cfg.family == "audio":
        batch["frame_embeds"] = 0.1 * jax.random.normal(
            ks[3], (b, s, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", configs.ARCHS)
def test_arch_smoke_forward_loss_grad(name):
    """The assigned smoke test: reduced config, one forward/train step
    on CPU, output shapes + no NaNs."""
    cfg = configs.get_reduced(name)
    params = T.init_params(KEY, cfg)
    batch = make_batch(cfg)
    h, aux = T.forward(params, batch, cfg)
    assert h.shape == (B, S, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(h, np.float32)))
    loss, grads = jax.value_and_grad(
        lambda p: T.loss_fn(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", configs.ARCHS)
def test_arch_decode_smoke(name):
    cfg = configs.get_reduced(name)
    params = T.init_params(KEY, cfg)
    cache = T.init_cache(cfg, B, 32)
    batch = make_batch(cfg, s=1, train=False)
    batch["tokens"] = batch["tokens"][:, :1]
    logits, cache2 = T.decode_step(params, cache, batch, cfg)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert int(cache2["len"]) == 1


@pytest.mark.parametrize("name", configs.ARCHS)
def test_prefill_decode_matches_forward(name):
    """prefill(S-1) + decode(token S) == forward(S) at the last pos."""
    cfg = configs.get_reduced(name)
    params = T.init_params(KEY, cfg)
    batch = make_batch(cfg, train=False)
    full, _ = T.forward(params, batch, cfg, mode="train")
    ref_logits = T.logits_fn(params, full[:, -1])

    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :-1]
    if "frame_embeds" in pre_batch:
        pre_batch["frame_embeds"] = batch["frame_embeds"][:, :-1]
    _, cache = T.prefill(params, pre_batch, cfg)
    # pad cache seq to allow one more token
    def pad_seq(x):
        if x.ndim >= 3 and x.shape[-3] == S - 1:
            pads = [(0, 0)] * x.ndim
            pads[-3] = (0, 8)
            return jnp.pad(x, pads)
        return x
    cache = {k: (pad_seq(v) if k not in ("len", "cursor", "abs")
                 else v) for k, v in cache.items()}
    dec_batch = dict(batch)
    dec_batch["tokens"] = batch["tokens"][:, -1:]
    dec_batch.pop("labels", None)
    if "frame_embeds" in dec_batch:
        # decode path ignores frame embeds (conditioning was prefixed)
        dec_batch.pop("frame_embeds")
    logits, _ = T.decode_step(params, cache, dec_batch, cfg)
    # audio adds frame embeds in forward but not decode: skip exactness
    if cfg.family == "audio":
        return
    if cfg.family == "moe":
        # capacity-based top-2 routing depends on a token's group
        # companions, which differ between prefill and decode batches
        # (a real property of GShard-style MoE) — compare decisions.
        assert (np.argmax(np.asarray(logits), -1) ==
                np.argmax(np.asarray(ref_logits), -1)).all()
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits), atol=0.15)
        return
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(ref_logits),
                               atol=2e-3, rtol=2e-3)


def test_param_counts_match_published():
    expect = {
        "h2o-danube-3-4b": 4.0e9, "chatglm3-6b": 6.2e9,
        "command-r-plus-104b": 104e9, "yi-6b": 6.1e9,
        "falcon-mamba-7b": 7.3e9, "zamba2-7b": 7.0e9,
        "mixtral-8x7b": 46.7e9, "phi3.5-moe-42b-a6.6b": 41.9e9,
        "musicgen-large": 3.3e9, "llama-3.2-vision-90b": 88e9,
    }
    for name, target in expect.items():
        got = configs.get(name).param_count()
        assert abs(got - target) / target < 0.12, \
            f"{name}: {got / 1e9:.1f}B vs {target / 1e9:.1f}B"


def test_moe_active_params():
    assert abs(configs.get("mixtral-8x7b").active_param_count()
               - 12.9e9) / 12.9e9 < 0.05
    assert abs(configs.get("phi3.5-moe-42b-a6.6b").active_param_count()
               - 6.6e9) / 6.6e9 < 0.05


def test_long500k_applicability():
    runnable = [a for a in configs.ARCHS if shape_applicable(
        configs.get(a), SHAPES["long_500k"])[0]]
    assert sorted(runnable) == sorted(
        ["falcon-mamba-7b", "zamba2-7b", "h2o-danube-3-4b",
         "mixtral-8x7b"])


def test_swa_ring_buffer_decode():
    """Decoding past the window keeps only `window` live keys."""
    cfg = configs.get_reduced("h2o-danube-3-4b")
    assert cfg.sliding_window == 32
    params = T.init_params(KEY, cfg)
    cache = T.init_cache(cfg, 1, 128)
    assert cache["k"].shape[-3] == 32       # capped at window
    batch = {"tokens": jnp.zeros((1, 1), jnp.int32)}
    for i in range(40):                      # wrap the ring
        logits, cache = T.decode_step(params, cache, batch, cfg)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert int(cache["cursor"]) == 40
