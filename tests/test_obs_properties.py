"""Hypothesis property tests for the observability subsystem
(DESIGN.md §10):

* random span trees recorded through the tracer always satisfy
  ``check_nesting`` (a child interval nests within its parent), and
  attribution self-times sum back to step wall-clock;
* randomly generated causal event streams: well-formed
  request -> slot -> page chains validate clean, and a single injected
  dangle (unsubmitted rid, unbound slot, unallocated/freed gid) is
  always caught by ``check_causal``;
* streaming-histogram quantiles are monotone in q and track the exact
  order statistics within the bucket growth error.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.obs.attribution import attribute, check_causal, check_nesting
from repro.obs.metrics import StreamingHistogram
from repro.obs.trace import Tracer


class ManualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


KINDS = (None, "compute", "sched", "pages", "parcel", "copy")

# A span tree as a nested structure: (kind, dt_before, [children],
# dt_after) — dts advance the manual clock so intervals are distinct.
tree_strategy = st.deferred(lambda: st.tuples(
    st.sampled_from(KINDS),
    st.floats(0.001, 0.1),
    st.lists(tree_strategy, max_size=3),
    st.floats(0.001, 0.1),
))


def _record_tree(tr, clk, node):
    kind, before, kids, after = node
    clk.t += before
    with tr.span("engine", "op", kind=kind):
        for kid in kids:
            _record_tree(tr, clk, kid)
        clk.t += after


@settings(max_examples=50, deadline=None)
@given(st.lists(tree_strategy, min_size=1, max_size=4))
def test_random_span_trees_nest_and_attribute_exactly(trees):
    clk = ManualClock()
    tr = Tracer(capacity=1 << 12, clock=clk)
    for tree in trees:
        clk.t += 0.01
        with tr.span("engine", "step"):
            for sub in [tree]:
                _record_tree(tr, clk, sub)
            clk.t += 0.005
    recs = tr.records()
    assert tr.dropped == 0
    assert check_nesting(recs) == []
    rep = attribute(recs)
    assert rep["steps"] == len(trees)
    # self times sum to wall exactly (fake clock: no float noise beyond
    # accumulation error)
    assert rep["sum_residual"] < 1e-6
    assert rep["compute_ms"] + rep["overhead_ms"] == \
        pytest.approx(rep["wall_ms"])


# -- causal streams ----------------------------------------------------

@st.composite
def causal_stream(draw):
    """A well-formed stream plus an optional single injected dangle."""
    n_req = draw(st.integers(1, 4))
    n_pages = draw(st.integers(1, 6))
    n_slots = min(2, n_req)
    clk = ManualClock()
    tr = Tracer(capacity=1 << 12, clock=clk)
    for rid in range(n_req):
        clk.t += 0.01
        tr.instant("engine", "submit", rid=rid)
        clk.t += 0.01
        tr.instant("engine", "slot_bind", rid=rid, slot=rid % n_slots)
    gids = list(range(n_pages))
    for g in gids:
        clk.t += 0.01
        tr.instant("kvcache", "page_alloc", gid=g, slot=g % n_slots)
    use = draw(st.lists(st.sampled_from(gids), max_size=6))
    for g in use:
        clk.t += 0.01
        tr.instant("parcels", "local_apply", gids=[g])
    for g in gids:
        clk.t += 0.01
        tr.instant("kvcache", "page_free", gid=g, slot=g % n_slots)
    violation = draw(st.sampled_from(
        (None, "rid", "slot", "gid", "freed")))
    clk.t += 0.01
    if violation == "rid":
        tr.instant("engine", "finish", rid=n_req + 100)
    elif violation == "slot":
        tr.instant("kvcache", "attach", slot=99)
    elif violation == "gid":
        tr.instant("percolation", "stage", gids=[n_pages + 100])
    elif violation == "freed":
        tr.instant("percolation", "stage", gids=[gids[0]])
    return tr.records(), violation


@settings(max_examples=60, deadline=None)
@given(causal_stream())
def test_causal_ids_never_dangle_and_dangles_are_caught(stream):
    recs, violation = stream
    problems = check_causal(recs)
    if violation is None:
        assert problems == []
    else:
        assert len(problems) == 1


# -- histogram quantiles -----------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(1e-6, 1e6), min_size=1, max_size=300),
       st.lists(st.floats(0.0, 100.0), min_size=2, max_size=8))
def test_histogram_quantiles_monotone_and_accurate(samples, qs):
    h = StreamingHistogram()
    for s in samples:
        h.record(s)
    qs = sorted(qs)
    vals = [h.quantile(q) for q in qs]
    assert all(a <= b + 1e-12 for a, b in zip(vals, vals[1:]))
    assert h.min <= vals[0] and vals[-1] <= h.max
    # the sketch lands in the same log bucket as the floor order
    # statistic (np.percentile method="lower"), so relative error is
    # bounded by the bucket growth (~3%; 7% allows interpolation slack)
    srt = sorted(samples)
    for q, v in zip(qs, vals):
        exact = srt[int((q / 100.0) * (len(srt) - 1))]
        assert v == pytest.approx(exact, rel=0.07, abs=1e-9)
