"""Paper Fig 8: wallclock comparison and the overhead crossover.

"The HPX based code adds overhead ... which results in slower execution
in simulations with fewer levels of refinement.  MPI outperforms HPX in
these cases.  However, as the number of levels ... and processors
increases, the HPX code outperforms the MPI counterpart by as much as
5%."  We sweep (levels, workers) and report the speedup matrix; the
crossover and the best-case margin are the derived quantities.

The dataflow engine here carries HIGHER per-task overhead (more, finer
tasks + parcel latency) exactly as in the paper; barrier runs pay a
global barrier per substep instead.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro import amr
from repro.amr import taskgraph as tg
from repro.core import barrier_schedule, list_schedule


def run(n_points=512, verbose=True):
    prob = amr.WaveProblem(n_points=n_points, rmax=20.0,
                           amplitude=0.005)
    best_margin = -1e9
    crossover = None
    for levels in (1, 2, 3):
        specs = amr.default_specs(prob, levels)
        # dataflow uses finer grain (its advantage); barrier uses the
        # clustering-style coarse grain; both graphs perform identical
        # physics work.
        wg_df = tg.build_window_graph(specs, 2, 8)
        wg_ba = tg.build_window_graph(specs, 2, 64)
        for p in (4, 8, 16, 32):
            tg.assign_owners(wg_df, p)
            tg.assign_owners(wg_ba, p)
            df = list_schedule(wg_df.graph, p, overhead=5e-6,
                               comm_latency=1e-6)
            ba = barrier_schedule(wg_ba.graph, p, overhead=3e-6,
                                  barrier_cost=2e-5)
            speedup = ba.makespan / df.makespan
            margin = (speedup - 1) * 100
            best_margin = max(best_margin, margin)
            if margin > 0 and crossover is None:
                crossover = (levels, p)
            if verbose:
                who = "HPX" if margin > 0 else "MPI"
                print(f"# fig8 L={levels} P={p:2d} "
                      f"dataflow={df.makespan * 1e3:7.3f}ms "
                      f"barrier={ba.makespan * 1e3:7.3f}ms "
                      f"margin={margin:+6.1f}% ({who} wins)")
    emit("fig8_best_hpx_margin_pct", best_margin,
         f"crossover_at={crossover}")
    return best_margin, crossover


if __name__ == "__main__":
    run()
