"""Percolation core (DESIGN.md §4d): tiered AGAS directories, the
copy-parcel queue, and the double-buffered transfer engine."""

import numpy as np
import pytest

from repro.core.agas import AGAS, AGASError, GlobalAddress
from repro.core.localities import LocalityDomain
from repro.core.percolation import (CopyParcel, PercolationQueue, Tier,
                                    TransferEngine, domain_tiers,
                                    tiered_domain)


# -- tier-aware AGAS ---------------------------------------------------

def _tiered_agas(n_dev=2, dev_cap=4, host_cap=16):
    return AGAS(tiered_domain(n_dev),
                [dev_cap] * n_dev + [host_cap],
                space="kvpage", tiers=domain_tiers(n_dev))


def test_per_locality_capacities_and_tiers():
    agas = _tiered_agas()
    assert agas.capacities == [4, 4, 16]
    assert agas.localities_in_tier(int(Tier.DEVICE)) == [0, 1]
    assert agas.localities_in_tier(int(Tier.HOST)) == [2]
    assert agas.free_count(2) == 16
    # least_loaded unfiltered would pick the big host pool; the
    # device-tier filter must not
    assert agas.least_loaded() == 2
    assert agas.least_loaded(tier=int(Tier.DEVICE)) in (0, 1)


def test_capacity_mismatch_rejected():
    with pytest.raises(ValueError):
        AGAS(tiered_domain(2), [4, 4], space="x",
             tiers=domain_tiers(2))
    with pytest.raises(ValueError):
        AGAS(tiered_domain(2), [4, 4, 8], space="x", tiers=[0, 1])


def test_name_stable_across_tier_migration():
    """The AGAS promise, vertically: demotion/promotion are migrate
    calls that never change the gid."""
    agas = _tiered_agas()
    a = agas.allocate(0)
    gid = a.gid
    agas.migrate(a, 2)              # demote
    assert agas.lookup(a)[0] == 2
    assert agas.tier_of(agas.locality_of(a)) == int(Tier.HOST)
    agas.migrate(a, 1)              # promote onto the other shard
    assert agas.lookup(a)[0] == 1
    assert a.gid == gid
    # device pool exhaustion raises per-locality
    for _ in range(4):
        agas.allocate(0)
    with pytest.raises(AGASError):
        agas.allocate(0)
    # ... while the host locality still has room
    assert agas.free_count(2) == 16


def test_checkpoint_roundtrip_keeps_capacities():
    agas = _tiered_agas()
    a = agas.allocate(0)
    agas.migrate(a, 2)
    state = agas.checkpoint_state()
    back = AGAS.restore_state(state, tiered_domain(2))
    assert back.capacities == [4, 4, 16]
    assert back.tiers == agas.tiers
    assert back.lookup(a)[0] == 2


def test_uniform_restore_onto_different_count_still_works():
    """The elastic-restore fold (§8) predates tiers and must keep
    working: restoring onto a different locality count falls back to
    the uniform capacity."""
    agas = AGAS(LocalityDomain.simulated(4), 8, space="blk")
    addrs = [agas.allocate(i % 4) for i in range(8)]
    state = agas.checkpoint_state()
    back = AGAS.restore_state(state, LocalityDomain.simulated(2))
    for a in addrs:
        loc, _ = back.lookup(a)
        assert 0 <= loc < 2


# -- the percolation queue --------------------------------------------

def test_queue_counters_and_overlap():
    q = PercolationQueue()
    q.record(CopyParcel("d0", (1, 2, 3), "demote", 300))
    # staging enqueues WITHOUT counting: only committed copies move
    # the traffic totals (an abandoned staging never landed)
    q.push(CopyParcel("p0", (1, 2), "promote", 200))
    assert len(q) == 1 and "p0" in q
    assert q.demote_pages == 3 and q.promote_pages == 0
    q.pop("p0")
    assert len(q) == 0
    q.record(CopyParcel("p0", (1, 2), "promote", 200))   # commit
    assert q.promote_pages == 2 and q.promote_bytes == 200
    assert q.demote_bytes == 300
    q.record_promote_commit(prefetched=True)
    q.record_promote_commit(prefetched=True)
    q.record_promote_commit(prefetched=False)
    assert q.prefetch_hits == 2 and q.demand_promotes == 1
    assert q.overlap() == pytest.approx(2 / 3)
    s = q.stats()
    assert s["offload_bytes"] == 300
    assert s["copy_compute_overlap"] == pytest.approx(2 / 3)


# -- the transfer engine ----------------------------------------------

def test_double_buffered_staging():
    eng = TransferEngine(max_inflight=2)
    pay = {"k": np.ones((2, 3)), "v": np.zeros((2, 3))}
    assert eng.stage("a", [1], pay)
    assert eng.stage("a", [1], pay)          # idempotent
    assert eng.stage("b", [2], pay)
    assert not eng.stage("c", [3], pay)      # double buffer full
    assert eng.staged_keys() == ["a", "b"]
    gids, arrays = eng.take("a")
    assert gids == (1,)
    np.testing.assert_array_equal(np.asarray(arrays["k"]), pay["k"])
    assert eng.take("a") is None             # taken once
    eng.drop("b")
    assert eng.staged_keys() == []
    assert eng.stage("c", [3], pay)          # room again
    assert len(eng.queue) == 1               # only c still in flight
    assert eng.queue.promote_parcels == 0    # nothing committed yet


def test_to_host_materializes_device_arrays():
    import jax.numpy as jnp
    eng = TransferEngine()
    arrays = {"k": jnp.arange(6.0).reshape(2, 3)}
    out = eng.to_host(arrays)
    assert isinstance(out["k"], np.ndarray)
    np.testing.assert_array_equal(out["k"],
                                  np.arange(6.0).reshape(2, 3))
