"""Pallas TPU kernels: paged attention over block tables.

Two entry points share the scheme:

* `paged_attention_bhd` — decode: ONE query token per sequence attends
  a KV cache scattered across fixed-size pages.
* `paged_prefill_attention_btd` — chunked prefill: a CHUNK of T query
  tokens (absolute positions start..start+T-1) attends the pages
  already written for earlier chunks plus the chunk's own freshly
  written pages, causal within the chunk (DESIGN.md §4b).

The block table is a *scalar-prefetch* operand
(pltpu.PrefetchScalarGridSpec): it is available before the kernel body
runs, so the k/v index maps dereference it to pick the physical page
row each grid step DMAs into VMEM — the AGAS lookup compiled into an
index map, with no gather materialized in HBM.

Tiling: grid = (B, H, nP) with the page axis LAST (sequential);
online-softmax statistics (m, l) and the output accumulator persist in
VMEM scratch across the nP steps of one (B, H) tile and are flushed on
the final step (same scheme as flash.py).

  q tile  : (1, T, 1, D) VMEM       k/v tile: (1, ps, 1, D) VMEM
  scratch : acc (T, D) f32, m (T, 1) f32, l (T, 1) f32
  (decode is the T == 1 special case with its own entry point)

GQA is handled in the k/v index maps (head h reads kv head
h // n_rep); pages entirely outside the slot's valid range — beyond
its per-slot position counter (or the chunk's last query) or behind
its sliding window — are skipped via @pl.when, so compute scales with
the tokens actually resident, not with the table width.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, ps, n_pages, window, scale,
            sharded=False):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[b]
    base = p * ps
    live = base <= pos
    if window > 0:
        live &= pos - (base + ps - 1) < window

    @pl.when(live)
    def _body():
        q = q_ref[0]                       # (1, D)
        # sharded pools DMA a (1, 1, ps, 1, D) block (locality axis
        # resolved by the index map); flat pools a (1, ps, 1, D) one
        k = k_ref[0, 0, :, 0] if sharded else k_ref[0, :, 0]   # (ps, D)
        v = v_ref[0, 0, :, 0] if sharded else v_ref[0, :, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (1, ps)
        j = base + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        mask = j <= pos
        if window > 0:
            mask &= pos - j < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        pr = jnp.exp(s - m_new)
        pr = jnp.where(mask, pr, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(pr, axis=-1,
                                                 keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            pr.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention_bhd(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray,
                        block_tables: jnp.ndarray,
                        positions: jnp.ndarray, *,
                        window: int = 0,
                        interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, D); k/v_pages: (N, ps, KV, D) — or (S, R, ps, KV, D)
    for a locality-sharded pool, where block-table rows encode
    ``locality * R + slot`` and the index map performs the AGAS
    (locality, slot) decode; block_tables: (B, P) int32 physical rows;
    positions: (B,) int32 per-slot clocks.  Returns (B, H, D)."""
    b, h, d = q.shape
    sharded = k_pages.ndim == 5
    ps, kvh = k_pages.shape[-3], k_pages.shape[-2]
    n_rep = h // kvh
    n_tables = block_tables.shape[1]
    kern = functools.partial(
        _kernel, ps=ps, n_pages=n_tables, window=window,
        scale=d ** -0.5, sharded=sharded)

    # index maps see the scalar-prefetch refs appended to grid indices
    if sharded:
        rps = k_pages.shape[1]             # rows per shard

        def kv_map(bi, hi, pi, bt, pos):
            row = bt[bi, pi]
            return (row // rps, row % rps, 0, hi // n_rep, 0)
        kv_spec = pl.BlockSpec((1, 1, ps, 1, d), kv_map)
    else:
        def kv_map(bi, hi, pi, bt, pos):
            return (bt[bi, pi], 0, hi // n_rep, 0)
        kv_spec = pl.BlockSpec((1, ps, 1, d), kv_map)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, n_tables),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda bi, hi, pi, bt, pos:
                         (bi, hi, 0)),
            kv_spec,
            kv_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bi, hi, pi, bt, pos:
                               (bi, hi, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), positions.astype(jnp.int32),
      q, k_pages, v_pages)


def _prefill_kernel(bt_ref, start_ref, q_ref, k_ref, v_ref, o_ref,
                    acc_ref, m_ref, l_ref, *, t, ps, n_pages, window,
                    scale, sharded=False):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    start = start_ref[b]
    base = p * ps
    # some query in the chunk can see this page: the last query sits at
    # start + t - 1; under a window the earliest query (at `start`)
    # bounds how far back any key can still be visible
    live = base <= start + (t - 1)
    if window > 0:
        live &= start - (base + ps - 1) < window

    @pl.when(live)
    def _body():
        q = q_ref[0, :, 0]                 # (T, D)
        k = k_ref[0, 0, :, 0] if sharded else k_ref[0, :, 0]   # (ps, D)
        v = v_ref[0, 0, :, 0] if sharded else v_ref[0, :, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (T, ps)
        j = base + jax.lax.broadcasted_iota(jnp.int32, (t, ps), 1)
        qpos = start + jax.lax.broadcasted_iota(jnp.int32, (t, ps), 0)
        mask = j <= qpos                   # causal across + within chunk
        if window > 0:
            mask &= qpos - j < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        pr = jnp.exp(s - m_new)
        pr = jnp.where(mask, pr, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(pr, axis=-1,
                                                 keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            pr.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_prefill_attention_btd(q: jnp.ndarray, k_pages: jnp.ndarray,
                                v_pages: jnp.ndarray,
                                block_tables: jnp.ndarray,
                                start: jnp.ndarray, *,
                                window: int = 0,
                                interpret: bool = True) -> jnp.ndarray:
    """Chunked-prefill attention over block tables.

    q: (B, T, H, D) chunk queries; k/v_pages: (N, ps, KV, D) — or
    (S, R, ps, KV, D) for a locality-sharded pool with
    ``locality * R + slot`` row encoding (the AGAS decode lives in the
    index map, exactly as in the decode kernel); block_tables: (B, P)
    int32 physical rows; start: (B,) int32 absolute position of
    q[:, 0].  The chunk's own K/V must already be written into its
    pages; query t attends keys at positions <= start + t (and within
    the sliding window when set).  Returns (B, T, H, D).
    """
    b, t, h, d = q.shape
    sharded = k_pages.ndim == 5
    ps, kvh = k_pages.shape[-3], k_pages.shape[-2]
    n_rep = h // kvh
    n_tables = block_tables.shape[1]
    kern = functools.partial(
        _prefill_kernel, t=t, ps=ps, n_pages=n_tables, window=window,
        scale=d ** -0.5, sharded=sharded)

    if sharded:
        rps = k_pages.shape[1]             # rows per shard

        def kv_map(bi, hi, pi, bt, st):
            row = bt[bi, pi]
            return (row // rps, row % rps, 0, hi // n_rep, 0)
        kv_spec = pl.BlockSpec((1, 1, ps, 1, d), kv_map)
    else:
        def kv_map(bi, hi, pi, bt, st):
            return (bt[bi, pi], 0, hi // n_rep, 0)
        kv_spec = pl.BlockSpec((1, ps, 1, d), kv_map)

    def q_map(bi, hi, pi, bt, st):
        return (bi, 0, hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, n_tables),
        in_specs=[
            pl.BlockSpec((1, t, 1, d), q_map),
            kv_spec,
            kv_spec,
        ],
        out_specs=pl.BlockSpec((1, t, 1, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((t, d), jnp.float32),
            pltpu.VMEM((t, 1), jnp.float32),
            pltpu.VMEM((t, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, t, h, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), start.astype(jnp.int32),
      q, k_pages, v_pages)
