"""Batched serving demo: continuous batching over an AGAS page pool.

  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

import repro.configs as configs
from repro.models import transformer as T
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = configs.get_reduced("yi-6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    # a page pool half the dense footprint: 4 slots x 160 tokens dense
    # would be 40 pages of 16; 20 pages serve the same traffic because
    # pages are allocated on demand (preempting under pressure)
    eng = ServingEngine(params, cfg, slots=4, max_len=160,
                        prefill_buckets=(32, 64), page_size=16,
                        n_pages=20)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    futures = []
    for rid in range(10):
        n = int(rng.integers(8, 60))
        futures.append(eng.submit(Request(
            rid, rng.integers(0, cfg.vocab_size, size=n)
            .astype(np.int32), max_new_tokens=12)))
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    tok = sum(len(c.tokens) for c in eng.completions)
    print(f"{len(eng.completions)} completions, {tok} tokens, "
          f"{dt:.2f}s ({tok / dt:.1f} tok/s incl. compile)")
    for fut in futures[:5]:
        c = fut.get()                  # completion arrives via the LCO
        print(f"  rid={c.rid:2d} prefill={c.prefill_s * 1e3:6.0f}ms "
              f"decode={c.decode_s * 1e3:6.0f}ms tokens={c.tokens[:6]}...")
    s = eng.stats()
    print(f"pages: peak occupancy {s['peak_page_occupancy']:.0%}, "
          f"{s['page_shares']} prefix-shared, "
          f"{s['preemptions']} preemptions")


if __name__ == "__main__":
    main()
