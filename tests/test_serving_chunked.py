"""Chunked prefill (DESIGN.md §4b): chunk attention op, chunk-granular
page accounting, the token-budget step scheduler, differential parity
across all three engines, and the TTFT/inter-token latency split."""

import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import transformer as T
from repro.serving.engine import (ChunkedPagedServingEngine,
                                  DenseServingEngine,
                                  PagedServingEngine, Request,
                                  make_engine)
from repro.serving.kvcache import PagedKVCache

RNG = np.random.default_rng(11)


def _cfg(name="yi-6b"):
    return configs.get_reduced(name)


# -- chunked paged attention op ----------------------------------------

def _rand_pages(n, ps, kvh, d):
    k = jnp.asarray(RNG.normal(size=(n, ps, kvh, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(n, ps, kvh, d)), jnp.float32)
    return k, v


@pytest.mark.parametrize("window", [0, 6])
@pytest.mark.parametrize("kvh", [1, 2])
def test_chunk_prefill_pallas_kernel_matches_ref(window, kvh):
    from repro.kernels.attention.ops import paged_prefill_attention
    from repro.kernels.attention.ref import paged_prefill_attention_ref
    b, t, h, d, ps, npages, ptab = 3, 8, 4, 16, 8, 9, 5
    q = jnp.asarray(RNG.normal(size=(b, t, h, d)), jnp.float32)
    kp, vp = _rand_pages(npages + 1, ps, kvh, d)
    tables = jnp.asarray(RNG.integers(0, npages, size=(b, ptab)),
                         jnp.int32)
    start = jnp.asarray([0, 8, 21], jnp.int32)
    ref = paged_prefill_attention_ref(q, kp, vp, tables, start,
                                      window=window)
    got = paged_prefill_attention(q, kp, vp, tables, start,
                                  window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5)


def test_chunk_ref_first_chunk_matches_flash_prefill():
    """A chunk starting at position 0 whose pages hold exactly its own
    K/V must reproduce plain causal attention."""
    from repro.kernels.attention.ref import paged_prefill_attention_ref
    from repro.models.attention import flash_jnp, repeat_kv
    b, t, h, kvh, d, ps = 1, 16, 4, 2, 16, 8
    q = jnp.asarray(RNG.normal(size=(b, t, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, t, kvh, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, t, kvh, d)), jnp.float32)
    # lay the chunk's K/V into pages 0..1 (null row = 2)
    kp = jnp.zeros((3, ps, kvh, d), jnp.float32)
    vp = jnp.zeros((3, ps, kvh, d), jnp.float32)
    kp = kp.at[:2].set(k.reshape(2, ps, kvh, d))
    vp = vp.at[:2].set(v.reshape(2, ps, kvh, d))
    tables = jnp.asarray([[0, 1, 2]], jnp.int32)
    got = paged_prefill_attention_ref(q, kp, vp, tables,
                                      jnp.asarray([0], jnp.int32))
    ref = flash_jnp(q, repeat_kv(k, h // kvh), repeat_kv(v, h // kvh),
                    causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5)


# -- chunk-granular page accounting ------------------------------------

def test_begin_chunk_accounting_and_prefix_chain():
    cfg = _cfg()
    kvc = PagedKVCache(cfg, slots=2, max_len=64, n_pages=8,
                       page_size=16)
    padded = RNG.integers(0, 100, size=40).astype(np.int32)
    # chunk 1: two full pages; chunk 2: one partial page (8 of 16)
    rows0, cov0 = kvc.begin_chunk(0, padded, 0, 32)
    assert len(rows0) == 2 and kvc.lengths[0] == 32
    assert cov0 == 0                    # cold: nothing covered
    assert all(r != kvc.pool.null_row for r in rows0)
    rows1, _ = kvc.begin_chunk(0, padded, 32, 40)
    assert len(rows1) == 1 and kvc.lengths[0] == 40
    assert kvc.pool.used_pages == 3
    # the partial last page is held between prefill and decode: the
    # first decode write lands at offset 8 of the SAME page, no alloc
    assert not kvc.needs_alloc(0)
    kvc.prepare_decode(0)
    assert kvc.pool.used_pages == 3
    assert int(kvc.write_offs[0]) == 8
    # chunk boundaries don't change page identity: a whole-prompt
    # attach of the same padded prompt shares every chunked page
    assert kvc.pages_needed(padded) == 0
    L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    k = jnp.zeros((L, 40, kvh, hd), jnp.float32)
    covered = kvc.attach(1, padded, k, k)
    assert covered == 40                # every page a leading hit
    assert kvc.pool.shares == 3
    assert np.array_equal(kvc.tables[0][:3], kvc.tables[1][:3])
    kvc.release(0)
    kvc.release(1)
    assert kvc.pool.used_pages == 0


def test_begin_chunk_atomic_under_exhaustion():
    cfg = _cfg()
    kvc = PagedKVCache(cfg, slots=1, max_len=64, n_pages=3,
                       page_size=16)
    padded = RNG.integers(0, 100, size=64).astype(np.int32)
    kvc.begin_chunk(0, padded, 0, 32)
    from repro.serving.kvcache import PageExhausted
    with pytest.raises(PageExhausted):
        kvc.begin_chunk(0, padded, 32, 64)   # needs 2, only 1 free
    # all-or-nothing: the failed chunk acquired no pages
    assert kvc.pool.used_pages == 2 and kvc.lengths[0] == 32
    kvc.release(0)
    assert kvc.pool.used_pages == 0


# -- differential parity: dense == whole-prompt paged == chunked -------

def _parity_requests(cfg, seed=3, bucket=64):
    """Mixed real lengths, pre-padded to one shared left-padded
    stream: the paged engines run prompts pad-free while the dense
    baseline left-pads to its bucket, so cross-engine parity needs the
    pad to be part of the prompt itself — then every engine computes
    the identical layout."""
    rng = np.random.default_rng(seed)
    # 5 < one page (16); 40 > one chunk (32); plus two mid lengths
    lens = [5, 40, 20, 12]
    reqs = []
    for rid, n in enumerate(lens):
        p = np.zeros(bucket, np.int32)
        p[bucket - n:] = rng.integers(0, cfg.vocab_size,
                                      size=n).astype(np.int32)
        reqs.append(Request(rid, p, max_new_tokens=6))
    return reqs


@pytest.mark.parametrize("arch", ["yi-6b", "mixtral-8x7b",
                                  "h2o-danube-3-4b"])
def test_differential_engine_parity(arch):
    """Greedy decode is token-identical across the dense, whole-prompt
    paged, and chunked engines — dense attention (yi), MoE (mixtral),
    and sliding-window (danube) — on a trace of mixed real lengths
    sharing one explicit left-padded stream (see _parity_requests).

    One shared bucket keeps the dense engine's single position clock
    valid (seed caveat), and — as in the seed parity test — the chosen
    seed has no float near-ties between the separately compiled
    executables.  (Pad-free mixed-length layouts are exercised by the
    differential fuzzer, which compares the two paged engines.)"""
    cfg = _cfg(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _parity_requests(cfg)
    kw = dict(slots=4, max_len=96, prefill_buckets=(64,))
    engines = [
        ChunkedPagedServingEngine(params, cfg, page_size=16,
                                  chunk_size=32, **kw),
        PagedServingEngine(params, cfg, page_size=16, **kw),
        DenseServingEngine(params, cfg, **kw),
    ]
    results = []
    for eng in engines:
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
        results.append({c.rid: c.tokens for c in eng.completions})
    chunked, paged, dense = results
    assert set(chunked) == {r.rid for r in reqs}
    assert chunked == paged
    assert chunked == dense
    for eng in engines[:2]:
        assert eng.kvc.pool.used_pages == 0


def test_make_engine_selects_and_falls_back():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    kw = dict(slots=2, max_len=64, prefill_buckets=(32,))
    assert isinstance(make_engine(params, cfg, **kw),
                      ChunkedPagedServingEngine)
    assert isinstance(make_engine(params, cfg, engine="paged", **kw),
                      PagedServingEngine)
    assert isinstance(make_engine(params, cfg, engine="dense", **kw),
                      DenseServingEngine)
    scfg = _cfg("falcon-mamba-7b")
    sparams = T.init_params(jax.random.PRNGKey(0), scfg)
    eng = make_engine(sparams, scfg, chunk_size=32, **kw)
    assert isinstance(eng, DenseServingEngine)   # ssm: no paged layout
    with pytest.raises(ValueError, match="unknown engine"):
        make_engine(params, cfg, engine="turbo", **kw)


# -- preemption determinism mid-prefill --------------------------------

def test_mid_prefill_preemption_readmits_with_identical_tokens():
    """Page exhaustion during a chunked prefill preempts the request
    (LIFO); its re-admission re-prefills from scratch and must produce
    exactly the tokens of an uncontended run."""
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    A = Request(0, rng.integers(0, cfg.vocab_size, size=20)
                .astype(np.int32), max_new_tokens=24)
    B = Request(1, rng.integers(0, cfg.vocab_size, size=30)
                .astype(np.int32), max_new_tokens=6)

    def run(reqs):
        eng = ChunkedPagedServingEngine(
            params, cfg, slots=2, max_len=64, prefill_buckets=(32,),
            page_size=8, chunk_size=16, n_pages=8)
        victim_phases = []
        orig = eng._preempt

        def spy(slot):
            victim_phases.append(eng.active[slot]["phase"])
            orig(slot)
        eng._preempt = spy
        futs = [eng.submit(r) for r in reqs]
        eng.run_to_completion()
        return eng, futs, victim_phases

    eng, futs, phases = run([A, B])
    # the pool (8 pages of 8) cannot hold A's decode growth plus B's
    # prefill: B must have been evicted mid-prefill at least once
    assert eng.preemptions > 0
    assert "prefill" in phases
    comp = {c.rid: c for c in eng.completions}
    assert len(comp[0].tokens) == 24 and len(comp[1].tokens) == 6
    assert comp[1].preemptions > 0
    assert eng.kvc.pool.used_pages == 0
    for r, f in zip([A, B], futs):
        assert f.done() and f.get().rid == r.rid

    solo, _, _ = run([B])
    assert solo.preemptions == 0
    solo_tokens = {c.rid: c.tokens for c in solo.completions}[1]
    assert comp[1].tokens == solo_tokens


# -- stats(): guarded aggregates + the TTFT / inter-token split --------

def test_stats_safe_before_any_completion():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    for engine in ("paged", "chunked"):
        eng = make_engine(params, cfg, engine=engine, slots=2,
                          max_len=64, prefill_buckets=(32,))
        with warnings.catch_warnings():
            warnings.simplefilter("error")      # np.mean([]) would warn
            s = eng.stats()
        for key in ("mean_prefill_ms", "mean_decode_ms", "mean_ttft_ms",
                    "ttft_p50_ms", "ttft_p95_ms", "mean_itl_ms",
                    "itl_p50_ms", "itl_p95_ms"):
            assert s[key] == 0.0 and not np.isnan(s[key])


def test_stats_ttft_and_itl_populated_after_run():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ChunkedPagedServingEngine(params, cfg, slots=2, max_len=64,
                                    prefill_buckets=(32,), page_size=16,
                                    chunk_size=32)
    for rid in range(2):
        eng.submit(Request(rid, np.arange(10 + rid, dtype=np.int32),
                           max_new_tokens=4))
    eng.run_to_completion()
    for c in eng.completions:
        assert c.ttft_s > 0.0
        assert len(c.itl_s) == len(c.tokens) - 1
        assert all(d >= 0.0 for d in c.itl_s)
    s = eng.stats()
    assert s["ttft_p50_ms"] > 0.0
    assert s["itl_p50_ms"] > 0.0
    assert 0.0 < s["ttft_p50_ms"] <= s["ttft_p95_ms"]
    # per-step telemetry records the budget split: pad-free layouts
    # prefill exactly the real tokens (10 + 11), not a padded bucket
    assert all("prefill_chunk_tokens" in x and "decode_tokens" in x
               for x in eng.counters)
    assert sum(x["prefill_chunk_tokens"] for x in eng.counters) == 21
    assert all(x["prefill_chunk_tokens"] + x["decode_tokens"]
               <= x["budget_tokens"] for x in eng.counters)


def test_max_new_tokens_one_returns_exactly_one_token():
    """The token prefill samples counts against the cap: a
    max_new_tokens=1 request never enters the decode batch (all three
    engines)."""
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    for engine in ("dense", "paged", "chunked"):
        eng = make_engine(params, cfg, engine=engine, slots=2,
                          max_len=64, prefill_buckets=(32,))
        fut = eng.submit(Request(0, np.arange(10, dtype=np.int32),
                                 max_new_tokens=1))
        eng.run_to_completion()
        assert len(fut.get().tokens) == 1, engine
        if hasattr(eng, "kvc"):
            assert eng.kvc.pool.used_pages == 0


def test_step_budget_holds_across_prefill_to_decode_transition():
    """A slot whose final chunk lands mid-step must NOT also decode in
    that step: with 2 slots already decoding (budget 34 - 2 = 32) a
    32-token final chunk exactly fills the remainder, and letting the
    transitioning slot decode too would spend 35 > 34 tokens."""
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ChunkedPagedServingEngine(params, cfg, slots=4, max_len=64,
                                    prefill_buckets=(32,), page_size=16,
                                    chunk_size=32, step_tokens=34)
    for rid in range(3):
        eng.submit(Request(rid, np.arange(32, dtype=np.int32) + rid,
                           max_new_tokens=4))
    eng.run_to_completion()
    assert len(eng.completions) == 3
    assert all(x["prefill_chunk_tokens"] + x["decode_tokens"]
               <= x["budget_tokens"] for x in eng.counters)


def test_chunked_engine_rejects_bad_grain_config():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="multiple"):
        ChunkedPagedServingEngine(params, cfg, page_size=16,
                                  chunk_size=24)
    with pytest.raises(ValueError, match="step_tokens"):
        ChunkedPagedServingEngine(params, cfg, page_size=16,
                                  chunk_size=32, step_tokens=16)
