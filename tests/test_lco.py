"""LCO semantics: futures, dataflow, full/empty, semaphores."""

import pytest

from repro.core.lco import (CountingSemaphore, Dataflow,
                            DependencyCounter, FullEmptyBit, Future,
                            LCOError)


def test_future_set_get():
    f = Future()
    assert not f.done()
    f.set(42)
    assert f.done() and f.get() == 42


def test_future_write_once():
    f = Future()
    f.set(1)
    with pytest.raises(LCOError):
        f.set(2)


def test_future_get_before_set_raises():
    with pytest.raises(LCOError):
        Future().get()


def test_future_continuations_run_inline():
    f = Future()
    seen = []
    f.then(seen.append)
    f.then(seen.append)
    f.set("x")
    assert seen == ["x", "x"]
    # late registration fires immediately
    f.then(seen.append)
    assert seen == ["x", "x", "x"]


def test_dataflow_fires_once_all_inputs_set():
    out = []
    df = Dataflow(3, out.append)
    df.set_input(2, "c")
    df.set_input(0, "a")
    assert not df.fired
    df.set_input(1, "b")
    assert df.fired and out == [["a", "b", "c"]]


def test_dataflow_zero_inputs_fires_immediately():
    out = []
    Dataflow(0, out.append)
    assert out == [[]]


def test_dataflow_input_set_twice_raises():
    df = Dataflow(2, lambda v: None)
    df.set_input(0, 1)
    with pytest.raises(LCOError):
        df.set_input(0, 1)


def test_full_empty_bit():
    fe = FullEmptyBit()
    got = []
    fe.read_ff(got.append)          # queued
    fe.write_ef(7)
    assert got == [7]
    assert fe.read_fe() == 7        # empties
    with pytest.raises(LCOError):
        fe.read_fe()


def test_counting_semaphore_cooperative():
    sem = CountingSemaphore(1)
    order = []
    sem.wait(lambda: order.append("a"))   # grabs the initial count
    sem.wait(lambda: order.append("b"))   # queued
    sem.wait(lambda: order.append("c"))   # queued
    sem.signal(2)
    assert order == ["a", "b", "c"]


def test_dependency_counter():
    fired = []
    c = DependencyCounter(2, lambda: fired.append(True))
    c.satisfy()
    assert not fired
    c.satisfy()
    assert fired == [True]
    with pytest.raises(LCOError):
        c.satisfy()
