"""ParalleX execution-model core: LCOs, parcels, AGAS, localities,
the dataflow scheduler, and task-granularity control."""

from repro.core.agas import (AGAS, AGASError, GlobalAddress,
                             balanced_placement, contiguous_placement)
from repro.core.granularity import (GrainModel, auto_tune, n_tasks,
                                    optimal_grain_analytic, sweep)
from repro.core.lco import (CountingSemaphore, Dataflow, DependencyCounter,
                            FullEmptyBit, Future, LCOError)
from repro.core.localities import Locality, LocalityDomain
from repro.core.parcels import (ActionRegistry, HaloLowering, MigrationPlan,
                                Parcel, ParcelPort, lower_halo_parcels,
                                migration_plan, parcel_traffic_bytes)
from repro.core.scheduler import (RoundSchedule, ScheduleError,
                                  ScheduleResult, Task, TaskGraph,
                                  barrier_schedule, execute_topologically,
                                  list_schedule, pack_rounds)

__all__ = [
    "AGAS", "AGASError", "GlobalAddress", "balanced_placement",
    "contiguous_placement", "GrainModel", "auto_tune", "n_tasks",
    "optimal_grain_analytic", "sweep", "CountingSemaphore", "Dataflow",
    "DependencyCounter", "FullEmptyBit", "Future", "LCOError", "Locality",
    "LocalityDomain", "ActionRegistry", "HaloLowering", "MigrationPlan",
    "Parcel", "ParcelPort", "lower_halo_parcels", "migration_plan",
    "parcel_traffic_bytes", "RoundSchedule", "ScheduleError",
    "ScheduleResult", "Task", "TaskGraph", "barrier_schedule",
    "execute_topologically", "list_schedule", "pack_rounds",
]
