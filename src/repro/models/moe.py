"""Mixture-of-Experts: top-k routing with GShard grouped dense dispatch.

The dispatch/combine path is the LM-side incarnation of ParalleX
*parcels*: a token routed to a remote expert is exactly "move the work
to the data" — under the production sharding (groups over "data",
experts over "model") the dispatch einsums lower to the all-to-all /
slice collectives the roofline's collective term measures.

EP x TP composition (DESIGN.md §6): when n_experts < the model-axis
size M, each expert is split into tp = M / n_experts *virtual experts*
along d_ff (mixtral: 8 experts x 2 TP -> 16 virtual).  The dispatch
mask is kron-expanded so a token visits both halves of its expert; the
combine sum over virtual experts IS the tensor-parallel psum.  Gate
probabilities are applied once per real expert because the halves'
partial outputs add to the full output.

Capacity: per group of `group_size` tokens, each (virtual) expert owns
C = ceil(top_k * group_size * capacity_factor / n_virtual) slots;
overflow tokens are dropped (standard GShard top-2 behaviour).  Groups
keep the dispatch tensor at O(group_size * C) per device.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import Params, _init_dense


def moe_init(key, cfg: ArchConfig, tp: int = 1) -> Params:
    """tp = virtual-expert split factor (model_axis / n_experts at the
    production mesh; 1 on CPU smoke tests)."""
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    ev, ffv = e * tp, ff // tp
    return {
        "router": _init_dense(ks[0], d, e, jnp.float32),
        # virtual-expert stacked weights: (EV, d, ff/tp) / (EV, ff/tp, d)
        "wi": _init_dense(ks[1], d, ffv * ev, dt).reshape(d, ev, ffv)
              .swapaxes(0, 1),
        "wg": _init_dense(ks[2], d, ffv * ev, dt).reshape(d, ev, ffv)
              .swapaxes(0, 1),
        "wdown": _init_dense(ks[3], ffv * ev, d, dt).reshape(ev, ffv, d),
    }


def top2_dispatch(logits: jnp.ndarray, capacity: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """GShard top-2 routing for one token group.

    logits: (G, T, E) f32.  Returns (dispatch (G,T,E,C) bool-ish,
    combine (G,T,E,C) f32, aux_loss ()).
    """
    g, t, e = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)
    # top-1
    idx1 = jnp.argmax(probs, axis=-1)
    mask1 = jax.nn.one_hot(idx1, e, dtype=jnp.float32)
    p1 = jnp.sum(probs * mask1, axis=-1)
    # top-2 (mask out the winner)
    probs2 = probs * (1.0 - mask1)
    idx2 = jnp.argmax(probs2, axis=-1)
    mask2 = jax.nn.one_hot(idx2, e, dtype=jnp.float32)
    p2 = jnp.sum(probs * mask2, axis=-1)
    # renormalize the pair
    denom = jnp.maximum(p1 + p2, 1e-9)
    p1, p2 = p1 / denom, p2 / denom
    # positions within expert buffers (top-1 claims slots first)
    pos1 = jnp.cumsum(mask1, axis=1) * mask1 - mask1      # 0-based
    count1 = jnp.sum(mask1, axis=1, keepdims=True)        # (G,1,E)
    pos2 = (jnp.cumsum(mask2, axis=1) - mask2 + count1) * mask2
    keep1 = mask1 * (pos1 < capacity)
    keep2 = mask2 * (pos2 < capacity)
    oh1 = jax.nn.one_hot(pos1, capacity, dtype=jnp.float32) * \
        keep1[..., None]
    oh2 = jax.nn.one_hot(pos2, capacity, dtype=jnp.float32) * \
        keep2[..., None]
    dispatch = oh1 + oh2                                  # (G,T,E,C)
    combine = oh1 * p1[..., None, None] + oh2 * p2[..., None, None]
    # load-balancing aux loss (Switch/GShard form)
    me = jnp.mean(probs, axis=1)                          # (G,E)
    ce = jnp.mean(mask1, axis=1)
    aux = jnp.mean(me * ce) * (e * e)
    return dispatch, combine, aux


def moe_apply(params: Params, x: jnp.ndarray, cfg: ArchConfig,
              tp: int = 1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss).

    Tokens are folded into groups of cfg.moe_group_size; each group
    routes and disperses independently (GShard).  With groups sharded
    over "data" and (virtual) experts over "model", all einsums below
    are local except the final combine's psum over the expert axis.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    assert k == 2, "top-2 routing (mixtral/phi3.5)"
    tokens = x.reshape(b * s, d)
    gs = min(cfg.moe_group_size, tokens.shape[0])
    n_groups = tokens.shape[0] // gs
    xt = tokens[:n_groups * gs].reshape(n_groups, gs, d)

    logits = (xt.astype(jnp.float32) @ params["router"])  # (G,T,E)
    cap = max(int(k * gs * cfg.capacity_factor / e), 4)
    dispatch, combine, aux = top2_dispatch(logits, cap)
    if tp > 1:
        # Each real expert's token set goes to ALL of its tp virtual
        # splits (same slot); the combine-sum over virtual experts adds
        # the partial wdown outputs — i.e. the TP psum.
        dispatch = jnp.repeat(dispatch, tp, axis=2)
        combine = jnp.repeat(combine, tp, axis=2)

    # dispatch is a 0/1 routing tensor: its cotangent is useless (the
    # router learns through `combine`), and killing it removes one
    # activation-sized all-reduce per layer per microbatch (§Perf F2a).
    dsp = jax.lax.stop_gradient(dispatch).astype(x.dtype)
    # combine in param dtype: its (G,T,E,C) cotangent is psum'd across
    # the expert shards every layer; f32 doubles those bytes (F2c).
    combine = combine.astype(x.dtype)
    from repro.models.layers import constrain_spec
    expert_in = jnp.einsum("gtec,gtd->gecd", dsp, xt)     # (G,EV,C,D)
    # Pin expert buffers to EP sharding (e -> "model"); without this
    # the partitioner contracted over a model-sharded d and emitted
    # f32 all-reduces of the (G,EV,C,F) hidden per layer (§Perf F2b).
    expert_in = constrain_spec(expert_in, "DP", "model", "U", "U")
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in,
                               params["wg"])) * \
        jnp.einsum("gecd,edf->gecf", expert_in, params["wi"])
    h = constrain_spec(h, "DP", "model", "U", "U")
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["wdown"])
    y = jnp.einsum("gtec,gecd->gtd", combine, expert_out)
    y = y.reshape(n_groups * gs, d)
    if n_groups * gs < tokens.shape[0]:
        y = jnp.concatenate(
            [y, jnp.zeros((tokens.shape[0] - n_groups * gs, d),
                          y.dtype)], axis=0)
    return y.reshape(b, s, d), aux
