"""falcon-mamba-7b: attention-free Mamba-1 SSM.
[arXiv:2410.05355; unverified]

64L d_model=4096 (attn-free) vocab=65024, ssm_state=16, expand=2.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    mamba_version=1,
    tie_embeddings=True,
    microbatch_per_device=2,
    # §Perf F9: 7.3B params shard only 16-way without FSDP, leaving
    # 1.8 GiB f32 grad buffers x2 in the accumulation scan; FSDP +
    # bf16 accumulation bring the train cell under HBM.
    force_fsdp=True,
    grad_accum_dtype="bfloat16",
)
