"""Paper Fig 9: thread-management overhead and its amortization.

Two measurements:

1. MEASURED host-engine overhead: wall-clock per task of the dataflow
   executor (core/lco.DependencyCounter firing through
   execute_topologically) on zero-work tasks — our analogue of the
   HPX-thread 3-5 us management cost, measured on this machine.

2. The Fig 9 sweep on the execution model: average per-task overhead
   vs worker count for artificial workloads of 0/15/45/115 us, one
   chain-free graph of N tasks; reports the scaling factor at 44
   workers for the 115 us load (paper: ~23x).

3. COMPILED-engine overhead: per-task cost of the compiled wavefront
   (rounds lowered to one XLA program) — scheduling decisions are
   compile-time constants, so the per-task runtime overhead is the
   amortized launch cost only (DESIGN.md §2/§5).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import TaskGraph, execute_topologically, list_schedule


def measured_dispatch_overhead(n_tasks=20000):
    g = TaskGraph()
    prev = None
    for i in range(n_tasks):
        deps = [prev] if prev is not None and i % 7 == 0 else []
        tid = g.add(0.0, phase=i, deps=deps)
        prev = tid
    sink = [0]

    def run(task):
        sink[0] += 1

    t0 = time.perf_counter()
    execute_topologically(g, run)
    dt = time.perf_counter() - t0
    assert sink[0] == n_tasks
    return dt / n_tasks


def fig9_sweep(n_tasks=100_000, verbose=True, sigma=None):
    if sigma is None:
        sigma = measured_dispatch_overhead()
    workloads = [0.0, 15e-6, 45e-6, 115e-6]
    workers = [2, 4, 8, 16, 32, 44, 48]
    out = {}
    for w_us in workloads:
        g = TaskGraph()
        for i in range(n_tasks // 10):   # model is per-task: scale ok
            g.add(w_us, phase=0)
        row = []
        for p in workers:
            r = list_schedule(g, p, overhead=sigma)
            # average overhead per thread, as plotted in Fig 9:
            # (makespan*P - useful work) / n_tasks
            avg_ovh = (r.makespan * p - g.work()) / len(g)
            row.append((p, r.makespan, avg_ovh))
        out[w_us] = row
        if verbose:
            print(f"# fig9 load={w_us * 1e6:5.1f}us  " + " ".join(
                f"P{p}:{o * 1e6:.2f}us" for p, _, o in row))
    # scaling factor at 44 workers for the heaviest load
    heavy = out[115e-6]
    t1 = [m for p, m, _ in heavy if p == 2][0] * 2   # serial estimate
    t44 = [m for p, m, _ in heavy if p == 44][0]
    scaling = t1 / t44
    return sigma, scaling, out


def compiled_overhead():
    """Per-task overhead of the compiled engine: one jitted step over
    a pool of blocks vs the same compute as per-block python calls."""
    import jax
    import jax.numpy as jnp

    from repro.amr.compiled import CompiledAMRConfig, make_uniform_step
    from repro.amr.wave import WaveProblem

    from repro.distributed.compat import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    prob = WaveProblem(rmax=20.0, amplitude=0.005)
    cfg = CompiledAMRConfig(grain=64, slots=32, n_steps=8)
    step, mk, init, to_g, shd, info = make_uniform_step(
        prob, cfg, mesh, ("data", "model"))
    jstep = jax.jit(step)
    pool = init()
    jstep(pool)[0].block_until_ready() if hasattr(
        jstep(pool), '__getitem__') else None
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        pool = jstep(pool)
    jax.block_until_ready(pool)
    dt = time.perf_counter() - t0
    n_task_execs = cfg.slots * cfg.n_steps * reps
    return dt / n_task_execs


def run(verbose=True, out=None):
    from benchmarks.common import emit_registry
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    disp = reg.histogram("fig9.host_dispatch_us")
    for _ in range(3):
        disp.record(measured_dispatch_overhead(n_tasks=5000) * 1e6)
    sigma = disp.quantile(50) * 1e-6   # median of the repeats
    _, scaling, _ = fig9_sweep(verbose=verbose, sigma=sigma)
    comp = compiled_overhead()
    reg.gauge("fig9.scaling_factor_44w_115us").set(scaling)
    reg.gauge("fig9.compiled_per_task_us").set(comp * 1e6)
    if verbose:
        print(f"# fig9 measured host dispatch overhead: "
              f"{sigma * 1e6:.2f} us/task (paper: 3-5 us)")
        print(f"# fig9 scaling factor at 44 workers, 115us load: "
              f"{scaling:.1f} (paper: ~23)")
        print(f"# fig9 compiled-engine per-task time: "
              f"{comp * 1e6:.2f} us (scheduling overhead ~0, "
              f"amortized launch only)")
    emit("fig9_host_dispatch_overhead", sigma * 1e6, "us_per_task")
    emit("fig9_scaling_factor_44w_115us", scaling, "paper_23")
    emit("fig9_compiled_per_task", comp * 1e6, "us_per_task")
    emit_registry(reg)
    if out:
        import json
        with open(out, "w") as f:
            json.dump(reg.snapshot(), f, indent=1, sort_keys=True)
        if verbose:
            print(f"# fig9 registry snapshot -> {out}")
    return sigma, scaling, comp


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot JSON")
    run(out=ap.parse_args().out)
