"""Serving benchmark: AGAS paged KV cache vs dense slot-pool baseline.

At equal peak KV bytes, the dense engine owns `slots x max_len` token
rows whether or not tokens exist; the paged engine spends the same
bytes as an on-demand page pool and can therefore run MORE concurrent
requests when real prompt lengths are mixed (short requests only hold
the pages they touched).  This bench serves one mixed-length trace
through both engines and reports throughput, achieved concurrency, and
page occupancy — the serving rendering of the paper's Fig 9 claim that
runtime-managed resources amortize their management overhead.

Emits the run.py ``name,us_per_call,derived`` CSV contract plus one
``# json {...}`` line (and ``--out FILE`` to persist the JSON).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import emit

ARCH = "yi-6b"
SLOTS_DENSE = 4
MAX_LEN = 96                # dense peak: 4 * 96 = 384 KV token rows
PAGE_SIZE = 16
N_PAGES = SLOTS_DENSE * MAX_LEN // PAGE_SIZE    # same 384 rows: 24 pages
SLOTS_PAGED = 8             # paged runs 2x the decode width, same bytes
N_REQUESTS = 16
MAX_NEW = 16


def _requests(cfg):
    rng = np.random.default_rng(0)
    from repro.serving.engine import Request
    return [Request(rid, rng.integers(
        0, cfg.vocab_size, size=int(rng.integers(8, 30)))
        .astype(np.int32), max_new_tokens=MAX_NEW)
        for rid in range(N_REQUESTS)]


def _serve(eng, reqs):
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    new_tokens = sum(len(c.tokens) for c in eng.completions)
    assert len(eng.completions) == len(reqs)
    return dt, new_tokens


def run(verbose=True, out_path=None):
    import jax

    import repro.configs as configs
    from repro.models import transformer as T
    from repro.serving.engine import (DenseServingEngine,
                                      PagedServingEngine)

    cfg = configs.get_reduced(ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(cfg)

    dense = DenseServingEngine(params, cfg, slots=SLOTS_DENSE,
                               max_len=MAX_LEN, prefill_buckets=(32,))
    dense_s, dense_tok = _serve(dense, reqs)
    # the dense engine can never exceed its slot count
    dense_peak_active = SLOTS_DENSE

    paged = PagedServingEngine(params, cfg, slots=SLOTS_PAGED,
                               max_len=MAX_LEN, prefill_buckets=(32,),
                               page_size=PAGE_SIZE, n_pages=N_PAGES)
    paged_s, paged_tok = _serve(paged, reqs)
    st = paged.stats()

    result = {
        "arch": ARCH,
        "kv_token_rows": SLOTS_DENSE * MAX_LEN,
        "dense": {"slots": SLOTS_DENSE, "tok_s": dense_tok / dense_s,
                  "wall_s": dense_s, "peak_active": dense_peak_active},
        "paged": {"slots": SLOTS_PAGED, "tok_s": paged_tok / paged_s,
                  "wall_s": paged_s, "pages": N_PAGES,
                  "page_size": PAGE_SIZE,
                  "peak_active": st["peak_active"],
                  "peak_page_occupancy": st["peak_page_occupancy"],
                  "preemptions": st["preemptions"],
                  "page_shares": st["page_shares"],
                  "cow_copies": st["cow_copies"]},
    }
    if verbose:
        print(f"# serve_bench dense  {dense_tok / dense_s:8.1f} tok/s "
              f"peak_active={dense_peak_active}")
        print(f"# serve_bench paged  {paged_tok / paged_s:8.1f} tok/s "
              f"peak_active={st['peak_active']} "
              f"occ={st['peak_page_occupancy']:.2f} "
              f"preempt={st['preemptions']}")
        print("# json " + json.dumps(result))
    emit("serve_dense_tok_s", dense_tok / dense_s, "tok_per_s")
    emit("serve_paged_tok_s", paged_tok / paged_s, "tok_per_s")
    emit("serve_paged_peak_active", st["peak_active"],
         f"dense_slots_{SLOTS_DENSE}_equal_kv_bytes")
    emit("serve_paged_peak_page_occupancy",
         st["peak_page_occupancy"] * 100.0, "percent")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run(out_path=args.out)
