"""Shared benchmark utilities."""

from __future__ import annotations

import json
import time
from typing import Callable, List, Tuple

import numpy as np

#: Schema id stamped into every machine-readable bench trajectory
#: file (BENCH_<n>.json) so tools/bench_compare.py can refuse files
#: it does not understand instead of mis-diffing them.
BENCH_SCHEMA = "repro.serve_bench.v1"


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The run.py contract: name,us_per_call,derived CSV lines."""
    print(f"{name},{us_per_call:.3f},{derived}")


def emit_registry(registry, derived: str = "registry") -> None:
    """Emit every scalar in an obs.MetricsRegistry snapshot through
    emit(), so benchmark metrics flow through the same CSV contract
    as hand-picked numbers (DESIGN.md §10)."""
    for name, value in sorted(registry.snapshot().items()):
        emit(name, float(value), derived)


def write_bench(path: str, bench_id: int, scenarios: dict,
                floors: dict | None = None,
                meta: dict | None = None) -> dict:
    """Write one machine-readable bench trajectory (BENCH_<n>.json):
    a schema'd, diffable snapshot of per-scenario bench metrics.
    ``floors`` maps dotted ``scenario.metric`` keys to minimum
    acceptable values; tools/bench_compare.py checks them and diffs
    the scenario map against the previous BENCH_*.json."""
    doc = {
        "schema": BENCH_SCHEMA,
        "bench_id": int(bench_id),
        "scenarios": scenarios,
        "floors": dict(floors or {}),
        "meta": dict(meta or {}),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


def read_bench(path: str) -> dict:
    """Load + schema-check one BENCH_<n>.json trajectory."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != BENCH_SCHEMA:
        raise ValueError(
            f"{path}: schema {doc.get('schema')!r} is not "
            f"{BENCH_SCHEMA!r}")
    return doc
